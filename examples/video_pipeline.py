"""Real-time video analysis pipeline (paper §5.2 Video Streams).

frames -> detector -> {people classifier, vehicle classifier} in parallel
-> union -> groupby(label) -> count, with operator fusion.  The paper's
headline result is meeting real-time latency on this pipeline.

  PYTHONPATH=src python examples/video_pipeline.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_tiny_config
from repro.core.dataflow import Dataflow
from repro.core.table import Table
from repro.models import build_model
from repro.runtime import NetModel, Runtime


def load(arch, seed):
    cfg = get_tiny_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))

    @jax.jit
    def fwd(tokens):
        logits, _ = model.logits(params, {"tokens": tokens}, remat=False)
        return logits[:, -1]

    fwd(jnp.ones((1, 16), jnp.int32)).block_until_ready()
    return fwd


def main():
    yolo = load("llama-3.2-vision-11b", 0)   # detector stand-in (vlm arch!)
    people = load("yi-9b", 1)
    vehicles = load("glm4-9b", 2)

    def detect(clip: np.ndarray) -> np.ndarray:
        toks = (clip[:16] * 255).astype(np.int32) % 500
        _ = np.asarray(yolo(jnp.asarray(toks)[None]))
        return toks

    def classify_people(toks: np.ndarray) -> tuple[str, float]:
        o = np.asarray(people(jnp.asarray(toks)[None]))[0]
        return f"person-{int(o.argmax()) % 3}", float(o.max())

    def classify_vehicles(toks: np.ndarray) -> tuple[str, float]:
        o = np.asarray(vehicles(jnp.asarray(toks)[None]))[0]
        return f"vehicle-{int(o.argmax()) % 3}", float(o.max())

    fl = Dataflow([("clip", np.ndarray)])
    d = fl.map(detect, names=["toks"])
    a = d.map(classify_people, names=["label", "conf"])
    b = d.map(classify_vehicles, names=["label", "conf"])
    fl.output = a.union(b).groupby("label").agg("count", "label")

    rt = Runtime(n_cpu=4, net=NetModel())
    fl.deploy(rt, fusion=True)
    rng = np.random.default_rng(0)
    lats = []
    for i in range(6):
        t0 = time.perf_counter()
        out = fl.execute(Table([("clip", np.ndarray)],
                               [(rng.random(30 * 64),)])).result(60)
        lats.append(time.perf_counter() - t0)
        print(f"clip {i}: {out.to_dicts()} ({lats[-1]*1e3:.1f} ms)")
    med = sorted(lats)[len(lats) // 2]
    print(f"median {med*1e3:.1f} ms -> "
          f"{'REAL-TIME (<1s/clip)' if med < 1.0 else 'over budget'}")
    rt.stop()


if __name__ == "__main__":
    main()
