"""Real-time video analysis pipeline (paper §5.2 Video Streams), on the
compiled serving path.

    frames -> detector (a registry VLM as a ``ModelOp``)
           -> {people head, vehicles head} in parallel (fused, lowered
              to batched XLA chains)
           -> union -> groupby(label) -> count

The detector is a real model wrapped as a first-class plan operator
(``model_stage_op``), so the SLO controller plans against its *measured*
cost curve; the classifier heads are two-step GPU chains the compiler
fuses and lowers to one vmapped XLA dispatch per batch.

  PYTHONPATH=src python examples/video_pipeline.py
"""
import time
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_tiny_config
from repro.core.compiler import compile_flow
from repro.core.dataflow import Dataflow
from repro.core.table import Table
from repro.models import build_model
from repro.models.registry import model_stage_op
from repro.profiling.controller import SLOController
from repro.profiling.profiler import profile_plan, seed_from_model_ops
from repro.runtime import NetModel, Runtime

SEQ = 16


def build_flow():
    """The video Dataflow (detector ModelOp + two classifier heads)."""
    cfg = get_tiny_config("llama-3.2-vision-11b")   # detector stand-in
    detector = build_model(cfg)
    params = detector.init(jax.random.PRNGKey(0))
    det_op = model_stage_op(detector, params, "logits",
                            model_name="detector", seq_len=SEQ)
    v = cfg.vocab_size
    kp, kv_ = jax.random.split(jax.random.PRNGKey(1))
    w_people = jax.random.normal(kp, (v, 8), jnp.float32) * 0.1
    w_vehicle = jax.random.normal(kv_, (v, 8), jnp.float32) * 0.1

    def people_proj(det: jax.Array) -> jax.Array:
        return det.astype(jnp.float32) @ w_people

    def vehicle_proj(det: jax.Array) -> jax.Array:
        return det.astype(jnp.float32) @ w_vehicle

    def score(h: jax.Array) -> jax.Array:
        return jax.nn.softmax(h)

    def label_people(s: jax.Array) -> Tuple[str, float]:
        return f"person-{int(np.argmax(s)) % 3}", float(np.max(s))

    def label_vehicle(s: jax.Array) -> Tuple[str, float]:
        return f"vehicle-{int(np.argmax(s)) % 3}", float(np.max(s))

    def gate(tokens: jax.Array) -> jax.Array:
        return jnp.clip(tokens, 0, v - 1)

    fl = Dataflow([("tokens", jax.Array)])
    # gate fuses with the detector ModelOp into one lowered chain, so the
    # detector serves batches as a single XLA dispatch (native batch via
    # the ModelOp's custom_vmap rule)
    det = fl.map(gate, names=["tokens"], gpu=True).apply_op(det_op,
                                                            gpu=True)
    pa = det.map(people_proj, names=["h"], gpu=True).map(
        score, names=["s"], gpu=True)
    pb = det.map(vehicle_proj, names=["h"], gpu=True).map(
        score, names=["s"], gpu=True)
    la = pa.map(label_people, names=["label", "conf"])
    lb = pb.map(label_vehicle, names=["label", "conf"])
    fl.output = la.union(lb).groupby("label").agg("count", "label")
    return fl


def build(rt, *, name="video"):
    """Compile the pipeline onto ``rt``; returns the deployed flow."""
    return compile_flow(build_flow(), rt, fusion=True, name=name)


def _frame(rng, v=500):
    return (jnp.asarray(rng.integers(0, v, SEQ), jnp.int32),)


def check_flows():
    """Static-verifier hook (``python -m repro.check``)."""
    rng = np.random.default_rng(0)
    return [{"name": "video", "flow": build_flow(),
             "compile": {"fusion": True},
             "sample": Table([("tokens", jax.Array)], [_frame(rng)])}]


def run(frames: int = 4, *, controller: bool = True, verbose: bool = False):
    """Headless run; returns a metrics dict (used by the smoke test)."""
    rt = Runtime(n_cpu=4, n_gpu=1, net=NetModel(scale=0.0))
    try:
        dep = build(rt)
        rng = np.random.default_rng(0)
        profile = None
        if controller:
            # build the controller's model BEFORE traffic (so the tick
            # sees a fresh arrival window): ModelOp-measured curves for
            # the detector chain, a quick sweep for everything else
            profile = seed_from_model_ops(dep.plan, batch_sizes=(1, 2, 4))
            sample = Table([("tokens", jax.Array)], [_frame(rng)])
            swept = profile_plan(dep.plan, sample, batch_sizes=(1, 2),
                                 runs=1, warmup=1)
            for k, c in swept.curves.items():
                profile.curves.setdefault(k, c)
        lats, counts = [], []
        for i in range(frames):
            t0 = time.perf_counter()
            out = dep.execute(Table([("tokens", jax.Array)],
                                    [_frame(rng)])).result(60)
            lats.append(time.perf_counter() - t0)
            counts.append(out.to_dicts())
            if verbose:
                print(f"frame {i}: {counts[-1]} ({lats[-1] * 1e3:.1f} ms)")
        med = sorted(lats)[len(lats) // 2]
        result = {"frames": frames, "median_ms": med * 1e3,
                  "p99_ms": max(lats) * 1e3,
                  "labels_per_frame": len(counts[-1])}
        if controller:
            ctl = SLOController(rt, dep, slo_p99_s=0.5, profile=profile,
                                replan_cooldown_s=1e9)
            ev = ctl.tick()
            result["controller"] = ev.kind
            if verbose:
                print(f"controller tick: {ev.kind} {ev.detail}")
        return result
    finally:
        rt.stop()


def main():
    r = run(frames=6, verbose=True)
    rt_ok = r["median_ms"] < 1000.0
    print(f"median {r['median_ms']:.1f} ms -> "
          f"{'REAL-TIME (<1s/frame)' if rt_ok else 'over budget'}")


if __name__ == "__main__":
    main()
