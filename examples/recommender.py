"""Recommender pipeline (paper §5.2, Facebook-style): request -> category
from recent clicks -> KVS lookup of the (large) product-category matrix ->
top-k scoring.  Demonstrates the locality optimization: with fusion +
dynamic dispatch the scoring lands on the executor caching the category.

  PYTHONPATH=src python examples/recommender.py
"""
import time

import numpy as np

from repro.core.dataflow import Dataflow
from repro.core.table import Table
from repro.runtime import NetModel, Runtime

N_CATEGORIES = 8
PRODUCTS = 4096
DIM = 64


def build_flow():
    def categorize(user: int, clicks: int) -> tuple[int, str]:
        return user, f"cat{clicks % N_CATEGORIES}"

    def score(user: int, cat: str, lookup) -> tuple[int, float]:
        uvec = np.random.default_rng(user).random(DIM)
        scores = lookup @ uvec
        top = int(np.argmax(scores))
        return top, float(scores[top])

    fl = Dataflow([("user", int), ("clicks", int)])
    lk = fl.map(categorize, names=["user", "cat"]).lookup("cat", column=True)
    fl.output = lk.map(score, names=["product", "score"])
    return fl


def check_flows():
    """Static-verifier hook (``python -m repro.check``): lint both the
    optimized (fusion + locality) and per-stage deployments."""
    sample = Table([("user", int), ("clicks", int)], [(1, 7)])
    return [{"name": "recommender", "flow": build_flow(),
             "compile": {"fusion": True, "locality": True},
             "sample": sample},
            {"name": "recommender-unopt", "flow": build_flow(),
             "compile": {}, "sample": sample}]


def run(optimized: bool):
    rt = Runtime(n_cpu=4, net=NetModel(latency_s=0.5e-3, bandwidth=1e9))
    try:
        cat = np.random.default_rng(0).random((PRODUCTS, DIM))  # ~2MB each
        for i in range(N_CATEGORIES):
            rt.kvs.put(f"cat{i}", cat, charge=False)
        fl = build_flow()
        fl.deploy(rt, fusion=optimized, locality=optimized)
        reqs = [Table([("user", int), ("clicks", int)], [(u, u * 7)])
                for u in range(16)]
        for t in reqs:   # warm caches
            fl.execute(t).result(60)
        lats = []
        for t in reqs:
            t0 = time.perf_counter()
            out = fl.execute(t).result(60)
            lats.append(time.perf_counter() - t0)
        return sorted(lats)[len(lats) // 2], out.to_dicts()[0]
    finally:
        rt.stop()


def main():
    naive, sample = run(optimized=False)
    opt, _ = run(optimized=True)
    print(f"sample recommendation: {sample}")
    print(f"median latency naive:            {naive*1e3:7.2f} ms")
    print(f"median latency fusion+dispatch:  {opt*1e3:7.2f} ms")
    print(f"locality speedup: {naive/opt:.2f}x (paper: ~2x vs Sagemaker)")


if __name__ == "__main__":
    main()
