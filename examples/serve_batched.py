"""End-to-end SERVING driver: a small model served with batched requests
through the full stack — Cloudflow dataflow -> serverless runtime with the
batching executor -> jitted prefill+decode engine with KV cache.

  PYTHONPATH=src python examples/serve_batched.py --requests 12
"""
import argparse
import time

from repro.core.table import Table
from repro.launch.serve import build_flow
from repro.runtime import NetModel, Runtime


def check_flows():
    """Static-verifier hook (``python -m repro.check``)."""
    flow, _engine = build_flow("yi-9b", max_new_tokens=2, batching=True)
    return [{"name": "serve-batched", "flow": flow,
             "compile": {"fusion": False},
             "sample": Table([("text", str)], [("request 0",)])}]


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="yi-9b")
    p.add_argument("--requests", type=int, default=12)
    p.add_argument("--new-tokens", type=int, default=8)
    args = p.parse_args()

    flow, engine = build_flow(args.arch, max_new_tokens=args.new_tokens,
                              batching=True)
    rt = Runtime(n_cpu=2, net=NetModel(scale=0.0), max_batch=8,
                 batch_wait_ms=20.0)
    flow.deploy(rt, fusion=False)

    t0 = time.perf_counter()
    futs = [flow.execute(Table([("text", str)], [(f"request {i}",)]))
            for i in range(args.requests)]
    for i, f in enumerate(futs):
        out = f.result(timeout=300)
        print(f"req {i:2d} -> {out.to_dicts()[0]['completion']}")
    wall = time.perf_counter() - t0
    sizes = [b.batch_sizes for b in rt._batchers.values()]
    print(f"{args.requests} generations ({args.new_tokens} tokens each) "
          f"in {wall:.2f}s = {args.requests/wall:.2f} req/s; "
          f"batch sizes: {sizes}")
    rt.stop()


if __name__ == "__main__":
    main()
