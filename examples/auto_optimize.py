"""Automated optimization selection (paper §7 'Future Work', implemented).

The planner profiles the pipeline on a sample request (per-operator latency
mean/CV + payload sizes) and chooses fusion / competitive execution /
locality automatically — no manual optimization flags.

  PYTHONPATH=src python examples/auto_optimize.py
"""
import random
import time

import numpy as np

from repro.core.dataflow import Dataflow
from repro.core.planner import auto_deploy
from repro.core.table import Table
from repro.runtime import NetModel, Runtime


def build_flow():
    rng = random.Random(0)

    def preproc(x: np.ndarray) -> np.ndarray:
        return x * 2.0                       # cheap, big payload -> fuse

    def jittery_model(x: np.ndarray) -> tuple[float, float]:
        time.sleep(rng.choice([0.002, 0.002, 0.04]))   # heavy tail
        return float(x.mean()), 0.9

    def postproc(mean: float, conf: float) -> str:
        return f"label-{int(mean * 10) % 5}"

    fl = Dataflow([("x", np.ndarray)])
    fl.output = (fl.map(preproc, names=["x"])
                 .map(jittery_model, names=["mean", "conf"])
                 .map(postproc, names=["label"]))
    return fl


def check_flows():
    """Static-verifier hook (``python -m repro.check``): lint under the
    planner's richest flag set (fusion on)."""
    return [{"name": "auto-optimize", "flow": build_flow(),
             "compile": {"fusion": True},
             "sample": Table([("x", np.ndarray)], [(np.ones(1024),)])}]


def main():
    fl = build_flow()
    rt = Runtime(n_cpu=8, net=NetModel())
    sample = Table([("x", np.ndarray)], [(np.ones(64 * 1024),)])

    deployed, plan = auto_deploy(fl, rt, sample, runs=6)
    print("planner decisions:")
    for note in plan.notes:
        print("  -", note)
    print("  flags:", plan.flags)

    lats = []
    for i in range(10):
        t0 = time.perf_counter()
        out = deployed.execute(sample).result(timeout=30)
        lats.append(time.perf_counter() - t0)
    lats.sort()
    print(f"result: {out.to_dicts()[0]}")
    print(f"median {lats[len(lats)//2]*1e3:.1f} ms / "
          f"p90 {lats[int(len(lats)*0.9)]*1e3:.1f} ms over 10 requests")
    rt.stop()


if __name__ == "__main__":
    main()
