"""End-to-end TRAINING driver: train a ~100M-param llama-style model for a
few hundred steps on the synthetic-motif LM task and assert the loss drops
well below the random floor.  Exercises data pipeline -> train_step (remat,
grad clip) -> AdamW -> checkpointing -> restore.

  PYTHONPATH=src python examples/train_small.py --steps 200
(defaults are sized for this 1-core CPU container: ~100M params via a
reduced depth/width; pass --d-model 768 --layers 12 for the full 100M.)
"""
import argparse
import dataclasses
import os
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.training import checkpoint, optim
from repro.training.data import DataConfig, SyntheticLM
from repro.training.train_step import init_train_state, make_train_step


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--d-model", type=int, default=256)
    p.add_argument("--layers", type=int, default=4)
    p.add_argument("--batch-size", type=int, default=8)
    p.add_argument("--seq-len", type=int, default=64)
    p.add_argument("--vocab", type=int, default=2048)
    p.add_argument("--lr", type=float, default=3e-3)
    args = p.parse_args()

    base = get_config("yi-9b")
    cfg = dataclasses.replace(
        base, name="yi-small", num_layers=args.layers, d_model=args.d_model,
        num_heads=max(4, args.d_model // 64), num_kv_heads=2, head_dim=64,
        d_ff=args.d_model * 3, vocab_size=args.vocab)
    model = build_model(cfg)
    n = cfg.param_count()
    print(f"model: {cfg.name} {n/1e6:.1f}M params "
          f"({cfg.num_layers}L d{cfg.d_model})")

    opt = optim.OptConfig(lr=args.lr, warmup_steps=30)
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq_len,
                                  batch_size=args.batch_size, seed=0,
                                  num_motifs=16))
    losses = []
    t0 = time.time()
    ckpt_dir = os.path.join(tempfile.gettempdir(), "repro_train_small")
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch().items()}
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {losses[-1]:.4f} "
                  f"({(time.time()-t0)/(i+1):.2f} s/step)")
        if i == args.steps // 2:
            checkpoint.save(ckpt_dir, state, i)
    # restore check
    restored = checkpoint.restore(ckpt_dir, state)
    assert jax.tree.all(jax.tree.map(
        lambda a, b: a.shape == b.shape, state, restored))
    import math
    floor = math.log(cfg.vocab_size)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(uniform floor {floor:.2f})")
    assert losses[-1] < losses[0] - 1.0, "training did not learn"
    print("OK: model learned the synthetic distribution; checkpoint "
          f"round-trip at {ckpt_dir}")


if __name__ == "__main__":
    main()
