"""Transformer prefill -> decode cascade on the compiled serving path.

A registry transformer's serving stages become first-class plan operators
(``model_stage_op``): ``prefill`` turns a prompt row into greedy-decode
state (next token, position, per-row KV cache columns) and each ``decode``
step advances it.  The compiler fuses the whole cascade into ONE
device-resident batched chain — the KV cache never leaves the device
between steps, and a whole batch of prompts runs each fused step as a
single XLA dispatch (the ModelOp's ``custom_vmap`` rule maps the row axis
onto the model's native batch dimension).

  PYTHONPATH=src python examples/decode_cascade.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_tiny_config
from repro.core.compiler import compile_flow
from repro.core.dataflow import Dataflow
from repro.core.table import Table
from repro.models import build_model
from repro.models.registry import model_stage_op
from repro.runtime import NetModel, Runtime

ARCH = "yi-9b"
SEQ = 16
CACHE = 32
STEPS = 4


def build_ops(*, arch=ARCH, seq_len=SEQ, cache_len=CACHE, measure=True):
    """(model, params, prefill op, decode op).  The decode op is ONE
    instance reused at every cascade position, so recompiles share step
    function identity (stable chain signatures -> zero retraces)."""
    cfg = get_tiny_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pre = model_stage_op(model, params, "prefill", model_name=arch,
                         seq_len=seq_len, cache_len=cache_len,
                         measure=measure)
    dec = model_stage_op(model, params, "decode", model_name=arch,
                         seq_len=seq_len, cache_len=cache_len,
                         measure=measure)
    return model, params, pre, dec


def build_flow(pre, dec, *, steps=STEPS):
    fl = Dataflow([("tokens", jax.Array)])
    node = fl.apply_op(pre, gpu=True)
    for _ in range(steps):
        node = node.apply_op(dec, gpu=True)
    fl.output = node
    return fl


def build(rt, pre, dec, *, steps=STEPS, name="decode-cascade"):
    return compile_flow(build_flow(pre, dec, steps=steps), rt,
                        fusion=True, name=name)


def check_flows():
    """Static-verifier hook (``python -m repro.check``)."""
    from repro.models.registry import stage_input_specs
    model, _params, pre, dec = build_ops(measure=False)
    return [{"name": "decode-cascade", "flow": build_flow(pre, dec),
             "compile": {"fusion": True},
             "input_specs": stage_input_specs(model, "prefill",
                                              seq_len=SEQ,
                                              cache_len=CACHE)}]


def reference_decode(model, params, toks, *, steps=STEPS, cache_len=CACHE):
    """Plain model loop (the unfused oracle): greedy tokens after
    prefill + ``steps`` decode steps."""
    logits, cache = model.prefill(params, {"tokens": toks}, cache_len)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    pos = jnp.full(toks.shape[:1], toks.shape[1], jnp.int32)
    for _ in range(steps):
        lg, cache = model.decode_step(params, tok[:, None], pos, cache)
        tok = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)
        pos = pos + 1
    return [int(x) for x in tok]


def run(prompts: int = 3, *, steps: int = STEPS, verbose: bool = False):
    """Headless run; returns a metrics dict (used by the smoke test)."""
    rt = Runtime(n_cpu=2, n_gpu=1, net=NetModel(scale=0.0))
    try:
        model, params, pre, dec = build_ops(measure=False)
        dep = build(rt, pre, dec, steps=steps)
        cfg = model.cfg
        toks = jax.random.randint(jax.random.PRNGKey(1), (prompts, SEQ),
                                  0, cfg.vocab_size)
        table = Table([("tokens", jax.Array)],
                      [(toks[i],) for i in range(prompts)])
        lats, out = [], None
        for _ in range(3):
            t0 = time.perf_counter()
            out = dep.execute(table).result(120)
            lats.append(time.perf_counter() - t0)
        got = [int(r.values[0]) for r in out.rows]
        want = reference_decode(model, params, toks, steps=steps)
        if verbose:
            print(f"fused cascade tokens:  {got}")
            print(f"reference loop tokens: {want}")
            print(f"latency: first {lats[0] * 1e3:.1f} ms, "
                  f"steady {min(lats) * 1e3:.1f} ms")
        return {"prompts": prompts, "steps": steps,
                "tokens_match": got == want,
                "first_ms": lats[0] * 1e3, "steady_ms": min(lats) * 1e3}
    finally:
        rt.stop()


def main():
    r = run(verbose=True)
    print("PARITY OK" if r["tokens_match"] else "PARITY FAILED")


if __name__ == "__main__":
    main()
