"""Model cascade (paper §3.2 Fig 3 / §5.2 image cascade).

A cheap model answers first; low-confidence inputs escalate to a larger
model; a left join merges both paths.  Shows the fusion rewrite collapsing
the chain and the cascade skipping the expensive model when confident.

  PYTHONPATH=src python examples/image_cascade.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_tiny_config
from repro.core.dataflow import Dataflow
from repro.core.table import Table
from repro.models import build_model
from repro.runtime import NetModel, Runtime

THRESHOLD = 0.5


def load(arch, seed, temp):
    cfg = get_tiny_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))

    @jax.jit
    def fwd(tokens):
        logits, _ = model.logits(params, {"tokens": tokens}, remat=False)
        return jax.nn.softmax(logits[:, -1] / temp)

    return fwd


def main():
    simple_fwd = load("yi-9b", 0, temp=1.0)
    complex_fwd = load("granite-34b", 1, temp=0.05)  # sharp => confident

    def preproc(img: np.ndarray) -> np.ndarray:
        return (img[:16] * 255).astype(np.int32) % 500

    def simple(tokens: np.ndarray) -> tuple[np.ndarray, str, float]:
        p = np.asarray(simple_fwd(jnp.asarray(tokens)[None]))[0]
        return tokens, f"class{int(p.argmax())}", float(p.max())

    def low_confidence(tokens: np.ndarray, label: str, conf: float) -> bool:
        return conf < THRESHOLD

    def complex_model(tokens: np.ndarray, label: str,
                      conf: float) -> tuple[str, float]:
        p = np.asarray(complex_fwd(jnp.asarray(tokens)[None]))[0]
        return f"class{int(p.argmax())}", float(p.max())

    def best(tokens: np.ndarray, label: str, conf: float, clabel: str,
             cconf: float) -> tuple[str, float]:
        if clabel is not None and cconf > conf:
            return clabel, cconf
        return label, conf

    fl = Dataflow([("img", np.ndarray)])
    s = fl.map(preproc, names=["tokens"]).map(
        simple, names=["tokens", "label", "conf"])
    c = s.filter(low_confidence).map(complex_model, names=["clabel",
                                                           "cconf"])
    fl.output = s.join(c, how="left").map(best, names=["label", "conf"])

    rt = Runtime(n_cpu=4, net=NetModel(scale=0.0))
    fl.deploy(rt, fusion=True)
    rng = np.random.default_rng(0)
    escalated = 0
    for i in range(6):
        t0 = time.perf_counter()
        out = fl.execute(Table([("img", np.ndarray)],
                               [(rng.random(64),)])).result(60)
        d = out.to_dicts()[0]
        esc = d["conf"] >= THRESHOLD and "granite" or "yi"
        escalated += d["conf"] >= THRESHOLD
        print(f"img{i}: {d['label']} conf={d['conf']:.2f} "
              f"({(time.perf_counter()-t0)*1e3:.1f} ms)")
    rt.stop()
    print(f"cascade escalated on low confidence; threshold={THRESHOLD}")


if __name__ == "__main__":
    main()
