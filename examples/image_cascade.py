"""Model cascade (paper §3.2 Fig 3 / §5.2 image cascade), on the compiled
serving path.

A cheap model answers first; low-confidence rows escalate to a larger
model; a left join merges both paths.  The escalation branch is a GPU
``filter -> map`` chain the compiler fuses and lowers with the filter
evaluated *inside* the jitted body (masked rows compact only at the
device->host boundary), so the cascade's branch decision costs no extra
dispatch.

  PYTHONPATH=src python examples/image_cascade.py
"""
import time
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_tiny_config
from repro.core.compiler import compile_flow
from repro.core.dataflow import Dataflow
from repro.core.table import Table
from repro.models import build_model
from repro.runtime import NetModel, Runtime

THRESHOLD = 0.5
SEQ = 16


def _forward(arch, seed, temp):
    """Per-row forward closure (tokens [S] -> class probs [V]) over a
    built registry model — pure jnp, so it vmaps inside lowered chains."""
    cfg = get_tiny_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))

    def probs(tokens):
        logits, _ = model.logits(params, {"tokens": tokens[None]},
                                 remat=False)
        return jax.nn.softmax(logits[0, -1].astype(jnp.float32) / temp)

    return probs, cfg.vocab_size


def build_flow(simple_fwd, complex_fwd, v):
    """The cascade Dataflow over the given per-row forward closures."""
    def gate(tokens: jax.Array) -> jax.Array:
        return jnp.clip(tokens, 0, v - 1)

    def simple(tokens: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
        p = simple_fwd(tokens)
        return tokens, jnp.argmax(p).astype(jnp.int32), jnp.max(p)

    def low_confidence(tokens: jax.Array, idx: jax.Array,
                       conf: jax.Array) -> bool:
        return conf < THRESHOLD

    def complex_model(tokens: jax.Array, idx: jax.Array,
                      conf: jax.Array) -> Tuple[jax.Array, jax.Array]:
        p = complex_fwd(tokens)
        return jnp.argmax(p).astype(jnp.int32), jnp.max(p)

    def lab_simple(tokens: jax.Array, idx: jax.Array,
                   conf: jax.Array) -> Tuple[str, float]:
        return f"class{int(idx)}", float(conf)

    def lab_complex(cidx: jax.Array, cconf: jax.Array) -> Tuple[str, float]:
        return f"class{int(cidx)}", float(cconf)

    def best(label: str, conf: float, clabel: str,
             cconf: float) -> Tuple[str, float]:
        if clabel is not None and cconf > conf:
            return clabel, cconf
        return label, conf

    fl = Dataflow([("tokens", jax.Array)])
    s = fl.map(gate, names=["tokens"], gpu=True).map(
        simple, names=["tokens", "idx", "conf"], gpu=True)
    c = s.filter(low_confidence, gpu=True).map(
        complex_model, names=["cidx", "cconf"], gpu=True)
    slab = s.map(lab_simple, names=["label", "conf"])
    clab = c.map(lab_complex, names=["clabel", "cconf"])
    fl.output = slab.join(clab, how="left").map(best,
                                                names=["label", "conf"])
    return fl


def build(rt, *, name="cascade"):
    simple_fwd, v = _forward("yi-9b", 0, temp=1.0)
    complex_fwd, _ = _forward("granite-34b", 1, temp=0.05)  # sharp
    return compile_flow(build_flow(simple_fwd, complex_fwd, v), rt,
                        fusion=True, name=name)


def check_flows():
    """Static-verifier hook (``python -m repro.check``): one tiny model
    stands in for both cascade stages — the flow shape is identical."""
    fwd, v = _forward("yi-9b", 0, temp=1.0)
    toks = jnp.zeros((SEQ,), jnp.int32)
    return [{"name": "cascade", "flow": build_flow(fwd, fwd, v),
             "compile": {"fusion": True},
             "sample": Table([("tokens", jax.Array)], [(toks,)])}]


def run(images: int = 6, *, verbose: bool = False):
    """Headless run; returns a metrics dict (used by the smoke test)."""
    rt = Runtime(n_cpu=4, n_gpu=1, net=NetModel(scale=0.0))
    try:
        dep = build(rt)
        rng = np.random.default_rng(0)
        escalated, labels, lats = 0, [], []
        for i in range(images):
            toks = jnp.asarray(rng.integers(0, 500, SEQ), jnp.int32)
            t0 = time.perf_counter()
            out = dep.execute(Table([("tokens", jax.Array)],
                                    [(toks,)])).result(60)
            lats.append(time.perf_counter() - t0)
            d = out.to_dicts()[0]
            labels.append(d["label"])
            escalated += d["conf"] >= THRESHOLD
            if verbose:
                print(f"img{i}: {d['label']} conf={d['conf']:.2f} "
                      f"({lats[-1] * 1e3:.1f} ms)")
        return {"images": images, "escalated": int(escalated),
                "labels": labels,
                "median_ms": sorted(lats)[len(lats) // 2] * 1e3}
    finally:
        rt.stop()


def main():
    r = run(verbose=True)
    print(f"cascade: {r['escalated']}/{r['images']} answered confidently; "
          f"threshold={THRESHOLD}, median {r['median_ms']:.1f} ms")


if __name__ == "__main__":
    main()
