"""Quickstart: the paper's Figure-1 ensemble in ~25 lines.

An input is preprocessed, scored by three (tiny zoo) models in parallel,
and the most confident prediction wins — deployed on the serverless runtime
with operator fusion enabled.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_tiny_config
from repro.core.dataflow import Dataflow
from repro.core.table import Table
from repro.models import build_model
from repro.runtime import NetModel, Runtime


def load_model(arch: str, seed: int):
    cfg = get_tiny_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))

    @jax.jit
    def forward(tokens):
        logits, _ = model.logits(params, {"tokens": tokens}, remat=False)
        return jax.nn.softmax(logits[:, -1])

    def predict(tokens: np.ndarray) -> tuple[str, float]:
        probs = np.asarray(forward(jnp.asarray(tokens)[None]))[0]
        return f"{arch}:class{int(probs.argmax())}", float(probs.max())

    return predict


def build_flow(models):
    """The Figure-1 ensemble dataflow over the given predict closures."""
    def preproc(url: str) -> np.ndarray:
        return (np.frombuffer(url.encode()[:16].ljust(16), np.uint8)
                .astype(np.int32) % 500)

    fl = Dataflow([("url", str)])
    img = fl.map(preproc, names=["tokens"])
    preds = [img.map(m, names=["label", "conf"]) for m in models]
    fl.output = preds[0].union(*preds[1:]).agg("max", "conf")
    return fl


def check_flows():
    """Static-verifier hook (``python -m repro.check``): lint the real
    flow shape; one tiny model stands in for all three ensemble heads."""
    m = load_model("yi-9b", 0)
    return [{"name": "quickstart", "flow": build_flow([m, m, m]),
             "compile": {"fusion": True},
             "sample": Table([("url", str)], [("img://cat.jpg",)])}]


def main():
    fl = build_flow([load_model("yi-9b", 0), load_model("glm4-9b", 1),
                     load_model("gemma2-9b", 2)])

    rt = Runtime(n_cpu=4, net=NetModel(scale=0.0))
    fl.deploy(rt, fusion=True)
    for url in ("img://cat.jpg", "img://dog.jpg"):
        result = fl.execute(Table([("url", str)], [(url,)])).result(30)
        print(url, "->", result.to_dicts()[0])
    rt.stop()


if __name__ == "__main__":
    main()
