"""Runtime failure handling + additional property coverage."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.dataflow import Dataflow
from repro.core.table import Table
from repro.models import layers
from repro.runtime.netmodel import NetModel
from repro.runtime.runtime import Runtime


def test_operator_exception_propagates_to_future():
    def boom(x: int) -> int:
        raise ValueError("kaboom")
    fl = Dataflow([("x", int)])
    fl.output = fl.map(boom, names=["x"])
    rt = Runtime(n_cpu=2, net=NetModel(scale=0.0))
    try:
        fl.deploy(rt)
        fut = fl.execute(Table([("x", int)], [(1,)]))
        with pytest.raises(ValueError, match="kaboom"):
            fut.result(timeout=10)
    finally:
        rt.stop()


def test_runtime_type_error_propagates():
    def lies(x: int) -> int:
        return "not an int"  # type: ignore
    fl = Dataflow([("x", int)])
    fl.output = fl.map(lies, names=["x"])
    rt = Runtime(n_cpu=2, net=NetModel(scale=0.0))
    try:
        fl.deploy(rt)
        from repro.core.operators import TypecheckError
        with pytest.raises(TypecheckError):
            fl.execute(Table([("x", int)], [(1,)])).result(timeout=10)
    finally:
        rt.stop()


def test_concurrent_requests_isolated():
    """Many in-flight requests must not cross-contaminate results."""
    def double(x: int) -> int:
        time.sleep(0.002)
        return x * 2
    fl = Dataflow([("x", int)])
    fl.output = fl.map(double, names=["x"]).map(double, names=["x"])
    rt = Runtime(n_cpu=4, net=NetModel(scale=0.0))
    try:
        fl.deploy(rt, fusion=False)   # separate stages, shared executors
        futs = [(i, fl.execute(Table([("x", int)], [(i,)])))
                for i in range(24)]
        for i, f in futs:
            assert f.result(timeout=20).rows[0].values[0] == 4 * i
    finally:
        rt.stop()


@given(st.integers(2, 64), st.integers(1, 4), st.integers(1, 4))
@settings(max_examples=12, deadline=None)
def test_triangle_attention_property(chunks, heads, kv_heads):
    """Triangle-pair attention equals full chunked attention for any
    chunk count / GQA grouping (hypothesis sweep)."""
    if heads % kv_heads:
        heads = kv_heads * max(1, heads // kv_heads)
    c = 8
    S = chunks * c
    if S > 256:
        S, chunks = 256, 256 // c
    key = jax.random.PRNGKey(chunks * 131 + heads)
    q = jax.random.normal(key, (1, S, heads, 16)) * 0.5
    k = jax.random.normal(jax.random.PRNGKey(1), (1, S, kv_heads, 16)) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(2), (1, S, kv_heads, 16)) * 0.5
    pos = jnp.arange(S, dtype=jnp.int32)
    a = layers.chunked_attention(q, k, v, q_positions=pos, k_positions=pos,
                                 causal=True, chunk_q=c, chunk_k=c)
    b = layers.chunked_attention_causal_skip(q, k, v, q_positions=pos,
                                             k_positions=pos, chunk=c)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=1,
                max_size=64))
@settings(max_examples=25, deadline=None)
def test_kv_quantization_bounded_error(vals):
    """int8 KV quantization error is bounded by scale/2 per element."""
    x = jnp.asarray(vals, jnp.float32).reshape(1, -1)
    q, s = layers.kv_quantize(x)
    back = layers.kv_dequantize(q, s, jnp.float32)
    err = np.max(np.abs(np.asarray(back - x)))
    bound = float(np.max(np.asarray(s))) * 0.51 + 1e-6
    assert err <= bound
