import pytest

from repro.core import operators as ops
from repro.core.table import Table
from repro.core.operators import TypecheckError


def tbl(*rows):
    return Table([("a", int), ("b", float)], rows)


def test_map_schema_from_annotations():
    def f(a: int, b: float) -> tuple[int, float]:
        return a + 1, b * 2
    m = ops.Map(f, names=["x", "y"])
    out = m.apply([tbl((1, 2.0), (3, 4.0))])
    assert out.columns == ["x", "y"]
    assert out.to_dicts() == [{"x": 2, "y": 4.0}, {"x": 4, "y": 8.0}]


def test_map_requires_return_annotation():
    def f(a, b):
        return a
    with pytest.raises(TypecheckError):
        ops.Map(f)


def test_map_runtime_type_error():
    def f(a: int, b: float) -> int:
        return "oops"  # type: ignore
    m = ops.Map(f)
    with pytest.raises(TypecheckError):
        m.apply([tbl((1, 2.0))])


def test_map_deploy_time_arity_check():
    def f(a: int) -> int:
        return a
    m = ops.Map(f)
    with pytest.raises(TypecheckError):
        m.out_schema([[("a", int), ("b", float)]])


def test_filter_keeps_matching():
    def f(a: int, b: float) -> bool:
        return a > 1
    out = ops.Filter(f).apply([tbl((1, 1.0), (2, 2.0), (3, 3.0))])
    assert [r.values[0] for r in out.rows] == [2, 3]


def test_filter_nonbool_raises():
    def f(a: int, b: float) -> bool:
        return 1  # type: ignore
    with pytest.raises(TypecheckError):
        ops.Filter(f).apply([tbl((1, 1.0))])


def test_groupby_and_agg():
    t = Table([("k", str), ("v", int)],
              [("x", 1), ("x", 3), ("y", 5)])
    g = ops.GroupBy("k").apply([t])
    assert g.grouping == "k"
    for fn, expect in [("count", {"x": 2, "y": 1}),
                       ("sum", {"x": 4, "y": 5}),
                       ("min", {"x": 1, "y": 5}),
                       ("max", {"x": 3, "y": 5}),
                       ("avg", {"x": 2.0, "y": 5.0})]:
        out = ops.Agg(fn, "v").apply([g])
        got = {r.values[0]: r.values[1] for r in out.rows}
        assert got == expect, fn


def test_agg_ungrouped_single_row():
    t = Table([("k", str), ("v", int)], [("x", 1), ("y", 3)])
    out = ops.Agg("sum", "v").apply([t])
    assert len(out) == 1 and out.rows[0].values[1] == 4


def test_agg_bad_fn():
    with pytest.raises(TypecheckError):
        ops.Agg("median", "v")


def test_join_on_row_id():
    left = Table([("a", int)])
    right = Table([("b", str)])
    r1 = left.insert((1,))
    r2 = left.insert((2,))
    right.insert(ops.Row(("x",), r1.row_id))
    out = ops.Join().apply([left, right])
    assert len(out) == 1
    assert out.rows[0].values == (1, "x")


def test_left_and_outer_join():
    left = Table([("k", int), ("l", str)], [(1, "a"), (2, "b")])
    right = Table([("k", int), ("r", str)], [(1, "x"), (3, "z")])
    lj = ops.Join(key="k", how="left").apply([left, right])
    assert len(lj) == 2
    oj = ops.Join(key="k", how="outer").apply([left, right])
    assert len(oj) == 3


def test_join_rejects_grouped():
    with pytest.raises(TypecheckError):
        ops.Join().out_grouping(["k", None])


def test_union_and_anyof():
    a = tbl((1, 1.0))
    b = tbl((2, 2.0))
    u = ops.Union().apply([a, b])
    assert len(u) == 2
    any_ = ops.AnyOf().apply([None, b])
    assert any_ is b


def test_union_schema_mismatch():
    with pytest.raises(TypecheckError):
        ops.Union().out_schema([[("a", int)], [("a", str)]])


def test_fuse_chain_semantics():
    def f(a: int, b: float) -> tuple[int, float]:
        return a * 2, b
    def g(a: int, b: float) -> bool:
        return a > 2
    fuse = ops.Fuse([ops.Map(f, names=["a", "b"]), ops.Filter(g)])
    out = fuse.apply([tbl((1, 0.0), (2, 0.0))])
    assert [r.values[0] for r in out.rows] == [4]
    assert fuse.out_schema([[("a", int), ("b", float)]]) == [
        ("a", int), ("b", float)]


class _Ctx:
    def __init__(self, store):
        self.kvs = store
        self._store = store

    def kvs_get(self, key):
        return self._store[key]


def test_lookup_constant_and_column():
    t = Table([("key", str)], [("k1",), ("k2",)])
    ctx = _Ctx({"k1": 10, "k2": 20, "c": 99})
    out = ops.Lookup("key", is_column=True).apply([t], ctx)
    assert [r.values[-1] for r in out.rows] == [10, 20]
    out = ops.Lookup("c").apply([t], ctx)
    assert [r.values[-1] for r in out.rows] == [99, 99]
    with pytest.raises(RuntimeError):
        ops.Lookup("c").apply([t], None)
