"""Hypothesis property tests on system invariants.

``hypothesis`` is an optional dev dependency (see pyproject ``[project
.optional-dependencies]``); skip the whole module when it is absent.
"""
import math

import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import operators as ops
from repro.core.dataflow import Dataflow
from repro.core.rewrites import fuse_chains
from repro.core.table import Table
from repro.serving.batcher import Batcher

ints = st.integers(-1000, 1000)
rows = st.lists(st.tuples(ints, ints), min_size=0, max_size=30)


def _t(data):
    return Table([("a", int), ("b", int)], data)


@given(rows, st.integers(1, 6))
@settings(max_examples=40, deadline=None)
def test_fusion_equivalence_on_random_chains(data, n):
    """Operator fusion must preserve semantics for any map/filter chain."""
    def inc(a: int, b: int) -> tuple[int, int]:
        return a + 1, b
    def flip(a: int, b: int) -> tuple[int, int]:
        return b, a
    def keep(a: int, b: int) -> bool:
        return (a + b) % 3 != 0
    fns = [(inc, "map"), (flip, "map"), (keep, "filter")]
    fl = Dataflow([("a", int), ("b", int)])
    node = fl.source
    for i in range(n):
        fn, kind = fns[i % 3]
        node = (node.map(fn, names=["a", "b"]) if kind == "map"
                else node.filter(fn))
    fl.output = node
    base = fl.execute_local(_t(data)).to_dicts()
    fused = fuse_chains(fl).execute_local(_t(data)).to_dicts()
    assert base == fused


@given(rows)
@settings(max_examples=40, deadline=None)
def test_agg_matches_python(data):
    t = _t(data)
    if not data:
        return
    for fn, pyfn in [("sum", sum), ("min", min), ("max", max),
                     ("count", len)]:
        out = ops.Agg(fn, "a").apply([t])
        vals = [r[0] for r in data]
        assert out.rows[0].values[1] == pyfn(vals)
    avg = ops.Agg("avg", "a").apply([t]).rows[0].values[1]
    assert math.isclose(avg, sum(r[0] for r in data) / len(data))


@given(rows, rows)
@settings(max_examples=40, deadline=None)
def test_join_counts(left_data, right_data):
    """inner <= left <= outer; left join preserves all left rows."""
    left = Table([("k", int), ("l", int)], left_data)
    right = Table([("k", int), ("r", int)], right_data)
    inner = ops.Join(key="k").apply([left, right])
    leftj = ops.Join(key="k", how="left").apply([left, right])
    outer = ops.Join(key="k", how="outer").apply([left, right])
    assert len(inner) <= len(leftj) <= len(outer)
    lkeys = {r[0] for r in left_data}
    rkeys = {r[0] for r in right_data}
    matched_left = sum(1 for r in left_data if r[0] in rkeys)
    unmatched_left = len(left_data) - matched_left
    assert len(leftj) == len(inner) + unmatched_left
    unmatched_right = sum(1 for r in right_data if r[0] not in lkeys)
    assert len(outer) == len(leftj) + unmatched_right


@given(rows)
@settings(max_examples=30, deadline=None)
def test_union_multiset(data):
    a = _t(data)
    b = _t(data[::-1])
    u = ops.Union().apply([a, b])
    assert len(u) == 2 * len(data)
    assert sorted(r.values for r in u.rows) == sorted(
        [tuple(v) for v in data] * 2)


@given(st.lists(ints, min_size=1, max_size=40), st.integers(1, 10))
@settings(max_examples=20, deadline=None)
def test_batcher_matches_sequential(xs, max_batch):
    """Batched execution demultiplexes to exactly the sequential results."""
    def fn(args):
        return [a * 2 + 1 for a in args]
    b = Batcher(fn, max_batch=max_batch, max_wait_ms=1.0)
    try:
        import concurrent.futures as cf
        with cf.ThreadPoolExecutor(8) as pool:
            futs = [pool.submit(b.call, x) for x in xs]
            got = [f.result(timeout=10) for f in futs]
        assert got == [x * 2 + 1 for x in xs]
    finally:
        b.close()


@given(st.lists(st.tuples(st.sampled_from("abcdef"), ints), min_size=1,
                max_size=50))
@settings(max_examples=30, deadline=None)
def test_groupby_agg_partition(data):
    """Sum over groups == total sum (aggregation is a partition)."""
    t = Table([("k", str), ("v", int)], data)
    g = ops.GroupBy("k").apply([t])
    out = ops.Agg("sum", "v").apply([g])
    assert sum(r.values[1] for r in out.rows) == sum(v for _, v in data)
