"""Blue/green replanning + the cross-DAG state collisions that blocked it.

* deployment state (batchers, batch config, batch metrics) is keyed by
  ``(dag, generation, node)`` — two DAGs sharing a node name, or the blue
  and green generation of one DAG mid-swap, never share a batcher whose
  batch fn captured the other deployment's node closure;
* retired batchers drain on a REAL quiescence signal (no queued items and
  no flush in progress), not ``q.empty()``, which lies during a flush;
* error-path latency is recorded (separate series + counter) and a rising
  error rate counts as an SLO miss;
* re-registration under sustained load completes every in-flight request
  on the old generation with zero drops and no batcher-thread leak;
* ``BlueGreenReplanner``: compile off the hot path -> pre-warm every
  (chain, bucket) executable through the shared cache -> canary-verify ->
  atomic swap; post-swap traffic pays ZERO executable re-traces and
  hot-applied batch config carries over to green.
"""
import threading
import time

import numpy as np
import pytest

from repro.core.dataflow import Dataflow
from repro.core.table import Row, Table
from repro.runtime.dag import RuntimeDag, RuntimeNode
from repro.runtime.netmodel import NetModel
from repro.runtime.runtime import Runtime
from repro.serving.batcher import Batcher

try:
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jax = None


@pytest.fixture
def rt():
    r = Runtime(n_cpu=4, net=NetModel(scale=0.0), batch_wait_ms=5.0)
    yield r
    r.stop()


# ---------------------------------------------------------------------------
# satellite: deployment state keyed by (dag, node), not bare node name
# ---------------------------------------------------------------------------

def _manual_batched_dag(dag_name: str, mult: int) -> RuntimeDag:
    """A one-node batched DAG whose node is named just "model" — the name
    two DAGs can share."""
    def fn(tables, ctx):
        t = tables[0]
        return t.with_rows([r.replace((r.values[0] * mult,))
                            for r in t.rows])
    node = RuntimeNode(name="model", fn=fn, deps=[], batching=True)
    return RuntimeDag(dag_name, {"model": node}, "model")


def test_two_dags_sharing_node_name_do_not_collide(rt):
    """Pre-fix, the second DAG's requests ran the FIRST DAG's captured
    batch closure (batchers were keyed by bare node name)."""
    rt.register_dag(_manual_batched_dag("a", 10))
    rt.register_dag(_manual_batched_dag("b", 100))
    fa = [rt.call_dag("a", Table([("x", int)], [(i,)])) for i in range(4)]
    fb = [rt.call_dag("b", Table([("x", int)], [(i,)])) for i in range(4)]
    assert [f.result(timeout=10).rows[0].values[0] for f in fa] == \
        [i * 10 for i in range(4)]
    assert [f.result(timeout=10).rows[0].values[0] for f in fb] == \
        [i * 100 for i in range(4)]
    # each deployment owns its batcher and its metric series
    assert rt.batcher_for("a", "model") is not rt.batcher_for("b", "model")
    snap = rt.metrics_snapshot()
    assert sum(snap["batch/a/model/size"]) == 4
    assert sum(snap["batch/b/model/size"]) == 4


def test_batch_config_is_per_dag(rt):
    rt.register_dag(_manual_batched_dag("a", 10))
    rt.register_dag(_manual_batched_dag("b", 100))
    assert rt.configure_batching("a", "model", max_batch=3,
                                 batch_wait_ms=1.0)
    rt.call_dag("a", Table([("x", int)], [(1,)])).result(timeout=10)
    rt.call_dag("b", Table([("x", int)], [(1,)])).result(timeout=10)
    assert rt.batcher_for("a", "model").max_batch == 3
    assert rt.batcher_for("b", "model").max_batch == rt.max_batch


# ---------------------------------------------------------------------------
# satellite: retired-batcher drain uses a real quiescence signal
# ---------------------------------------------------------------------------

def test_quiescent_false_during_active_flush():
    """``q.empty()`` lies while a flush holds popped items; ``quiescent``
    must not.  Items already dequeued by an in-progress flush complete
    instead of being failed by a premature close."""
    started, release = threading.Event(), threading.Event()

    def fn(args):
        started.set()
        assert release.wait(5.0)
        return [a * 2 for a in args]

    b = Batcher(fn, max_batch=4, max_wait_ms=1.0)
    try:
        item = b.submit(21)
        assert started.wait(2.0)
        # the flush thread holds the popped item: queue is empty but the
        # batcher is NOT drained — the old q.empty() check closed here and
        # could fail the dequeued request
        assert b.q.empty()
        assert not b.quiescent()
        release.set()
        assert item.event.wait(2.0)
        assert item.error is None and item.result == 42
        assert b.quiescent()
    finally:
        release.set()
        b.close()


def test_sweep_does_not_close_mid_flush_batcher(rt):
    """A retired batcher mid-flush survives the sweep; its in-flight
    request completes, then the next sweep closes it."""
    started, release = threading.Event(), threading.Event()

    def slow(x: int) -> int:
        started.set()
        assert release.wait(10.0)
        return x * 10

    fl = Dataflow([("x", int)])
    fl.output = fl.map(slow, names=["y"], batching=True)
    dep = fl.deploy(rt, name="drain")
    fut = dep.execute(Table([("x", int)], [(7,)]))
    assert started.wait(5.0)        # batch dispatched, executor in slow()
    # swap in a fresh generation while the old one is mid-request: the old
    # batcher must NOT be closed out from under the live request
    dep2 = fl.deploy(rt, name="drain")
    release.set()
    assert fut.result(timeout=10).rows[0].values[0] == 70
    assert dep2.execute(Table([("x", int)], [(8,)])) \
        .result(timeout=10).rows[0].values[0] == 80
    deadline = time.time() + 5.0
    while rt.sweep_retired() and time.time() < deadline:
        time.sleep(0.02)
    assert not rt._retired_batchers


# ---------------------------------------------------------------------------
# satellite: error-path latency is measured, errors count as SLO misses
# ---------------------------------------------------------------------------

def test_error_latency_recorded_separately(rt):
    def flaky(x: int) -> int:
        if x < 0:
            raise ValueError("bad input")
        return x

    fl = Dataflow([("x", int)])
    fl.output = fl.map(flaky, names=["x"])
    dep = fl.deploy(rt, name="flaky")
    oks = [dep.execute(Table([("x", int)], [(i,)])) for i in range(3)]
    bads = [dep.execute(Table([("x", int)], [(-1,)])) for _ in range(2)]
    for f in oks:
        f.result(timeout=10)
    for f in bads:
        with pytest.raises(ValueError):
            f.result(timeout=10)
    snap = rt.metrics_snapshot()
    assert len(snap["dag/flaky/latency_s"]) == 3       # successes only
    assert len(snap["dag/flaky/error_latency_s"]) == 2
    assert len(snap["dag/flaky/error_t"]) == 2         # the error counter
    assert all(v >= 0 for v in snap["dag/flaky/error_latency_s"])


def test_controller_treats_error_rate_as_slo_miss(rt):
    from repro.profiling import (BucketStats, FlowProfile, OpLatencyCurve,
                                 SLOController)

    def flaky(x: int) -> int:
        if x % 2:
            raise ValueError("boom")
        return x

    fl = Dataflow([("x", int)])
    fl.output = fl.map(flaky, names=["x"])
    dep = fl.deploy(rt)
    op_id = next(iter(dep.plan.ops)).op_id
    # a curve so fast the latency estimate trivially meets the SLO: only
    # the error rate can flag the miss
    c = OpLatencyCurve(key=op_id, name="flaky", per_row_s=1e-6)
    c.buckets[1] = BucketStats(mean_s=1e-6, p99_s=2e-6, cv=0.0, runs=3,
                               out_bytes=8)
    ctl = SLOController(rt, dep, slo_p99_s=1.0,
                        profile=FlowProfile(curves={op_id: c}),
                        window_s=5.0, min_rate=1.0)
    futs = [dep.execute(Table([("x", int)], [(i,)])) for i in range(40)]
    for i, f in enumerate(futs):
        if i % 2:
            with pytest.raises(ValueError):
                f.result(timeout=10)
        else:
            f.result(timeout=10)
    ev = ctl.tick()
    assert ev.detail["error_rate"] > ctl.max_error_rate
    assert ev.detail["slo_ok"] is False
    assert ev.detail["current_p99_ms"] < 1e3   # latency alone looked fine


# ---------------------------------------------------------------------------
# satellite: re-registration under sustained load — zero drops, no leak
# ---------------------------------------------------------------------------

def test_reregistration_under_load_zero_drops_no_thread_leak(rt):
    def mk(gen):
        def model(x: int) -> int:
            return x * 10 + gen
        fl = Dataflow([("x", int)])
        fl.output = fl.map(model, names=["y"], batching=True)
        return fl.deploy(rt, name="hotswap")

    mk(0)
    results, errors = [], []
    lock = threading.Lock()
    stop = threading.Event()

    def driver():
        while not stop.is_set():
            try:
                out = rt.call_dag("hotswap",
                                  Table([("x", int)], [(5,)])) \
                    .result(timeout=10)
                with lock:
                    results.append(out.rows[0].values[0])
            except BaseException as e:  # pragma: no cover
                with lock:
                    errors.append(e)
            time.sleep(0.001)

    threads = [threading.Thread(target=driver) for _ in range(4)]
    for t in threads:
        t.start()
    seen_batchers = set()
    try:
        for gen in range(1, 4):         # 3 swaps under live traffic
            time.sleep(0.15)
            with rt._batchers_lock:
                seen_batchers.update(rt._batchers.values())
            mk(gen)
    finally:
        time.sleep(0.15)
        stop.set()
        for t in threads:
            t.join(timeout=10)
            assert not t.is_alive()

    assert not errors                   # ZERO dropped/errored requests
    # every result came from a real generation's closure
    assert results and all(v in (50, 51, 52, 53) for v in results)
    # old generations' batchers all drain, close, and their threads die
    deadline = time.time() + 5.0
    while rt.sweep_retired() and time.time() < deadline:
        time.sleep(0.02)
    assert not rt._retired_batchers
    with rt._batchers_lock:
        live = set(rt._batchers.values())
    for b in seen_batchers - live:
        assert b._stop, "retired batcher never closed"
        assert not b._thread.is_alive(), "batcher thread leaked"
    # exactly the live generation's batcher remains for this dag
    assert len(live) == 1


def test_swap_back_while_draining_keeps_live_generation(rt):
    """Rollback: re-registering a generation that is still DRAINING its
    pre-swap in-flight requests must clear the draining mark — otherwise
    the drain-to-zero retires the now-live generation's batchers out
    from under traffic, recurrently."""
    started, release = threading.Event(), threading.Event()

    def slow(x: int) -> int:
        started.set()
        assert release.wait(10.0)
        return x * 10

    fl = Dataflow([("x", int)])
    fl.output = fl.map(slow, names=["y"], batching=True)
    d1 = fl.deploy(rt, name="rb")
    gen1 = d1.dag.generation
    fut = d1.execute(Table([("x", int)], [(1,)]))
    assert started.wait(5.0)                 # gen1 has an in-flight req
    fl.deploy(rt, name="rb")                 # swap to gen2: gen1 drains
    rt.register_dag(d1.dag)                  # swap BACK to gen1, draining
    release.set()
    assert fut.result(timeout=10).rows[0].values[0] == 10
    # gen1 is live again: serving works and its state is not marked dead
    assert rt.call_dag("rb", Table([("x", int)], [(2,)])) \
        .result(timeout=10).rows[0].values[0] == 20
    key = ("rb", gen1)
    assert key not in rt._draining and key not in rt._retired_gens
    assert rt.batcher_for("rb", next(iter(d1.dag.nodes))) is not None


def test_failed_replan_cooldown_suppresses_retries():
    """A failed replan must not re-run compile+warm+canary every tick:
    the controller backs off for replan_cooldown_s."""
    from repro.profiling import (BucketStats, FlowProfile, OpLatencyCurve,
                                 SLOController)
    jax_mod = pytest.importorskip("jax")
    rt2 = Runtime(n_cpu=2, n_gpu=1, net=NetModel(scale=0.0))
    try:
        fl = _gpu_flow()
        dep = fl.deploy(rt2, fusion=True, batched_lowering=False,
                        name="cool")
        op_id = next(n for n in dep.dag.nodes.values()
                     if n.batching).plan_op_id
        c = OpLatencyCurve(key=op_id, name="chain", per_row_s=8e-3)
        for b in (1, 2, 4, 8, 16):
            c.buckets[b] = BucketStats(mean_s=1e-3 + 5e-5 * b,
                                       p99_s=1.5e-3 + 7e-5 * b, cv=0.05,
                                       runs=3, out_bytes=64 * b)
        calls = []

        def failing_handler(proposal):
            calls.append(proposal)
            from repro.profiling import ReplanReport
            return ReplanReport(dag_name="cool", ok=False,
                                phase="canary", reason="forced failure")

        ctl = SLOController(rt2, dep, slo_p99_s=0.05,
                            profile=FlowProfile(curves={op_id: c}),
                            window_s=2.0, min_rate=1.0,
                            replan_cooldown_s=60.0,
                            on_replan=failing_handler)
        futs = [dep.execute(_sample()) for _ in range(60)]
        for f in futs:
            f.result(timeout=30)
        ev1 = ctl.tick()
        assert ev1.kind == "replan" and len(calls) == 1
        ev2 = ctl.tick()                 # still missing; inside cooldown
        assert ev2.kind == "replan"
        assert ev2.detail.get("replan_suppressed_s", 0) > 0
        assert len(calls) == 1           # handler NOT re-invoked
    finally:
        rt2.stop()


# ---------------------------------------------------------------------------
# tentpole: blue/green replanning
# ---------------------------------------------------------------------------

pytestmark_gpu = pytest.mark.skipif(jax is None, reason="requires jax")


def _gm1(x: "jax.Array") -> "jax.Array":
    return x * 2.0


def _gm2(x: "jax.Array") -> "jax.Array":
    return x + 1.0


def _gpu_flow():
    fl = Dataflow([("x", jax.Array)])
    fl.output = fl.map(_gm1, names=["x"], gpu=True, batching=True) \
        .map(_gm2, names=["x"], gpu=True, batching=True)
    return fl


def _sample():
    return Table([("x", jax.Array)], [(jnp.ones(8, jnp.float32),)])


@pytestmark_gpu
def test_blue_green_swap_zero_retrace_and_state_carryover():
    from repro.core.lowering import EXECUTABLE_CACHE
    from repro.profiling import BlueGreenReplanner, NodeConfig, PlanConfig

    rt = Runtime(n_cpu=2, n_gpu=1, net=NetModel(scale=0.0),
                 batch_wait_ms=2.0)
    try:
        fl = _gpu_flow()
        dep = fl.deploy(rt, fusion=True, name="bg")
        blue_dag = dep.dag
        node = next(n for n in dep.dag.nodes.values() if n.batching)
        op_id = node.plan_op_id
        # steady traffic on blue + a hot-applied batcher config that must
        # carry over to green (logical (dag, node) keying)
        for _ in range(6):
            dep.execute(_sample()).result(timeout=30)
        rt.configure_batching("bg", node.name, max_batch=5,
                              batch_wait_ms=3.0)

        # the proposal needs a recompile: a different bucket set
        proposal = PlanConfig(nodes={op_id: NodeConfig(
            max_batch=5, batch_buckets=(1, 2, 4), batch_wait_ms=3.0,
            batched_lowering=True)})
        rp = BlueGreenReplanner(rt, dep, sample=_sample())
        rep = rp.replan(proposal)
        assert rep.ok, rep
        assert rep.phase == "done"
        assert rep.canary.get("ok") is True
        assert rep.green_generation != rep.blue_generation

        # the swap happened: same name serves, the handle follows
        assert rt.dags["bg"] is dep.dag
        assert dep.dag is not blue_dag
        green_node = next(n for n in dep.dag.nodes.values() if n.batching)
        assert tuple(dep.plan.op(op_id).op.bucket_sizes) == (1, 2, 4)

        # post-swap traffic: correct results, ZERO executable re-traces
        # (warm already traced every bucket of the new set)
        traces0 = EXECUTABLE_CACHE.traces()
        futs = [dep.execute(_sample()) for _ in range(10)]
        for f in futs:
            out = f.result(timeout=30)
            np.testing.assert_allclose(
                np.asarray(out.rows[0].values[0]),
                np.ones(8, np.float32) * 2 + 1, rtol=1e-6)
        assert EXECUTABLE_CACHE.traces() == traces0
        # hot-applied batch config carried over to the green batcher
        b = rt.batcher_for("bg", green_node.name)
        assert b is not None and b.max_batch == 5
        assert b.max_wait == pytest.approx(3e-3)
    finally:
        rt.stop()


@pytestmark_gpu
def test_blue_green_inflight_requests_finish_on_blue():
    """Requests in flight at swap time complete on the blue generation
    with correct results — zero drops across the swap."""
    from repro.profiling import BlueGreenReplanner, NodeConfig, PlanConfig

    rt = Runtime(n_cpu=2, n_gpu=1, net=NetModel(scale=0.0),
                 batch_wait_ms=2.0)
    try:
        fl = _gpu_flow()
        dep = fl.deploy(rt, fusion=True, name="bg2")
        op_id = next(n for n in dep.dag.nodes.values()
                     if n.batching).plan_op_id
        dep.execute(_sample()).result(timeout=30)       # warm blue
        blue_key = (dep.dag.name, dep.dag.generation)
        futs = [dep.execute(_sample()) for _ in range(24)]   # in flight
        rep = BlueGreenReplanner(rt, dep, sample=_sample()).replan(
            PlanConfig(nodes={op_id: NodeConfig(
                max_batch=4, batch_buckets=(1, 2, 4),
                batched_lowering=True)}))
        assert rep.ok
        futs += [dep.execute(_sample()) for _ in range(8)]   # on green
        for f in futs:
            out = f.result(timeout=30)
            np.testing.assert_allclose(
                np.asarray(out.rows[0].values[0]),
                np.ones(8, np.float32) * 2 + 1, rtol=1e-6)
        # blue fully drained: its generation has no in-flight entries
        deadline = time.time() + 5.0
        while rt._inflight.get(blue_key) and time.time() < deadline:
            time.sleep(0.02)
        assert not rt._inflight.get(blue_key)
    finally:
        rt.stop()


@pytestmark_gpu
def test_canary_failure_aborts_swap_blue_stays_live():
    from repro.profiling import BlueGreenReplanner, NodeConfig, PlanConfig

    rt = Runtime(n_cpu=2, n_gpu=1, net=NetModel(scale=0.0))
    try:
        fl = _gpu_flow()
        dep = fl.deploy(rt, fusion=True, name="bg3")
        blue_dag, blue_plan = dep.dag, dep.plan
        op_id = next(n for n in dep.dag.nodes.values()
                     if n.batching).plan_op_id
        # poison the canary reference: green's (correct) output will not
        # match, so the replan must abort before the swap
        wrong = Table([("x", jax.Array)],
                      [(jnp.zeros(8, jnp.float32),)])
        fl.execute_local = lambda t, ctx=None: wrong
        rep = BlueGreenReplanner(rt, dep, sample=_sample(),
                                 reference="local").replan(
            PlanConfig(nodes={op_id: NodeConfig(
                max_batch=4, batch_buckets=(1, 4),
                batched_lowering=True)}))
        assert not rep.ok
        assert rep.phase == "canary"
        assert "mismatch" in str(rep.canary.get("error"))
        # blue untouched and still serving
        assert rt.dags["bg3"] is blue_dag
        assert dep.dag is blue_dag and dep.plan is blue_plan
        out = dep.execute(_sample()).result(timeout=30)
        np.testing.assert_allclose(
            np.asarray(out.rows[0].values[0]),
            np.ones(8, np.float32) * 2 + 1, rtol=1e-6)
        # the aborted green generation's canary-created batchers were
        # discarded, not leaked: only blue's generation remains live
        deadline = time.time() + 5.0
        while rt.sweep_retired() and time.time() < deadline:
            time.sleep(0.02)
        assert not rt._retired_batchers
        with rt._batchers_lock:
            gens = {k[1] for k in rt._batchers}
        assert gens <= {blue_dag.generation}
    finally:
        rt.stop()


@pytestmark_gpu
def test_warm_deployment_pretraces_all_buckets():
    """After warm_deployment, driving every bucket size produces ZERO new
    traces — the first post-swap request is provably trace-free."""
    from repro.core.compiler import compile_flow
    from repro.core.lowering import EXECUTABLE_CACHE
    from repro.profiling import NodeConfig, PlanConfig, warm_deployment

    rt = Runtime(n_cpu=2, n_gpu=1, net=NetModel(scale=0.0))
    try:
        fl = _gpu_flow()
        probe = fl.deploy(rt, fusion=True, name="warm0")
        op_id = next(n for n in probe.dag.nodes.values()
                     if n.batching).plan_op_id
        cfg = PlanConfig(nodes={op_id: NodeConfig(
            max_batch=4, batch_buckets=(1, 2, 4), batched_lowering=True)})
        green = compile_flow(fl, rt, fusion=True, plan_config=cfg,
                             name="warm1", register=False)
        assert "warm1" not in rt.dags           # prepared, not serving
        assert green.dag.generation > 0
        w = warm_deployment(rt, green, _sample())
        assert not w["errors"]
        traces0 = EXECUTABLE_CACHE.traces()
        for b in (1, 2, 4):
            t = Table([("x", jax.Array)],
                      [(jnp.ones(8, jnp.float32),) for _ in range(b)])
            out = rt.call_dag_object(green.dag, t).result(timeout=30)
            assert len(out) == b
        assert EXECUTABLE_CACHE.traces() == traces0, \
            "post-warm traffic re-traced an executable"
    finally:
        rt.stop()


@pytestmark_gpu
def test_controller_default_replanner_escalates_swaps_and_confirms():
    """The full loop: a per-row-lowered deployment saturates at the
    measured rate -> the optimizer proposes a batched flip (compile-time)
    -> the controller escalates to its default BlueGreenReplanner ->
    green (batched) swaps in with zero drops -> the next tick confirms
    the post-swap SLO."""
    from repro.core.lowering import BatchedJittedFuse, JittedFuse
    from repro.profiling import (BucketStats, FlowProfile, OpLatencyCurve,
                                 SLOController)

    rt = Runtime(n_cpu=2, n_gpu=1, net=NetModel(scale=0.0),
                 batch_wait_ms=2.0)
    try:
        fl = _gpu_flow()
        # deploy PER-ROW lowered: the live plan cannot express batching
        dep = fl.deploy(rt, fusion=True, batched_lowering=False,
                        name="ctl")
        node = next(n for n in dep.dag.nodes.values() if n.batching)
        op_id = node.plan_op_id
        op0 = dep.plan.op(op_id).op
        assert isinstance(op0, JittedFuse) \
            and not isinstance(op0, BatchedJittedFuse)

        # synthetic curve: per-row saturates at the measured rate, the
        # batched path is comfortably cheap -> propose() must flip to
        # batched lowering, which needs a recompile
        c = OpLatencyCurve(key=op_id, name="chain", per_row_s=5e-3)
        for b in (1, 2, 4, 8, 16):
            c.buckets[b] = BucketStats(mean_s=1e-3 + 5e-5 * b,
                                       p99_s=1.5e-3 + 7e-5 * b,
                                       cv=0.05, runs=3, out_bytes=64 * b)
        ctl = SLOController(rt, dep, slo_p99_s=0.05,
                            profile=FlowProfile(curves={op_id: c}),
                            window_s=1.0, min_rate=1.0,
                            replan_sample=_sample())

        futs = [dep.execute(_sample()) for _ in range(60)]
        for f in futs:
            f.result(timeout=30)
        ev = ctl.tick()
        assert ev.kind == "replan", ev
        assert ev.detail.get("replan_report", {}).get("ok") is True
        # green is live and batched-lowered
        assert isinstance(dep.plan.op(op_id).op, BatchedJittedFuse)
        assert rt.dags["ctl"] is dep.dag

        # post-swap traffic + the confirming tick
        futs = [dep.execute(_sample()) for _ in range(30)]
        for f in futs:
            out = f.result(timeout=30)
            np.testing.assert_allclose(
                np.asarray(out.rows[0].values[0]),
                np.ones(8, np.float32) * 2 + 1, rtol=1e-6)
        ev2 = ctl.tick()
        confirm = ev2.detail.get("post_replan_confirm")
        assert confirm is not None
        assert confirm["slo_ok"] is True, ev2
        # the batched flip is realized: no further escalation
        assert ev2.kind != "replan"
    finally:
        rt.stop()


def _rb1(x: "jax.Array") -> "jax.Array":
    return x * 3.0


def _rb2(x: "jax.Array") -> "jax.Array":
    return x - 1.0


@pytestmark_gpu
def test_failed_confirm_rolls_back_to_blue_automatically():
    """Satellite: when the confirm tick after a blue/green swap shows the
    green generation missing the SLO (here: a rising error rate), the
    controller rolls back AUTOMATICALLY — blue is re-registered
    atomically (its generation un-retired), the handle follows, a
    ``replan/rollback`` metric is recorded, and the cooldown keeps the
    very next ticks from re-compiling the green that just failed."""
    from repro.core.lowering import BatchedJittedFuse, JittedFuse
    from repro.profiling import (BucketStats, FlowProfile, OpLatencyCurve,
                                 SLOController)

    rt = Runtime(n_cpu=2, n_gpu=1, net=NetModel(scale=0.0),
                 batch_wait_ms=2.0)
    try:
        # a chain signature no other test shares: refresh_profile folds
        # the process-wide live ChainProfile into the curves, and a chain
        # already driven per-row at real (fast) speed would overwrite the
        # synthetic saturated per_row_s below and suppress the escalation
        fl = Dataflow([("x", jax.Array)])
        fl.output = fl.map(_rb1, names=["x"], gpu=True, batching=True) \
            .map(_rb2, names=["x"], gpu=True, batching=True)
        dep = fl.deploy(rt, fusion=True, batched_lowering=False,
                        name="rb")
        blue_dag, blue_plan = dep.dag, dep.plan
        op_id = next(n for n in dep.dag.nodes.values()
                     if n.batching).plan_op_id
        # synthetic curve that forces the batched-flip escalation (same
        # shape as the escalate-and-confirm test above)
        c = OpLatencyCurve(key=op_id, name="chain", per_row_s=5e-3)
        for b in (1, 2, 4, 8, 16):
            c.buckets[b] = BucketStats(mean_s=1e-3 + 5e-5 * b,
                                       p99_s=1.5e-3 + 7e-5 * b,
                                       cv=0.05, runs=3, out_bytes=64 * b)
        ctl = SLOController(rt, dep, slo_p99_s=0.05,
                            profile=FlowProfile(curves={op_id: c}),
                            window_s=1.0, min_rate=1.0,
                            replan_sample=_sample())
        for f in [dep.execute(_sample()) for _ in range(60)]:
            f.result(timeout=30)
        ev = ctl.tick()
        assert ev.kind == "replan", ev
        assert ev.detail.get("replan_report", {}).get("ok") is True
        assert dep.dag is not blue_dag          # green is live

        # green "fails" in production: malformed requests drive the error
        # rate past max_error_rate, so the confirm tick judges slo_ok
        # False even though the modeled latency is fine
        bad = Table([("x", jax.Array)], [("junk",)])
        for f in [dep.execute(bad) for _ in range(30)]:
            with pytest.raises(Exception):
                f.result(timeout=30)
        ev2 = ctl.tick()
        confirm = ev2.detail.get("post_replan_confirm")
        assert confirm is not None and confirm["slo_ok"] is False, ev2
        rb = confirm.get("rollback")
        assert rb and rb["rolled_back"] is True
        assert rb["restored_generation"] == blue_dag.generation
        assert ev2.detail.get("rolled_back") is True

        # blue is live again and the shared handle follows the rollback
        assert rt.dags["rb"] is blue_dag
        assert dep.dag is blue_dag and dep.plan is blue_plan
        op0 = dep.plan.op(op_id).op
        assert isinstance(op0, JittedFuse) \
            and not isinstance(op0, BatchedJittedFuse)
        assert "replan/rollback" in rt.metrics_snapshot()
        # the rollback did NOT re-escalate in the same tick (cooldown)
        assert "replan_report" not in ev2.detail

        # blue's un-retired generation serves correctly: zero drops
        out = dep.execute(_sample()).result(timeout=30)
        np.testing.assert_allclose(
            np.asarray(out.rows[0].values[0]),
            np.ones(8, np.float32) * 3 - 1, rtol=1e-6)
        # inside the cooldown the controller must not re-compile the
        # green it just rolled back
        for f in [dep.execute(_sample()) for _ in range(10)]:
            f.result(timeout=30)
        ev3 = ctl.tick()
        assert "replan_report" not in ev3.detail
    finally:
        rt.stop()


# ---------------------------------------------------------------------------
# satellite: reserved warm-up/canary executors
# ---------------------------------------------------------------------------

def _blocked_serving_pool(rt, resource_class="cpu"):
    """Saturate every SERVING executor of a class with a blocking work
    item; returns the release event (set it to free the pool)."""
    release = threading.Event()

    def blocker(tables, ctx):
        release.wait(30.0)
        return None

    for ex in rt.pool.by_class(resource_class):
        from repro.runtime.executor import WorkItem
        ex.submit(WorkItem(fn=blocker, tables=[], produced_on=[],
                           callback=lambda *a: None))
    return release


def test_reserved_pool_keeps_canary_off_saturated_serving_pool():
    """Satellite: with ``reserved_cpu`` provisioned, a blue/green replan
    completes even while 100% of the serving pool is busy — warm-up and
    canary traffic for the prepared (not-yet-live) green generation
    routes to the reserved executors, which serving traffic never
    touches."""
    from repro.profiling import BlueGreenReplanner, NodeConfig, PlanConfig

    rt = Runtime(n_cpu=2, net=NetModel(scale=0.0), batch_wait_ms=2.0,
                 reserved_cpu=1)
    release = None
    try:
        def double(x: int) -> int:
            return x * 2

        fl = Dataflow([("x", int)])
        fl.output = fl.map(double, names=["x"], batching=True)
        dep = fl.deploy(rt, name="rsv")
        op_id = next(n for n in dep.dag.nodes.values()
                     if n.batching).plan_op_id
        # reserved executors are NOT serving candidates
        assert len(rt.pool.by_class("cpu")) == 2
        assert len(rt.pool.by_class("cpu", reserved=True)) == 1

        release = _blocked_serving_pool(rt)     # 100% serving-pool load
        # reference="local": the blue reference request would starve on
        # the saturated serving pool; ground truth runs inline
        rep = BlueGreenReplanner(
            rt, dep, sample=Table([("x", int)], [(3,)]),
            reference="local", canary_timeout_s=5.0).replan(
            PlanConfig(nodes={op_id: NodeConfig(max_batch=4,
                                                batch_wait_ms=1.0)}))
        assert rep.ok, rep
        assert rep.canary.get("ok") is True
        release.set()
        out = rt.call_dag("rsv", Table([("x", int)], [(5,)])) \
            .result(timeout=10)
        assert out.rows[0].values[0] == 10
    finally:
        if release is not None:
            release.set()
        rt.stop()


def test_canary_starves_without_reserved_pool():
    """Negative control for the reserved-pool satellite: the identical
    replan under the identical 100% serving-pool load times out in the
    canary phase when no reserved executors exist — blue stays live."""
    from repro.profiling import BlueGreenReplanner, NodeConfig, PlanConfig

    rt = Runtime(n_cpu=2, net=NetModel(scale=0.0), batch_wait_ms=2.0)
    release = None
    try:
        def double(x: int) -> int:
            return x * 2

        fl = Dataflow([("x", int)])
        fl.output = fl.map(double, names=["x"], batching=True)
        dep = fl.deploy(rt, name="nrsv")
        blue_dag = dep.dag
        op_id = next(n for n in dep.dag.nodes.values()
                     if n.batching).plan_op_id
        assert not rt.pool.by_class("cpu", reserved=True)

        release = _blocked_serving_pool(rt)
        rep = BlueGreenReplanner(
            rt, dep, sample=Table([("x", int)], [(3,)]),
            reference="local", canary_timeout_s=1.0).replan(
            PlanConfig(nodes={op_id: NodeConfig(max_batch=4,
                                                batch_wait_ms=1.0)}))
        assert not rep.ok
        assert rep.phase == "canary"
        assert rt.dags["nrsv"] is blue_dag      # blue untouched
    finally:
        if release is not None:
            release.set()
        rt.stop()
