"""use_pallas=True must match the pure-jnp model paths (interpret mode)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_tiny_config
from repro.models import build_model

B, S = 2, 32


def _compare(arch, tol=0.05):
    cfg = get_tiny_config(arch)
    cfg_p = dataclasses.replace(cfg, use_pallas=True)
    key = jax.random.PRNGKey(0)
    m, mp = build_model(cfg), build_model(cfg_p)
    params = m.init(key)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    a, _ = m.logits(params, {"tokens": toks}, remat=False)
    b, _ = mp.logits(params, {"tokens": toks}, remat=False)
    a = np.asarray(a.astype(jnp.float32))
    b = np.asarray(b.astype(jnp.float32))
    rel = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    assert rel < tol, (arch, rel)


@pytest.mark.parametrize("arch", ["yi-9b", "gemma2-9b", "rwkv6-1.6b"])
def test_pallas_forward_matches_jnp(arch):
    _compare(arch)


def test_pallas_decode_matches_jnp():
    cfg = get_tiny_config("yi-9b")
    cfg_p = dataclasses.replace(cfg, use_pallas=True)
    key = jax.random.PRNGKey(0)
    m, mpal = build_model(cfg), build_model(cfg_p)
    params = m.init(key)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    _, cache = m.prefill(params, {"tokens": toks}, cache_len=S + 4)
    pos = jnp.full((B,), S, jnp.int32)
    nxt = toks[:, :1]
    a, _ = m.decode_step(params, nxt, pos, cache)
    b, _ = mpal.decode_step(params, nxt, pos, cache)
    rel = float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(a)) + 1e-9))
    assert rel < 0.05, rel


def test_causal_skip_matches_full_attention():
    """Triangle-pair chunked attention == full chunked attention (exact)."""
    import dataclasses as dc
    from repro.models import layers as L
    key = jax.random.PRNGKey(3)
    Bq, Sq, H, K, hd = 2, 128, 4, 2, 32
    q = jax.random.normal(key, (Bq, Sq, H, hd)) * 0.5
    k = jax.random.normal(jax.random.PRNGKey(4), (Bq, Sq, K, hd)) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(5), (Bq, Sq, K, hd)) * 0.5
    pos = jnp.arange(Sq, dtype=jnp.int32)
    a = L.chunked_attention(q, k, v, q_positions=pos, k_positions=pos,
                            causal=True, chunk_q=32, chunk_k=32)
    b = L.chunked_attention_causal_skip(q, k, v, q_positions=pos,
                                        k_positions=pos, chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_causal_skip_model_logits_match():
    cfg = get_tiny_config("yi-9b")
    cfg_cs = dataclasses.replace(cfg, causal_skip=True)
    key = jax.random.PRNGKey(0)
    m, mcs = build_model(cfg), build_model(cfg_cs)
    params = m.init(key)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                              cfg.vocab_size)
    a, _ = m.logits(params, {"tokens": toks}, remat=False)
    b, _ = mcs.logits(params, {"tokens": toks}, remat=False)
    rel = float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                - b.astype(jnp.float32)))
                / (jnp.max(jnp.abs(a.astype(jnp.float32))) + 1e-9))
    assert rel < 1e-2, rel
