"""ModelOp: registry model serving stages as first-class plan operators.

Stage functions must agree with the underlying model (per-row AND
native-batched under vmap), lower into jitted chains, expose per-bucket
cost hooks that seed the estimator's curves, and drive the SLO
controller's propose -> hot-apply tick.  Also covers the
distribution-aware warm walk (observed buckets first).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_tiny_config
from repro.core import operators as ops
from repro.core.compiler import compile_flow
from repro.core.dataflow import Dataflow
from repro.core.lowering import map_is_jax_lowerable
from repro.core.table import Table
from repro.models import build_model
from repro.models.registry import model_stage_op
from repro.profiling.controller import SLOController
from repro.profiling.profiler import seed_from_model_ops
from repro.profiling.replan import warm_deployment
from repro.runtime import NetModel, Runtime

ARCH = "yi-9b"
SEQ, CACHE = 8, 16


@pytest.fixture(scope="module")
def stages():
    cfg = get_tiny_config(ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    kw = dict(model_name=ARCH, seq_len=SEQ, cache_len=CACHE,
              measure=False)
    return (cfg, model, params,
            model_stage_op(model, params, "logits", **kw),
            model_stage_op(model, params, "prefill", **kw),
            model_stage_op(model, params, "decode", **kw))


@pytest.fixture(scope="module")
def rt():
    r = Runtime(n_cpu=2, n_gpu=1, net=NetModel(scale=0.0))
    yield r
    r.stop()


def _toks(cfg, n):
    return jax.random.randint(jax.random.PRNGKey(1), (n, SEQ), 0,
                              cfg.vocab_size)


def test_stage_ops_are_lowerable_model_ops(stages):
    _, _, _, lg, pre, dec = stages
    for op in (lg, pre, dec):
        assert isinstance(op, ops.ModelOp)
        assert map_is_jax_lowerable(op), op.name
        assert op.cost_hook is None            # measure=False
    assert lg.name == f"model[{ARCH}:logits]"
    assert pre.stage == "prefill" and dec.stage == "decode"


def test_logits_stage_matches_model(stages):
    cfg, model, params, lg, _, _ = stages
    toks = _toks(cfg, 2)
    want, _ = model.logits(params, {"tokens": toks}, remat=False)
    want = want[:, -1]
    got_row = lg.fn(toks[0])                   # per-row path
    got_vmap = jax.vmap(lg.fn)(toks)           # batched-lowered path
    np.testing.assert_allclose(np.asarray(got_row),
                               np.asarray(want[0]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_vmap),
                               np.asarray(want), atol=1e-5)


def test_prefill_decode_stages_match_model_loop(stages):
    cfg, model, params, _, pre, dec = stages
    toks = _toks(cfg, 2)
    steps = 2

    # the op path, natively batched under vmap (what lowered chains do)
    state = jax.vmap(pre.fn)(toks)
    for _ in range(steps):
        state = jax.vmap(dec.fn)(*state)
    got = [int(t) for t in state[0]]

    # the op path per row (the runtime's singleton route)
    row = pre.fn(toks[0])
    for _ in range(steps):
        row = dec.fn(*row)
    got_row = int(row[0])

    # the plain model loop (ground truth)
    logits, cache = model.prefill(params, {"tokens": toks}, CACHE)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    pos = jnp.full((2,), SEQ, jnp.int32)
    for _ in range(steps):
        lg_, cache = model.decode_step(params, tok[:, None], pos, cache)
        tok = jnp.argmax(lg_[:, -1], -1).astype(jnp.int32)
        pos = pos + 1
    want = [int(t) for t in tok]

    assert got == want
    assert got_row == want[0]


def test_cost_hook_contract(stages):
    cfg, model, params, _, _, _ = stages
    op = model_stage_op(model, params, "logits", model_name=ARCH,
                        seq_len=SEQ, cache_len=CACHE, runs=1)
    d = op.cost_hook(2)
    assert {"mean_s", "p99_s", "cv", "runs", "out_bytes"} <= set(d)
    assert d["mean_s"] > 0 and d["p99_s"] >= d["mean_s"]
    assert d["runs"] == 1 and d["out_bytes"] > 0


def _gate(tokens: "jax.Array") -> "jax.Array":
    return jnp.abs(tokens)


def test_seed_from_model_ops_feeds_controller(stages, rt):
    """A measured ModelOp's cost hooks become estimator curves keyed by
    the (fused) physical op, and the controller completes a
    propose -> hot-apply tick against them."""
    cfg, model, params, _, _, _ = stages
    det = model_stage_op(model, params, "logits", model_name=ARCH,
                         seq_len=SEQ, cache_len=CACHE, runs=1)
    fl = Dataflow([("tokens", jax.Array)])
    fl.output = fl.map(_gate, names=["tokens"], gpu=True) \
        .apply_op(det, gpu=True)
    dep = compile_flow(fl, rt, fusion=True, name="modelop_seed")

    profile = seed_from_model_ops(dep.plan, batch_sizes=(1, 2))
    assert len(profile.curves) == 1
    (op_id, curve), = profile.curves.items()
    assert any(isinstance(s, ops.ModelOp)
               for s in getattr(dep.plan.op(op_id).op, "ops",
                                [dep.plan.op(op_id).op]))
    assert set(curve.buckets) == {1, 2}
    assert all(b.mean_s > 0 and b.out_bytes > 0
               for b in curve.buckets.values())

    tab = Table([("tokens", jax.Array)], [(_toks(cfg, 1)[0],)])
    for _ in range(3):
        dep.execute(tab).result(120)
    ev = SLOController(rt, dep, slo_p99_s=0.5, profile=profile,
                       replan_cooldown_s=1e9).tick()
    assert ev.kind in ("apply", "steady"), (ev.kind, ev.detail)


def _m1(x: "jax.Array") -> "jax.Array":
    return x * 2.0


def _m2(x: "jax.Array") -> "jax.Array":
    return x + 1.0


def test_warm_deployment_prefers_observed_buckets(rt):
    fl = Dataflow([("x", jax.Array)])
    fl.output = fl.map(_m1, names=["x"], gpu=True) \
        .map(_m2, names=["y"], gpu=True)
    dep = compile_flow(fl, rt, fusion=True, name="warm_obs")
    tab = Table([("x", jax.Array)], [(jnp.ones((4,)),)])

    rep = warm_deployment(rt, dep, tab)
    assert rep["observed"] == []               # no traffic yet

    # live histogram: mostly 4-row merges, some 2s, one odd 3 (pads to 4)
    for v in (4, 4, 4, 2, 3):
        rt.record_metric("batch/warm_obs/warm_obs/n:any/size", v)
    rt.record_metric("batch/warm_obs/warm_obs/n:any/latency_s", 1.0)
    rep = warm_deployment(rt, dep, tab)
    assert rep["observed"] == [4, 2]
    assert rep["buckets"][:2] == [4, 2]        # observed first...
    assert set(rep["buckets"]) >= {1, 2, 4}    # ...full coverage kept
    assert rep["errors"] == []
