"""Beyond-paper serving quantization: int8 KV cache + int8 expert weights."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_tiny_config
from repro.models import build_model
from repro.models import layers
from repro.models import moe as moe_lib


def test_kv_quant_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 2, 32)) * 3.0
    q, s = layers.kv_quantize(x)
    assert q.dtype == jnp.int8 and s.shape == (4, 8, 2)
    back = layers.kv_dequantize(q, s, jnp.float32)
    err = float(jnp.max(jnp.abs(back - x)) / jnp.max(jnp.abs(x)))
    assert err < 0.01


def test_kv_quant_decode_consistency():
    cfg = dataclasses.replace(get_tiny_config("yi-9b"), kv_quant=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab_size)
    full, _ = m.logits(params, {"tokens": toks}, remat=False)
    _, cache = m.prefill(params, {"tokens": toks[:, :S]}, cache_len=S + 4)
    assert cache["k0"].dtype == jnp.int8
    dec, _ = m.decode_step(params, toks[:, S:S + 1],
                           jnp.full((B,), S, jnp.int32), cache)
    a = np.asarray(full[:, S].astype(jnp.float32))
    b = np.asarray(dec[:, 0].astype(jnp.float32))
    rel = np.max(np.abs(a - b)) / np.max(np.abs(a))
    assert rel < 0.08, rel


def test_expert_quant_weights_shapes():
    cfg = get_tiny_config("arctic-480b")
    p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32, 2)
    q = moe_lib.quantize_expert_weights(p)
    assert q["w_up"]["q"].dtype == jnp.int8
    assert q["w_up"]["q"].shape == p["w_up"].shape
    assert q["w_up"]["s"].shape == p["w_up"].shape[:-2] + p[
        "w_up"].shape[-1:]
    # dequant error small
    back = moe_lib._maybe_dequant(q["w_up"], jnp.float32)
    err = float(jnp.max(jnp.abs(back - p["w_up"]))
                / jnp.max(jnp.abs(p["w_up"])))
    assert err < 0.02


def test_expert_quant_logits_close_to_float():
    cfg_f = get_tiny_config("llama4-maverick-400b-a17b")
    cfg_q = dataclasses.replace(cfg_f, expert_quant=True)
    key = jax.random.PRNGKey(0)
    mf, mq = build_model(cfg_f), build_model(cfg_q)
    pf, pq = mf.init(key), mq.init(key)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg_f.vocab_size)}
    lf, _ = mf.logits(pf, batch, remat=False)
    lq, _ = mq.logits(pq, batch, remat=False)
    rel = float(jnp.max(jnp.abs(lf.astype(jnp.float32)
                                - lq.astype(jnp.float32)))
                / jnp.max(jnp.abs(lf.astype(jnp.float32))))
    assert rel < 0.1, rel


def test_expert_quant_decode_runs():
    cfg = dataclasses.replace(get_tiny_config("arctic-480b"),
                              expert_quant=True, kv_quant=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jnp.ones((2, 8), jnp.int32)
    _, cache = m.prefill(params, {"tokens": toks}, cache_len=16)
    logits, cache = m.decode_step(params, toks[:, :1],
                                  jnp.full((2,), 8, jnp.int32), cache)
    assert bool(jnp.all(jnp.isfinite(logits)))
