"""PlaceKernelsPass + kernel registry: identity, placement, parity.

Every Pallas kernel is routed through a fused lowered chain (interpret
mode on CPU) and must match the same chain compiled with
``place_kernels=False`` — whose step IS the :mod:`repro.kernels.ref`
oracle — at every padding bucket the serving path pads to, and under the
masked filter-in-jit variant.
"""
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dataflow import Dataflow
from repro.core.lowering import EXECUTABLE_CACHE, forced_batched_routing
from repro.core.table import Table
from repro.kernels import ops as kops
from repro.runtime import NetModel, Runtime

H, KV, S, HD = 2, 2, 32, 8       # attention shapes (per row)
T, R = 8, 8                      # recurrence shapes (per row)
DSEQ = 16                        # decode cache length


@pytest.fixture(scope="module")
def rt():
    r = Runtime(n_cpu=2, n_gpu=1, net=NetModel(scale=0.0))
    yield r
    r.stop()


def _rand(key, shape, scale=0.3):
    return jax.random.normal(jax.random.PRNGKey(key), shape,
                             jnp.float32) * scale


# -- one (flow builder, table builder) pair per kernel -----------------------

def _gate3(q: "jax.Array", k: "jax.Array", v: "jax.Array"
           ) -> "Tuple[jax.Array, jax.Array, jax.Array]":
    return q * 0.5, k, v


def _flow_flash():
    step = kops.kernel_step("flash_attention", causal=True,
                            block_q=16, block_k=16)
    fl = Dataflow([("q", jax.Array), ("k", jax.Array), ("v", jax.Array)])
    fl.output = fl.map(_gate3, names=["q", "k", "v"], gpu=True) \
        .map(step, names=["o"], gpu=True)
    return fl


def _tab_flash(n):
    q, k, v = (_rand(i, (n, H, S, HD)) for i in range(3))
    return Table([("q", jax.Array), ("k", jax.Array), ("v", jax.Array)],
                 [(q[i], k[i], v[i]) for i in range(n)])


def _gate_dec(q: "jax.Array", kc: "jax.Array", vc: "jax.Array",
              kpos: "jax.Array", qpos: "jax.Array"
              ) -> "Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]":
    return q * 0.5, kc, vc, kpos, qpos


def _flow_decode():
    step = kops.kernel_step("decode_attention", block_s=16)
    cols = ["q", "kc", "vc", "kpos", "qpos"]
    fl = Dataflow([(c, jax.Array) for c in cols])
    fl.output = fl.map(_gate_dec, names=cols, gpu=True) \
        .map(step, names=["o"], gpu=True)
    return fl


def _tab_decode(n):
    q = _rand(0, (n, H, HD))
    kc, vc = _rand(1, (n, KV, DSEQ, HD)), _rand(2, (n, KV, DSEQ, HD))
    kpos = jnp.broadcast_to(jnp.arange(DSEQ, dtype=jnp.int32),
                            (n, DSEQ))
    qpos = jnp.full((n,), DSEQ // 2, jnp.int32)
    cols = ["q", "kc", "vc", "kpos", "qpos"]
    return Table([(c, jax.Array) for c in cols],
                 [(q[i], kc[i], vc[i], kpos[i], qpos[i])
                  for i in range(n)])


def _gate4(r: "jax.Array", k: "jax.Array", v: "jax.Array", w: "jax.Array"
           ) -> "Tuple[jax.Array, jax.Array, jax.Array, jax.Array]":
    return r * 0.5, k, v, w


_WKV_U = None          # module-level: bound-arg identity must be stable


def _wkv_u():
    global _WKV_U
    if _WKV_U is None:
        _WKV_U = _rand(9, (H, HD))
    return _WKV_U


def _flow_wkv6():
    step = kops.kernel_step("wkv6", bound={"u": _wkv_u()}, chunk=4)
    cols = ["r", "k", "v", "w"]
    fl = Dataflow([(c, jax.Array) for c in cols])
    fl.output = fl.map(_gate4, names=cols, gpu=True) \
        .map(step, names=["o"], gpu=True)
    return fl


def _tab_wkv6(n):
    r, k, v, w = (_rand(i, (n, T, H, HD)) for i in range(4))
    cols = ["r", "k", "v", "w"]
    return Table([(c, jax.Array) for c in cols],
                 [(r[i], k[i], v[i], w[i]) for i in range(n)])


def _gate2(a: "jax.Array", x: "jax.Array"
           ) -> "Tuple[jax.Array, jax.Array]":
    return a, x * 0.5


def _flow_rglru():
    step = kops.kernel_step("rglru_scan", chunk=4, block_r=R)
    fl = Dataflow([("a", jax.Array), ("x", jax.Array)])
    fl.output = fl.map(_gate2, names=["a", "x"], gpu=True) \
        .map(step, names=["o"], gpu=True)
    return fl


def _tab_rglru(n):
    a = jax.nn.sigmoid(_rand(0, (n, T, R), 1.0))
    x = _rand(1, (n, T, R))
    return Table([("a", jax.Array), ("x", jax.Array)],
                 [(a[i], x[i]) for i in range(n)])


_CASES = {"flash": (_flow_flash, _tab_flash),
          "decode": (_flow_decode, _tab_decode),
          "wkv6": (_flow_wkv6, _tab_wkv6),
          "rglru": (_flow_rglru, _tab_rglru)}


def _assert_close(got, want, atol=1e-4):
    assert len(got.rows) == len(want.rows)
    for g, w in zip(got.rows, want.rows):
        for gv, wv in zip(g.values, w.values):
            np.testing.assert_allclose(
                np.asarray(gv, np.float32), np.asarray(wv, np.float32),
                atol=atol, rtol=1e-3)


# -- parity through lowered chains, at every padding bucket ------------------

@pytest.mark.parametrize("which", sorted(_CASES))
def test_lowered_chain_matches_oracle_at_buckets(rt, which):
    """Row counts 1, 2, 3 land on padding buckets 1, 2 and 4 (row 3 pads
    up), all batch-routed: the placed Pallas chain must reproduce the
    oracle chain at each."""
    build, mktab = _CASES[which]
    dep_k = build().deploy(rt, fusion=True, name=f"kp_{which}")
    dep_r = build().deploy(rt, fusion=True, place_kernels=False,
                           name=f"kp_{which}_ref")
    assert any(o.kernels for o in dep_k.plan.ops), "nothing placed"
    assert not any(o.kernels for o in dep_r.plan.ops)
    routed = [o.op for o in dep_k.plan.ops] \
        + [o.op for o in dep_r.plan.ops]
    for n in (1, 2, 3):
        tab = mktab(n)
        with forced_batched_routing(routed):
            got = dep_k.execute(tab).result(120)
            want = dep_r.execute(tab).result(120)
        _assert_close(got, want)


def _q_mean_pos(q: "jax.Array", k: "jax.Array", v: "jax.Array") -> bool:
    return jnp.mean(q) > 0


def test_masked_filter_in_jit_variant_matches(rt):
    """A gpu filter fused upstream of the kernel step lowers to the
    masked (filter-in-jit) executable; parity must hold there too, with
    only surviving rows emitted."""
    def build():
        step = kops.kernel_step("flash_attention", causal=True,
                                block_q=16, block_k=16)
        cols = [("q", jax.Array), ("k", jax.Array), ("v", jax.Array)]
        fl = Dataflow(cols)
        fl.output = fl.map(_gate3, names=["q", "k", "v"], gpu=True) \
            .filter(_q_mean_pos, gpu=True) \
            .map(step, names=["o"], gpu=True)
        return fl

    dep_k = build().deploy(rt, fusion=True, name="kp_masked")
    dep_r = build().deploy(rt, fusion=True, place_kernels=False,
                           name="kp_masked_ref")
    tab = _tab_flash(4)
    routed = [o.op for o in dep_k.plan.ops] \
        + [o.op for o in dep_r.plan.ops]
    with forced_batched_routing(routed):
        got = dep_k.execute(tab).result(120)
        want = dep_r.execute(tab).result(120)
    assert 0 < len(got.rows) < 4, "filter should split the 4 rows"
    _assert_close(got, want)


# -- registry identity & annotations -----------------------------------------

def test_kernel_step_memoized_per_params():
    s1 = kops.kernel_step("flash_attention", causal=True,
                          block_q=16, block_k=16)
    s2 = kops.kernel_step("flash_attention", block_k=16,
                          block_q=16, causal=True)       # order-free
    s3 = kops.kernel_step("flash_attention", causal=True,
                          block_q=32, block_k=16)
    assert s1 is s2
    assert s3 is not s1, "tile params must key distinct steps"
    assert s1.__kernel_placed__ is s2.__kernel_placed__
    assert s3.__kernel_placed__ is not s1.__kernel_placed__
    assert s1.__kernel__ != s3.__kernel__


def test_kernel_step_bound_identity():
    u1, u2 = _wkv_u(), _rand(10, (H, HD))
    a = kops.kernel_step("wkv6", bound={"u": u1}, chunk=4)
    b = kops.kernel_step("wkv6", bound={"u": u1}, chunk=4)
    c = kops.kernel_step("wkv6", bound={"u": u2}, chunk=4)
    assert a is b
    assert c is not a, "different bound array -> different step"


def test_kernel_step_rejects_unknown():
    with pytest.raises(ValueError):
        kops.kernel_step("flash_attention", bogus=1)
    with pytest.raises(ValueError):
        kops.kernel_step("no_such_kernel")


def _user_attn(q: "jax.Array", k: "jax.Array",
               v: "jax.Array") -> "jax.Array":
    return q      # stand-in body; the pattern tag is what matters


def test_register_pattern_resolves_twin():
    try:
        kops.register_pattern(_user_attn, "flash_attention",
                              causal=True, block_q=16, block_k=16)
        call = kops.match_kernel(_user_attn)
        assert call is not None and call.kernel == "flash_attention"
        assert kops.placed_twin(_user_attn) is kops.placed_fn(call)
    finally:
        kops.KERNEL_PATTERNS.pop(_user_attn, None)


def test_plan_repr_shows_placement(rt):
    dep = _flow_flash().deploy(rt, fusion=True, name="kp_repr")
    assert any("pallas:flash_attention" in repr(o)
               for o in dep.plan.ops)


def test_reregister_is_trace_free(rt):
    """Recompiling + re-registering the same flow shares step identity
    (memoized kernel steps), so chain signatures — and the executables
    behind them — are reused: zero fresh traces."""
    dep1 = _flow_flash().deploy(rt, fusion=True, name="kp_rr1")
    tab = _tab_flash(2)
    dep1.execute(tab).result(120)
    before = EXECUTABLE_CACHE.traces()
    dep2 = _flow_flash().deploy(rt, fusion=True, name="kp_rr2")
    dep2.execute(tab).result(120)
    assert EXECUTABLE_CACHE.traces() == before


# -- the interpret-resolution bugfix -----------------------------------------

def test_interpret_resolved_once_outside_jit():
    """``interpret=None`` must be resolved to a concrete bool ONCE per
    process (cached backend probe), never inside the jitted call — the
    jit cache key then never sees None."""
    kops._default_interpret.cache_clear()
    q, k, v = (_rand(i, (1, H, S, HD)) for i in range(3))
    kops.flash_attention(q, k, v, block_q=16, block_k=16)
    info = kops._default_interpret.cache_info()
    assert info.currsize == 1 and info.misses == 1
    kops.flash_attention(q, k, v, block_q=16, block_k=16)
    kops.wkv6(*(_rand(i, (1, T, H, HD)) for i in range(4)),
              _wkv_u(), chunk=4)
    info = kops._default_interpret.cache_info()
    assert info.misses == 1, "backend re-probed after first resolve"
    # explicit interpret bypasses the probe entirely
    assert kops._resolve_interpret(True) is True
    assert kops._resolve_interpret(False) is False
