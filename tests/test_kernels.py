"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.
All runs use interpret=True (CPU container; TPU is the lowering target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def rand(shape, dtype, scale=0.3, key=KEY):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,K,S,hd,bq,bk", [
    (1, 2, 2, 128, 64, 64, 64),
    (2, 4, 2, 256, 64, 128, 128),
    (1, 4, 1, 256, 128, 128, 64),   # MQA, uneven blocks
])
def test_flash_attention_sweep(B, H, K, S, hd, bq, bk, dtype):
    q = rand((B, H, S, hd), dtype)
    k = rand((B, K, S, hd), dtype)
    v = rand((B, K, S, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                              interpret=True)
    expect = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out.astype(np.float32),
                               expect.astype(np.float32),
                               atol=tol(dtype), rtol=tol(dtype))


@pytest.mark.parametrize("window,softcap,causal", [
    (0, 0.0, False), (64, 0.0, True), (0, 30.0, True), (32, 50.0, True)])
def test_flash_attention_masks(window, softcap, causal):
    B, H, K, S, hd = 1, 2, 1, 128, 64
    q, k, v = (rand((B, n, S, hd), jnp.float32) for n in (H, K, K))
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, block_q=64, block_k=64,
                              interpret=True)
    expect = ref.attention_ref(q, k, v, causal=causal, window=window,
                               softcap=softcap)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,K,S,hd,bs", [
    (2, 4, 2, 512, 64, 128),
    (1, 8, 8, 256, 64, 256),   # MHA
    (2, 4, 1, 512, 128, 512),  # MQA, single block
])
def test_decode_attention_sweep(B, H, K, S, hd, bs, dtype):
    q = rand((B, H, hd), dtype)
    kc = rand((B, K, S, hd), dtype)
    vc = rand((B, K, S, hd), dtype)
    kpos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    qpos = jnp.full((B,), S - 1, jnp.int32)
    out = ops.decode_attention(q, kc, vc, kpos, qpos, block_s=bs,
                               interpret=True)
    expect = ref.decode_attention_ref(q, kc, vc, kpos, qpos)
    np.testing.assert_allclose(out.astype(np.float32),
                               expect.astype(np.float32),
                               atol=tol(dtype), rtol=tol(dtype))


def test_decode_attention_ring_buffer_masking():
    """Partially-filled ring cache: empty slots (pos −1) must not attend."""
    B, H, K, S, hd = 1, 2, 2, 128, 64
    q = rand((B, H, hd), jnp.float32)
    kc = rand((B, K, S, hd), jnp.float32)
    vc = rand((B, K, S, hd), jnp.float32)
    kpos = jnp.where(jnp.arange(S) < 40, jnp.arange(S), -1)[None].astype(
        jnp.int32)
    qpos = jnp.full((B,), 39, jnp.int32)
    out = ops.decode_attention(q, kc, vc, kpos, qpos, block_s=64,
                               interpret=True)
    expect = ref.decode_attention_ref(q, kc, vc, kpos, qpos)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)
    # sliding window narrows further
    out_w = ops.decode_attention(q, kc, vc, kpos, qpos, window=8,
                                 block_s=64, interpret=True)
    expect_w = ref.decode_attention_ref(q, kc, vc, kpos, qpos, window=8)
    np.testing.assert_allclose(out_w, expect_w, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,H,hd,chunk", [
    (1, 64, 2, 32, 16), (2, 128, 2, 64, 64), (1, 96, 1, 32, 32)])
def test_wkv6_sweep(B, T, H, hd, chunk, dtype):
    r = rand((B, T, H, hd), dtype)
    k = rand((B, T, H, hd), dtype)
    v = rand((B, T, H, hd), dtype)
    w = (jax.nn.sigmoid(rand((B, T, H, hd), jnp.float32)) * 0.5
         + 0.45).astype(dtype)
    u = rand((H, hd), dtype)
    y = ops.wkv6(r, k, v, w, u, chunk=chunk, interpret=True)
    expect = ref.wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(y, expect, atol=max(tol(dtype), 1e-4),
                               rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_wkv6_matches_model_scan():
    """Kernel must agree with the model's wkv_scan (zero init state)."""
    from repro.models.rwkv6 import wkv_scan
    B, T, H, hd = 2, 64, 2, 32
    r = rand((B, T, H, hd), jnp.float32)
    k = rand((B, T, H, hd), jnp.float32)
    v = rand((B, T, H, hd), jnp.float32)
    w = jax.nn.sigmoid(rand((B, T, H, hd), jnp.float32)) * 0.5 + 0.45
    u = rand((H, hd), jnp.float32)
    y_model, _ = wkv_scan(r, k, v, w, u,
                          jnp.zeros((B, H, hd, hd), jnp.float32))
    y_kernel = ops.wkv6(r, k, v, w, u, chunk=32, interpret=True)
    np.testing.assert_allclose(y_kernel, y_model, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,R,chunk,br", [
    (2, 128, 256, 64, 128), (1, 64, 512, 64, 512), (3, 96, 128, 32, 128)])
def test_rglru_scan_sweep(B, T, R, chunk, br, dtype):
    a = jax.nn.sigmoid(rand((B, T, R), jnp.float32)).astype(dtype)
    x = rand((B, T, R), dtype)
    h = ops.rglru_scan(a, x, chunk=chunk, block_r=br, interpret=True)
    expect = ref.rglru_scan_ref(a, x)
    np.testing.assert_allclose(h, expect, atol=max(tol(dtype), 1e-4),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_rglru_scan_with_initial_state():
    B, T, R = 2, 64, 128
    a = jax.nn.sigmoid(rand((B, T, R), jnp.float32))
    x = rand((B, T, R), jnp.float32)
    h0 = rand((B, R), jnp.float32)
    h = ops.rglru_scan(a, x, h0, chunk=32, block_r=128, interpret=True)
    expect = ref.rglru_scan_ref(a, x, h0)
    np.testing.assert_allclose(h, expect, atol=1e-5, rtol=1e-5)
