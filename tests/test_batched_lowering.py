"""Batched vmap execution engine (lowering layer).

* correctness: the vmapped batched path matches the interpreted path on
  multi-row tables, ragged batches, and empty tables;
* bucketing: one XLA dispatch per shape bucket, row counts padded to
  powers of two;
* executable cache: hits across re-registrations of the identical chain
  (ZERO re-traces), misses across bucket boundaries and dtype changes;
* fallback: untraceable functions latch the interpreted path instead of
  crashing at request time;
* plumbing: IR annotations (``batchable``/``batch_buckets``), runtime DAG
  ``batched_fn``, planner flag.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.core import operators as ops
from repro.core.dataflow import Dataflow
from repro.core.ir import PhysicalPlan
from repro.core.lowering import (EXECUTABLE_CACHE, BatchedJittedFuse,
                                 JittedFuse, bucket_rows, chain_signature)
from repro.core.passes import build_pipeline
from repro.core.table import Table


def _f1(x: jax.Array) -> jax.Array:
    return jnp.tanh(x * 1.01 + 0.1)


def _f2(x: jax.Array) -> jax.Array:
    return x * x - 0.5 * x


def _chain(fns=(_f1, _f2)):
    fl = Dataflow([("x", jax.Array)])
    node = fl.source
    for f in fns:
        node = node.map(f, names=["x"], gpu=True)
    fl.output = node
    return fl


def _lower(fl, batched=True):
    return build_pipeline(fusion=True, batched_lowering=batched).run(
        PhysicalPlan.from_dataflow(fl))


def _table(rows):
    return Table([("x", jax.Array)], [(r,) for r in rows])


def test_bucket_rows_pads_to_power_of_two():
    assert [bucket_rows(n) for n in (1, 2, 3, 5, 8, 9, 64, 65, 200)] == \
        [1, 2, 4, 8, 8, 16, 64, 128, 256]


def test_batched_lowering_produces_batched_op_and_annotations():
    plan = _lower(_chain())
    (op,) = plan.ops
    assert isinstance(op.op, BatchedJittedFuse)
    assert op.batchable and op.batch_buckets
    per_row = _lower(_chain(), batched=False)
    assert isinstance(per_row.ops[0].op, JittedFuse)
    assert not isinstance(per_row.ops[0].op, BatchedJittedFuse)
    assert not per_row.ops[0].batchable


def test_batched_matches_interpreted_multi_row():
    plan = _lower(_chain())
    interp = build_pipeline(fusion=True, jit_fusion=False).run(
        PhysicalPlan.from_dataflow(_chain()))
    t = _table([jnp.linspace(-2.0, 2.0, 33) * (i + 1) for i in range(5)])
    got, want = plan.execute_local(t), interp.execute_local(t)
    assert [r.row_id for r in got.rows] == [r.row_id for r in want.rows]
    for a, b in zip(got.rows, want.rows):
        np.testing.assert_allclose(np.asarray(a.values[0]),
                                   np.asarray(b.values[0]), rtol=1e-6)


def test_one_dispatch_per_batch_bucket():
    plan = _lower(_chain())
    op = plan.ops[0].op
    t = _table([jnp.ones(16) * i for i in range(5)])   # 5 rows -> bucket 8
    plan.execute_local(t)
    assert op.batch_dispatches == 1 and op.rows_batched == 5
    plan.execute_local(_table([jnp.ones(16)] * 6))     # same bucket
    assert op.batch_dispatches == 2


def test_ragged_batch_splits_into_shape_groups():
    plan = _lower(_chain())
    op = plan.ops[0].op
    t = _table([jnp.ones(8), jnp.ones(16), jnp.ones(8) * 3, jnp.ones(16) * 2])
    out = plan.execute_local(t)
    assert op.batch_dispatches == 2                    # one per shape group
    # original row order preserved across groups
    assert [r.values[0].shape for r in out.rows] == [(8,), (16,), (8,), (16,)]
    for r_in, r_out in zip(t.rows, out.rows):
        np.testing.assert_allclose(np.asarray(r_out.values[0]),
                                   np.asarray(_f2(_f1(r_in.values[0]))),
                                   rtol=1e-6)


def test_empty_table_through_batched_path():
    plan = _lower(_chain())
    out = plan.execute_local(Table([("x", jax.Array)]))
    assert len(out) == 0 and plan.ops[0].op.batch_dispatches == 0


def test_executable_cache_hits_across_reregistration():
    """Re-lowering the identical chain (same fn objects) must reuse the
    compiled executable: zero new traces, a cache hit per repeat."""
    EXECUTABLE_CACHE.clear()
    t = _table([jnp.ones(12) * i for i in range(3)])
    _lower(_chain()).execute_local(t)
    sig = chain_signature([ops.Map(_f1, ["x"]), ops.Map(_f2, ["x"])])
    stats0 = EXECUTABLE_CACHE.stats()
    assert stats0["misses"] == 1 and stats0["traces"] == 1
    # fresh Dataflow + fresh plan + fresh BatchedJittedFuse, same functions
    _lower(_chain()).execute_local(t)
    stats1 = EXECUTABLE_CACHE.stats()
    assert stats1["traces"] == stats0["traces"]        # ZERO re-traces
    assert stats1["hits"] == stats0["hits"] + 1
    assert stats1["misses"] == stats0["misses"]
    assert EXECUTABLE_CACHE.traces(sig) == 1


def test_executable_cache_misses_across_bucket_boundaries():
    EXECUTABLE_CACHE.clear()
    plan = _lower(_chain())
    plan.execute_local(_table([jnp.ones(12)] * 3))     # bucket 4
    assert EXECUTABLE_CACHE.stats()["misses"] == 1
    plan.execute_local(_table([jnp.ones(12)] * 4))     # bucket 4: hit
    assert EXECUTABLE_CACHE.stats()["misses"] == 1
    assert EXECUTABLE_CACHE.stats()["hits"] == 1
    plan.execute_local(_table([jnp.ones(12)] * 5))     # bucket 8: miss
    stats = EXECUTABLE_CACHE.stats()
    assert stats["misses"] == 2 and stats["traces"] == 2


def test_executable_cache_misses_on_dtype_change():
    EXECUTABLE_CACHE.clear()
    plan = _lower(_chain())
    plan.execute_local(_table([jnp.ones(12, jnp.float32)] * 2))
    plan.execute_local(_table([jnp.ones(12, jnp.int32)] * 2))
    stats = EXECUTABLE_CACHE.stats()
    assert stats["misses"] == 2 and stats["chains"] == 1


def test_redefined_function_gets_a_fresh_cache_entry():
    EXECUTABLE_CACHE.clear()

    def g(x: jax.Array) -> jax.Array:
        return x + 1.0

    fl = Dataflow([("x", jax.Array)])
    fl.output = fl.map(_f1, names=["x"], gpu=True).map(g, names=["x"],
                                                       gpu=True)
    _lower(fl).execute_local(_table([jnp.ones(4)] * 2))
    assert EXECUTABLE_CACHE.stats()["chains"] == 1
    _lower(_chain()).execute_local(_table([jnp.ones(4)] * 2))
    assert EXECUTABLE_CACHE.stats()["chains"] == 2


def test_singleton_rows_use_per_row_executable():
    plan = _lower(_chain())
    op = plan.ops[0].op
    out = plan.execute_local(_table([jnp.linspace(0.0, 1.0, 9)]))
    assert op.row_dispatches == 1 and op.batch_dispatches == 0
    np.testing.assert_allclose(
        np.asarray(out.rows[0].values[0]),
        np.asarray(_f2(_f1(jnp.linspace(0.0, 1.0, 9)))), rtol=1e-6)


def test_vmap_failure_after_singleton_success_degrades_to_per_row():
    """A chain proven jit-traceable per row but failing under vmap must
    latch the per-row jitted path, not raise for the deployment's life."""
    calls = {"n": 0}

    def hostile(x: jax.Array) -> jax.Array:
        calls["n"] += 1
        if calls["n"] > 1:                  # first trace (per-row jit) ok,
            raise TypeError("no vmap for me")   # second trace (vmap) fails
        return x + 1.0

    def double(x: jax.Array) -> jax.Array:
        return x * 2.0

    fl = Dataflow([("x", jax.Array)])
    fl.output = fl.map(hostile, names=["x"], gpu=True).map(
        double, names=["x"], gpu=True)
    plan = _lower(fl)
    op = plan.ops[0].op
    # singleton first: proves the per-row executable
    plan.execute_local(_table([jnp.ones(4)]))
    assert op._jit_succeeded and not op._vmap_fallback
    # multi-row batch: vmap trace fails -> degrade to per-row, not raise
    out = plan.execute_local(_table([jnp.ones(4), jnp.ones(4) * 2]))
    assert op._vmap_fallback and not op._fallback
    np.testing.assert_allclose(np.asarray(out.rows[0].values[0]),
                               np.full(4, 4.0))
    # and it stays on the per-row path afterwards
    out2 = plan.execute_local(_table([jnp.ones(4)] * 3))
    assert len(out2) == 3


def test_executable_cache_lru_eviction_bounds_chains():
    from repro.core.lowering import ExecutableCache

    cache = ExecutableCache(max_chains=2)
    x = jnp.ones((2, 4))

    def mk(i):
        def f(v, _i=i):
            return v + _i
        return f

    sigs = [(mk(i),) for i in range(3)]
    for s in sigs:
        cache.executable(s, list(s), ((2, 4),), ("float32",))(x)
    stats = cache.stats()
    assert stats["chains"] == 2 and stats["evictions"] == 1
    # evicted chain's entries went with it
    assert all(k[0] != sigs[0] for k in cache._entries)


def test_batched_falls_back_for_untraceable_fns():
    def branchy(x: jax.Array) -> jax.Array:
        return x + 1 if float(x.sum()) > 0 else x - 1   # not traceable

    def double(x: jax.Array) -> jax.Array:
        return x * 2

    fl = Dataflow([("x", jax.Array)])
    fl.output = fl.map(branchy, names=["x"], gpu=True).map(
        double, names=["x"], gpu=True)
    plan = _lower(fl)
    assert isinstance(plan.ops[0].op, BatchedJittedFuse)
    out = plan.execute_local(_table([jnp.ones(4), -jnp.ones(4)]))
    np.testing.assert_allclose(np.asarray(out.rows[0].values[0]),
                               np.full(4, 4.0))
    np.testing.assert_allclose(np.asarray(out.rows[1].values[0]),
                               np.full(4, -4.0))


def test_non_stackable_values_fall_back_per_row():
    """Annotations can lie: object-typed values that numpy can't stack go
    down the per-row path instead of crashing the batch."""
    plan = _lower(_chain())
    op = plan.ops[0].op

    class Weird:
        pass

    t = Table([("x", jax.Array)])
    t.insert((Weird(),))
    with pytest.raises(Exception):
        # per-row jitted path also rejects it, but the error comes from the
        # chain, not from the stacker
        plan.execute_local(t)


def test_runtime_dag_carries_batched_fn():
    from repro.runtime.dag import RuntimeDag
    plan = _lower(_chain())
    dag = RuntimeDag.from_plan(plan, "bf")
    (node,) = dag.nodes.values()
    assert node.batched_fn is not None and node.jitted
    assert node.batch_buckets == plan.ops[0].batch_buckets
    out = node.batched_fn([_table([jnp.ones(4)] * 3)], None)
    assert len(out) == 3


# ---------------------------------------------------------------------------
# Filter-in-jit lowering (boolean masking inside the jitted body)
# ---------------------------------------------------------------------------

def _pos(x: jax.Array) -> bool:
    return x.sum() > 0


def _filter_chain():
    fl = Dataflow([("x", jax.Array)])
    fl.output = fl.map(_f1, names=["x"], gpu=True).filter(_pos, gpu=True) \
        .map(_f2, names=["x"], gpu=True)
    return fl


@pytest.mark.parametrize("mk_rows", [
    # mixed: some rows pass, some are masked out
    lambda: [jnp.linspace(-1.0, 1.0, 8) + i - 2 for i in range(5)],
    # empty-result batch: every row filtered
    lambda: [-jnp.ones(8) * (i + 1) for i in range(4)],
    # all-pass batch: no row filtered
    lambda: [jnp.ones(8) * (i + 1) for i in range(4)],
], ids=["mixed", "empty-result", "all-pass"])
def test_filter_chain_lowers_and_matches_interpreted(mk_rows):
    """A Filter fuses into the jitted body as a mask column: the chain
    still executes as ONE vmapped dispatch, and the output table (row ids,
    values, dropped rows) is identical to the interpreted path."""
    plan = _lower(_filter_chain())
    op = plan.ops[0].op
    assert isinstance(op, BatchedJittedFuse) and op._has_filter
    interp = build_pipeline(fusion=True, jit_fusion=False).run(
        PhysicalPlan.from_dataflow(_filter_chain()))
    t = _table(mk_rows())
    got, want = plan.execute_local(t), interp.execute_local(t)
    assert op.batch_dispatches == 1          # masked rows cost no dispatch
    assert [r.row_id for r in got.rows] == [r.row_id for r in want.rows]
    for a, b in zip(got.rows, want.rows):
        np.testing.assert_allclose(np.asarray(a.values[0]),
                                   np.asarray(b.values[0]), rtol=1e-6)


def test_filter_chain_per_row_jitted_matches_interpreted():
    """The per-row executable threads the keep-bit too (used below the
    batching crossover and for singletons)."""
    plan = _lower(_filter_chain(), batched=False)
    op = plan.ops[0].op
    assert isinstance(op, JittedFuse) and not isinstance(op,
                                                         BatchedJittedFuse)
    interp = build_pipeline(fusion=True, jit_fusion=False).run(
        PhysicalPlan.from_dataflow(_filter_chain()))
    t = _table([jnp.linspace(-1.0, 1.0, 8) + i - 2 for i in range(5)])
    got, want = plan.execute_local(t), interp.execute_local(t)
    assert [r.row_id for r in got.rows] == [r.row_id for r in want.rows]
    for a, b in zip(got.rows, want.rows):
        np.testing.assert_allclose(np.asarray(a.values[0]),
                                   np.asarray(b.values[0]), rtol=1e-6)


def test_filter_chain_singleton_routes_per_row():
    plan = _lower(_filter_chain())
    op = plan.ops[0].op
    kept = plan.execute_local(_table([jnp.ones(8)]))
    dropped = plan.execute_local(_table([-jnp.ones(8)]))
    assert op.batch_dispatches == 0 and op.row_dispatches == 2
    assert len(kept) == 1 and len(dropped) == 0


# ---------------------------------------------------------------------------
# device residency at the operator level
# ---------------------------------------------------------------------------

def test_apply_batched_emits_and_consumes_device_tables():
    from repro.core.table import DeviceTable

    plan = _lower(_chain())
    op = plan.ops[0].op
    t = _table([jnp.linspace(-1.0, 1.0, 8) * (i + 1) for i in range(3)])
    dt = op.apply_batched([t], emit_device=True)
    assert isinstance(dt, DeviceTable)
    assert dt.nrows == 3 and dt.cap == 4      # padded to the bucket
    assert [i for i in dt.row_ids] == [r.row_id for r in t.rows]
    # the emitted DeviceTable holds the chain's output...
    want = op.apply_batched([t])
    out = dt.to_table()
    assert [r.row_id for r in out.rows] == [r.row_id for r in want.rows]
    for a, b in zip(out.rows, want.rows):
        np.testing.assert_allclose(np.asarray(a.values[0]),
                                   np.asarray(b.values[0]), rtol=1e-6)
    # ...and a chain handed a DeviceTable *input* computes the same rows
    # as the host-table path, without re-stacking
    dt_in = DeviceTable.from_table(t, pad_to=4)
    dt_in.donatable = False
    got = op.apply_batched([dt_in])
    assert [r.row_id for r in got.rows] == [r.row_id for r in want.rows]
    for a, b in zip(got.rows, want.rows):
        np.testing.assert_allclose(np.asarray(a.values[0]),
                                   np.asarray(b.values[0]), rtol=1e-6)


def test_device_chain_donates_exclusive_buffers():
    """A donatable DeviceTable handed to a chain has its buffers donated
    to XLA (donate_argnums): after the call the input arrays are deleted —
    the allocation was reused for the output batch."""
    from repro.core.table import DeviceTable

    plan = _lower(_chain())
    op = plan.ops[0].op
    t = _table([jnp.linspace(-1.0, 1.0, 8) * (i + 1) for i in range(4)])
    dt = DeviceTable.from_table(t, pad_to=4)
    assert dt.donatable
    out = op.apply_batched([dt], emit_device=True)
    assert len(out) == 4 and not dt.donatable    # consumed
    with pytest.raises(RuntimeError):
        jax.device_get(dt.columns[0])            # donated -> deleted
    # shared (non-donatable) inputs survive execution
    dt2 = DeviceTable.from_table(t, pad_to=4)
    dt2.donatable = False
    op.apply_batched([dt2])
    np.testing.assert_allclose(np.asarray(jax.device_get(dt2.columns[0]))[0],
                               np.asarray(t.rows[0].values[0]))


def test_filter_chain_stays_device_resident_until_boundary():
    """Masked (filtered) rows ride along on the device; compaction happens
    only at the device->host boundary."""
    plan = _lower(_filter_chain())
    op = plan.ops[0].op
    t = _table([jnp.ones(8) * (1 if i % 2 else -1) * (i + 1)
                for i in range(4)])
    dt = op.apply_batched([t], emit_device=True)
    assert dt.mask is not None and dt.nrows == 4   # rows masked, not gone
    out = dt.to_table()
    assert [r.row_id for r in out.rows] == \
        [r.row_id for i, r in enumerate(t.rows) if i % 2]


# ---------------------------------------------------------------------------
# cost-based exec-path routing (measured per-row vs batched crossover)
# ---------------------------------------------------------------------------

def test_router_prefers_per_row_below_measured_crossover():
    """With a profile that says n per-row dispatches are cheaper than one
    batched dispatch at n's bucket, a small batch takes the per-row
    executable — no stacking, no vmapped dispatch."""
    EXECUTABLE_CACHE.clear()
    plan = _lower(_chain())
    op = plan.ops[0].op
    prof = EXECUTABLE_CACHE.profile(op._sig)
    prof.note_per_row(0.0001)              # 0.1ms/row
    prof.note_batched(4, 0.01)             # warm-up sample (discarded)
    prof.note_batched(4, 0.01)             # 10ms per 4-row dispatch
    t = _table([jnp.ones(8) * i for i in range(4)])
    out = plan.execute_local(t)
    assert len(out) == 4
    assert op.batch_dispatches == 0 and op.row_dispatches == 4
    # flip the measurements: same batch now takes the vmapped path
    prof.batched_s[4] = 0.00001
    plan.execute_local(t)
    assert op.batch_dispatches == 1


def test_router_probes_batched_path_when_unmeasured():
    EXECUTABLE_CACHE.clear()
    plan = _lower(_chain())
    op = plan.ops[0].op
    prof = EXECUTABLE_CACHE.profile(op._sig)
    prof.note_per_row(0.0001)
    # no batched estimate for this bucket yet -> batch (the call doubles
    # as the probe that measures the batched path)
    plan.execute_local(_table([jnp.ones(8) * i for i in range(4)]))
    assert op.batch_dispatches == 1


def test_chain_profile_crossover_math():
    from repro.core.lowering import ChainProfile

    p = ChainProfile()
    assert p.crossover_rows() is None      # unmeasured
    p.note_per_row(0.001)                  # 1ms/row
    for _ in range(2):                     # first sample per bucket is
        p.note_batched(4, 0.003)           # discarded as warm-up
        p.note_batched(8, 0.004)
    assert p.batched_s == {4: 0.003, 8: 0.004}
    # n=2 -> bucket 4: 2ms < 3ms per-row wins; n=3 -> 3ms >= 3ms: batch
    assert p.prefer_per_row(2, 4) and not p.prefer_per_row(3, 4)
    assert p.crossover_rows() == 3
    assert p.snapshot()["crossover_rows"] == 3


def test_routed_per_row_timing_feeds_profile():
    """Multi-row tables routed below the crossover feed the per-row EWMA
    with warm, amortized measurements.  Singletons and cold (tracing)
    calls never record — their cost is not the marginal per-row cost —
    and plain per-row chains never consult the router, so they skip the
    timing (and its host sync) entirely."""
    EXECUTABLE_CACHE.clear()
    plan = _lower(_chain())
    op = plan.ops[0].op
    plan.execute_local(_table([jnp.ones(8)]))   # cold singleton: no sample
    plan.execute_local(_table([jnp.ones(8)]))   # warm singleton: no sample
    prof = EXECUTABLE_CACHE.profile(op._sig)
    assert prof.per_row_samples == 0
    # make the router send a multi-row table per-row: that one records
    prof.note_per_row(0.0001)
    prof.note_batched(4, 1.0)
    prof.note_batched(4, 1.0)              # first sample is warm-up
    plan.execute_local(_table([jnp.ones(8) * i for i in range(3)]))
    assert op.batch_dispatches == 0        # routed per-row
    assert prof.per_row_samples == 2       # injected + measured
    # plain per-row lowering: no router, no timing
    per_row_plan = _lower(_chain(), batched=False)
    assert not getattr(per_row_plan.ops[0].op, "adaptive_routing", False)


def test_planner_decides_batched_lowering_from_hints():
    from repro.core.planner import make_plan
    from repro.runtime.netmodel import NetModel

    def slow_np(x: jax.Array) -> jax.Array:
        return jnp.sqrt(jnp.abs(x) + 1.0)

    fl = Dataflow([("x", jax.Array)])
    fl.output = fl.map(_f1, names=["x"], gpu=True).map(
        slow_np, names=["x"], gpu=True, batching=True)
    multi = _table([jnp.ones(64)] * 4)
    plan = make_plan(fl, multi, net=NetModel(scale=0.0), runs=1)
    if plan.jit_fusion:
        assert plan.batched_lowering          # batch hint present
    assert "batched_lowering" in plan.flags
