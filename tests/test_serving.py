import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_tiny_config
from repro.models import build_model
from repro.serving.engine import make_engine


@pytest.fixture(scope="module")
def engine_and_params():
    cfg = get_tiny_config("yi-9b")
    engine = make_engine(cfg, cache_len=64)
    params = engine.model.init(jax.random.PRNGKey(0))
    return cfg, engine, params


def test_generate_deterministic_greedy(engine_and_params):
    cfg, engine, params = engine_and_params
    batch = {"tokens": jnp.arange(8, dtype=jnp.int32)[None].repeat(2, 0)}
    out1 = engine.generate(params, batch, max_new_tokens=6)
    out2 = engine.generate(params, batch, max_new_tokens=6)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(out1, out2)
    assert out1.max() < cfg.padded_vocab


def test_generate_matches_stepwise_forward(engine_and_params):
    """Greedy generate must equal repeated argmax over the full forward."""
    cfg, engine, params = engine_and_params
    toks = jnp.arange(6, dtype=jnp.int32)[None]
    gen = engine.generate(params, {"tokens": toks}, max_new_tokens=4)
    cur = toks
    for i in range(4):
        logits, _ = engine.model.logits(params, {"tokens": cur}, remat=False)
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        assert int(nxt[0, 0]) == int(gen[0, i]), f"step {i}"
        cur = jnp.concatenate([cur, nxt], axis=1)


def test_generate_sampled_runs(engine_and_params):
    cfg, engine, params = engine_and_params
    batch = {"tokens": jnp.arange(4, dtype=jnp.int32)[None]}
    out = engine.generate(params, batch, max_new_tokens=3, temperature=1.0,
                          key=jax.random.PRNGKey(1))
    assert out.shape == (1, 3)


def test_cache_ring_buffer_window():
    """Sliding-window arch decodes fine past the window length."""
    cfg = get_tiny_config("gemma2-9b")
    engine = make_engine(cfg, cache_len=cfg.sliding_window)
    params = engine.model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.arange(16, dtype=jnp.int32)[None]}
    out = engine.generate(params, batch, max_new_tokens=cfg.sliding_window)
    assert out.shape == (1, cfg.sliding_window)
    assert np.isfinite(out).all()
