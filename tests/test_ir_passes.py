"""Physical-plan IR + pass-manager coverage.

* property-style equivalence: every pass combination over randomized DAGs
  preserves ``execute_local`` semantics (seeded ``random``, no hypothesis
  dependency — these must run in the minimal environment);
* JIT lowering: a fused JAX chain compiles to ONE jitted callable and
  produces outputs identical to the interpreted path;
* hint preservation: fusion keeps ``high_variance``/``competitive_replicas``
  so fusion-then-competitive still replicates;
* IR invariants: validation catches malformed plans, traces are recorded,
  runtime lowering carries the annotations.
"""
import itertools
import random

import pytest

from repro.core import operators as ops
from repro.core.dataflow import Dataflow
from repro.core.ir import SOURCE_ID, PhysicalOp, PhysicalPlan, PlanError
from repro.core.lowering import JittedFuse
from repro.core.passes import (CompetitivePass, FuseChainsPass,
                               LowerJaxChainsPass, PassContext, PassPipeline,
                               build_pipeline)
from repro.core.rewrites import apply_rewrites, competitive, fuse_chains
from repro.core.table import Table


def _inc(a: int, b: int) -> tuple[int, int]:
    return a + 1, b


def _flip(a: int, b: int) -> tuple[int, int]:
    return b, a


def _mix(a: int, b: int) -> tuple[int, int]:
    return a + b, a - b


def _keep(a: int, b: int) -> bool:
    return (a + b) % 3 != 0


def _random_flow(rng: random.Random) -> Dataflow:
    """A random DAG of maps/filters with branches, unions, and hints."""
    fl = Dataflow([("a", int), ("b", int)])
    frontier = [fl.source]
    for _ in range(rng.randint(2, 8)):
        node = rng.choice(frontier)
        roll = rng.random()
        if roll < 0.55:
            fn = rng.choice([_inc, _flip, _mix])
            hints = {}
            if rng.random() < 0.25:
                hints["competitive_replicas"] = rng.randint(2, 3)
            if rng.random() < 0.2:
                hints["high_variance"] = True
            if rng.random() < 0.2:
                hints["gpu"] = True
            frontier.append(node.map(fn, names=["a", "b"], **hints))
        elif roll < 0.75:
            frontier.append(node.filter(_keep))
        elif len(frontier) >= 2:
            other = rng.choice([n for n in frontier if n is not node])
            if other is not fl.source and node is not fl.source:
                frontier.append(node.union(other))
    tail = frontier[-1] if frontier[-1] is not fl.source else \
        fl.source.map(_inc, names=["a", "b"])
    if rng.random() < 0.3:
        tail = tail.groupby("a").agg("sum", "b")
    fl.output = tail
    return fl


def _sample(rng: random.Random) -> Table:
    n = rng.randint(0, 12)
    return Table([("a", int), ("b", int)],
                 [(rng.randint(-50, 50), rng.randint(-50, 50))
                  for _ in range(n)])


def _sorted_dicts(t: Table):
    return sorted((sorted(d.items()) for d in t.to_dicts()))


def test_random_dags_all_pass_combinations_preserve_semantics():
    for seed in range(25):
        rng = random.Random(seed)
        fl = _random_flow(rng)
        t = _sample(rng)
        expected = _sorted_dicts(fl.execute_local(t))
        for fusion, comp, loc in itertools.product((False, True), repeat=3):
            pipeline = build_pipeline(fusion=fusion, competitive_exec=comp,
                                      locality=loc)
            plan = pipeline.run(PhysicalPlan.from_dataflow(fl))
            got = _sorted_dicts(plan.execute_local(t))
            assert got == expected, (
                f"seed={seed} fusion={fusion} comp={comp} loc={loc}")
            # the logical round-trip must agree too (shim path)
            rt = _sorted_dicts(plan.to_dataflow().execute_local(t))
            assert rt == expected


def test_apply_rewrites_shim_matches_pipeline():
    for seed in range(10):
        rng = random.Random(100 + seed)
        fl = _random_flow(rng)
        t = _sample(rng)
        base = _sorted_dicts(fl.execute_local(t))
        out = apply_rewrites(fl, fusion=True, competitive_exec=True,
                             locality=True)
        assert _sorted_dicts(out.execute_local(t)) == base


# ---------------------------------------------------------------------------
# JIT lowering
# ---------------------------------------------------------------------------

def _jax_chain(n=3, gpu=True):
    import jax
    import jax.numpy as jnp

    def f1(x: jax.Array) -> jax.Array:
        return jnp.tanh(x * 1.01 + 0.1)

    def f2(x: jax.Array) -> jax.Array:
        return x * x - 0.5 * x

    def f3(x: jax.Array) -> jax.Array:
        return jnp.exp(-jnp.abs(x)) + x

    fl = Dataflow([("x", jax.Array)])
    node = fl.source
    for f in (f1, f2, f3)[:n]:
        node = node.map(f, names=["x"], gpu=gpu)
    fl.output = node
    return fl


def test_jax_chain_lowers_to_single_jitted_callable():
    jax = pytest.importorskip("jax")
    jnp = jax.numpy
    fl = _jax_chain(3)
    jit_plan = build_pipeline(fusion=True, jit_fusion=True).run(
        PhysicalPlan.from_dataflow(fl))
    interp_plan = build_pipeline(fusion=True, jit_fusion=False).run(
        PhysicalPlan.from_dataflow(fl))
    assert len(jit_plan.ops) == 1
    lowered = jit_plan.ops[0].op
    assert isinstance(lowered, JittedFuse)
    assert len(lowered.ops) == 3
    assert lowered.jitted_fn is not None        # exactly one compiled callable
    assert not isinstance(interp_plan.ops[0].op, JittedFuse)

    import numpy as np
    x = jnp.linspace(-2.0, 2.0, 257)
    t = Table([("x", jax.Array)], [(x,)])
    a = jit_plan.execute_local(t).rows[0].values[0]
    b = interp_plan.execute_local(t).rows[0].values[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_jit_lowering_falls_back_for_untraceable_fns():
    """Array annotations don't guarantee jax-traceability; a lowered chain
    whose fn has data-dependent control flow must fall back to the
    interpreted path instead of crashing at request time."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    import numpy as np

    def branchy(x: jax.Array) -> jax.Array:
        return x + 1 if float(x.sum()) > 0 else x - 1   # not traceable

    def double(x: jax.Array) -> jax.Array:
        return x * 2

    fl = Dataflow([("x", jax.Array)])
    fl.output = fl.map(branchy, names=["x"], gpu=True).map(
        double, names=["x"], gpu=True)
    plan = build_pipeline(fusion=True, jit_fusion=True).run(
        PhysicalPlan.from_dataflow(fl))
    assert isinstance(plan.ops[0].op, JittedFuse)
    t = Table([("x", jax.Array)], [(jnp.ones(4),)])
    out = plan.execute_local(t)
    np.testing.assert_allclose(np.asarray(out.rows[0].values[0]),
                               np.full(4, 4.0))


def test_jit_lowering_requires_gpu_placement():
    pytest.importorskip("jax")
    fl = _jax_chain(3, gpu=False)
    plan = build_pipeline(fusion=True, jit_fusion=True).run(
        PhysicalPlan.from_dataflow(fl))
    assert len(plan.ops) == 1
    assert isinstance(plan.ops[0].op, ops.Fuse)
    assert not isinstance(plan.ops[0].op, JittedFuse)


def test_runtime_dag_lowering_marks_jitted_node():
    pytest.importorskip("jax")
    from repro.runtime.dag import RuntimeDag
    fl = _jax_chain(3)
    plan = build_pipeline(fusion=True, jit_fusion=True).run(
        PhysicalPlan.from_dataflow(fl))
    dag = RuntimeDag.from_plan(plan, "jitflow")
    (node,) = dag.nodes.values()
    assert node.jitted and node.resource_class == "gpu"
    assert node.plan_op_id == plan.output_id


def test_jitted_flow_through_runtime_matches_interpreted():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    import numpy as np
    from repro.runtime.netmodel import NetModel
    from repro.runtime.runtime import Runtime

    x = jnp.linspace(-1.0, 1.0, 513)
    t = Table([("x", jax.Array)], [(x,)])
    outs = {}
    for jitted in (False, True):
        rt = Runtime(n_cpu=1, n_gpu=1, net=NetModel(scale=0.0))
        try:
            fl = _jax_chain(3)
            dep = fl.deploy(rt, fusion=True, jit_fusion=jitted)
            if jitted:
                assert any(n.jitted for n in dep.dag.nodes.values())
            outs[jitted] = dep.execute(t).result(timeout=30)
        finally:
            rt.stop()
    np.testing.assert_allclose(
        np.asarray(outs[True].rows[0].values[0]),
        np.asarray(outs[False].rows[0].values[0]), rtol=1e-6)


# ---------------------------------------------------------------------------
# hint preservation (fusion must compose with competitive execution)
# ---------------------------------------------------------------------------

def test_fusion_preserves_competitive_hints():
    def a(x: int) -> int:
        return x + 1

    def b(x: int) -> int:
        return x * 2

    fl = Dataflow([("x", int)])
    fl.output = fl.map(a, names=["x"]).map(b, names=["x"],
                                           competitive_replicas=3)
    fused = fuse_chains(fl)
    (node,) = [n for n in fused.sorted_nodes() if n.op is not None]
    assert isinstance(node.op, ops.Fuse)
    assert node.op.competitive_replicas == 3     # hint survived fusion

    rw = competitive(fused)
    nodes = [n for n in rw.sorted_nodes() if n.op is not None]
    anyofs = [n for n in nodes if isinstance(n.op, ops.AnyOf)]
    assert len(anyofs) == 1 and len(anyofs[0].upstreams) == 3
    out = rw.execute_local(Table([("x", int)], [(5,)]))
    assert out.rows[0].values == (12,)


def test_competitive_anyof_stays_off_the_accelerator_pool():
    def a(x: int) -> int:
        return x + 1

    fl = Dataflow([("x", int)])
    fl.output = fl.map(a, names=["x"], gpu=True, competitive_replicas=3)
    plan = CompetitivePass().run(PhysicalPlan.from_dataflow(fl),
                                 PassContext())
    anyof = plan.output
    assert anyof.wait_any and anyof.placement == "cpu"
    assert all(plan.op(i).placement == "gpu" for i in anyof.inputs)


def test_fusion_preserves_high_variance_flag():
    def a(x: int) -> int:
        return x + 1

    fl = Dataflow([("x", int)])
    fl.output = fl.map(a, names=["x"], high_variance=True).map(a, names=["x"])
    plan = FuseChainsPass().run(PhysicalPlan.from_dataflow(fl), PassContext())
    (op,) = plan.ops
    assert op.high_variance and op.op.high_variance


# ---------------------------------------------------------------------------
# IR invariants + pass manager mechanics
# ---------------------------------------------------------------------------

def test_plan_validation_rejects_malformed_plans():
    def a(x: int) -> int:
        return x + 1

    fl = Dataflow([("x", int)])
    fl.output = fl.map(a, names=["x"])
    plan = PhysicalPlan.from_dataflow(fl)
    (op,) = plan.ops
    with pytest.raises(PlanError):
        plan.with_ops([op.replace(inputs=(99,))])          # unknown input
    with pytest.raises(PlanError):
        plan.with_ops([op, op])                            # duplicate id
    with pytest.raises(PlanError):
        plan.with_ops([op], output_id=42)                  # dangling output


def test_pipeline_records_trace_and_typechecks():
    fl = _jax_chain(3)
    ctx = PassContext()
    pipeline = PassPipeline([FuseChainsPass(), CompetitivePass(),
                             LowerJaxChainsPass()])
    plan = pipeline.run(PhysicalPlan.from_dataflow(fl), ctx)
    assert [t.name for t in ctx.trace] == \
        ["fuse-chains", "competitive", "lower-jax-chains"]
    assert ctx.trace[0].ops_before == 3 and ctx.trace[0].ops_after == 1
    plan.typecheck()                         # final plan is well-typed


def test_broken_pass_fails_at_compile_time():
    class BadPass:
        name = "bad"

        def run(self, plan, ctx):
            (op,) = plan.ops[-1:]
            return PhysicalPlan(plan.input_schema, plan.ops,
                                output_id=op.op_id + 1000)

    def a(x: int) -> int:
        return x + 1

    fl = Dataflow([("x", int)])
    fl.output = fl.map(a, names=["x"])
    with pytest.raises(PlanError):
        PassPipeline([BadPass()]).run(PhysicalPlan.from_dataflow(fl))


def test_ir_roundtrip_preserves_annotations():
    def a(x: int) -> int:
        return x + 1

    fl = Dataflow([("x", int)])
    fl.output = fl.map(a, names=["x"], gpu=True, batching=True,
                       high_variance=True, competitive_replicas=2)
    plan = PhysicalPlan.from_dataflow(fl)
    (op,) = plan.ops
    assert (op.placement, op.batching, op.high_variance, op.replicas) == \
        ("gpu", True, True, 2)
    back = plan.to_dataflow()
    (node,) = [n for n in back.sorted_nodes() if n.op is not None]
    assert node.op.resource_class == "gpu" and node.op.batching
    assert node.op.high_variance and node.op.competitive_replicas == 2
