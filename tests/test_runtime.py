import time

import numpy as np
import pytest

from repro.core.dataflow import Dataflow
from repro.core.table import Table
from repro.runtime.autoscaler import Autoscaler, AutoscalerConfig
from repro.runtime.kvs import KVS, CacheClient
from repro.runtime.netmodel import NetModel, nbytes
from repro.runtime.runtime import Runtime


@pytest.fixture
def rt():
    r = Runtime(n_cpu=4, net=NetModel(scale=0.0))
    yield r
    r.stop()


def test_runtime_matches_local(rt):
    def pre(x: int) -> float:
        return float(x)
    def m1(v: float) -> tuple[str, float]:
        return "m1", v + 0.1
    def m2(v: float) -> tuple[str, float]:
        return "m2", v + 0.5
    fl = Dataflow([("x", int)])
    base = fl.map(pre, names=["v"])
    fl.output = base.map(m1, names=["l", "c"]).union(
        base.map(m2, names=["l", "c"])).agg("max", "c")
    t = Table([("x", int)], [(1,), (2,)])
    local = fl.execute_local(t).to_dicts()
    fl.deploy(rt, fusion=True)
    assert fl.execute(t).result(timeout=10).to_dicts() == local


def test_wait_for_any_returns_first(rt):
    def fast(x: int) -> int:
        return x
    def slow(x: int) -> int:
        time.sleep(0.5)
        return -x
    fl = Dataflow([("x", int)])
    a = fl.map(fast, names=["x"])
    b = fl.map(slow, names=["x"])
    fl.output = a.anyof(b)
    fl.deploy(rt)
    t0 = time.perf_counter()
    out = fl.execute(Table([("x", int)], [(5,)])).result(timeout=10)
    assert out.rows[0].values == (5,)
    assert time.perf_counter() - t0 < 0.4  # did not wait for slow branch


def test_batching_demux(rt):
    calls = []
    def model(x: int) -> int:
        calls.append(1)
        return x * 10
    fl = Dataflow([("x", int)])
    fl.output = fl.map(model, names=["y"], batching=True)
    fl.deploy(rt)
    futs = [fl.execute(Table([("x", int)], [(i,)])) for i in range(8)]
    outs = [f.result(timeout=10).rows[0].values[0] for f in futs]
    assert outs == [i * 10 for i in range(8)]
    batcher = rt._batchers[next(iter(rt._batchers))]
    assert max(batcher.batch_sizes) > 1  # actually batched across requests


def test_lookup_through_runtime(rt):
    rt.kvs.put("w", 42, charge=False)
    def use(key: str, lookup) -> int:
        return int(lookup)
    fl = Dataflow([("key", str)])
    fl.output = fl.lookup("key", column=True).map(use, names=["v"])
    fl.deploy(rt, locality=True)
    out = fl.execute(Table([("key", str)], [("w",)])).result(timeout=10)
    assert out.rows[0].values == (42,)


def test_locality_scheduler_prefers_cached_executor():
    rt = Runtime(n_cpu=4, net=NetModel(scale=0.0))
    try:
        rt.kvs.put("hot", np.zeros(1000), charge=False)
        ex = rt.pool.by_class("cpu")[2]
        ex.cache.get("hot")  # warm exactly one executor
        def use(key: str, lookup) -> int:
            return 1
        fl = Dataflow([("key", str)])
        fl.output = fl.lookup("key", column=True).map(use, names=["v"])
        fl.deploy(rt, locality=True)
        for _ in range(6):
            fl.execute(Table([("key", str)],
                             [("hot",)])).result(timeout=10)
        # all lookups after the first should be cache hits on that executor
        assert ex.cache.hits >= 5
    finally:
        rt.stop()


def test_kvs_cache_eviction_and_index():
    kvs = KVS(NetModel(scale=0.0))
    cache = CacheClient(kvs, "e1", capacity_bytes=2000)
    kvs.put("a", np.zeros(150), charge=False)   # 1200 B
    kvs.put("b", np.zeros(150), charge=False)
    cache.get("a")
    assert "e1" in kvs.cached_where("a")
    cache.get("b")                              # evicts a
    assert not cache.holds("a")
    assert "e1" not in kvs.cached_where("a")
    assert cache.holds("b")


def test_nbytes_estimates():
    assert nbytes(np.zeros(10, np.float64)) == 80
    assert nbytes("abcd") == 4
    assert nbytes([np.zeros(2, np.float32), "ab"]) == 10
    t = Table([("a", int)], [(1,), (2,)])
    assert nbytes(t) > 0


def test_autoscaler_scales_up_under_load():
    rt = Runtime(n_cpu=1, net=NetModel(scale=0.0))
    try:
        def slow(x: int) -> int:
            time.sleep(0.05)
            return x
        fl = Dataflow([("x", int)])
        fl.output = fl.map(slow, names=["x"])
        dep = fl.deploy(rt)
        fname = dep.function_names[0]
        # pin the function to one executor, then autoscale
        rt.pool.assign(fname, [rt.pool.by_class("cpu")[0].id])
        scaler = Autoscaler(rt.pool, {fname: "cpu"},
                            AutoscalerConfig(interval_s=0.05)).start()
        futs = [fl.execute(Table([("x", int)], [(i,)])) for i in range(40)]
        for f in futs:
            f.result(timeout=30)
        scaler.stop()
        assert rt.pool.replica_count(fname) > 1
    finally:
        rt.stop()
