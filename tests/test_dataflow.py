import pytest

from repro.core.dataflow import Dataflow
from repro.core.operators import TypecheckError
from repro.core.table import Table


def _ensemble():
    def pre(x: int) -> float:
        return x * 1.0
    def m1(v: float) -> tuple[str, float]:
        return "m1", v + 0.1
    def m2(v: float) -> tuple[str, float]:
        return "m2", v + 0.5
    fl = Dataflow([("x", int)])
    base = fl.map(pre, names=["v"])
    a = base.map(m1, names=["label", "conf"])
    b = base.map(m2, names=["label", "conf"])
    fl.output = a.union(b).agg("max", "conf")
    return fl


def test_ensemble_local():
    fl = _ensemble()
    out = fl.execute_local(Table([("x", int)], [(1,), (2,)]))
    assert out.to_dicts() == [{"group": None, "max": 2.5}]


def test_output_must_derive():
    f1 = Dataflow([("x", int)])
    f2 = Dataflow([("x", int)])
    def f(x: int) -> int:
        return x
    node = f2.map(f)
    with pytest.raises(ValueError):
        f1.output = node


def test_typecheck_error_propagates():
    fl = Dataflow([("x", str)])
    def f(x: int) -> int:
        return x
    fl.output = fl.map(f)
    with pytest.raises(TypecheckError):
        fl.typecheck()


def test_missing_output():
    fl = Dataflow([("x", int)])
    with pytest.raises(ValueError):
        fl.typecheck()


def test_extend_composition():
    def inc(x: int) -> int:
        return x + 1
    def dbl(x: int) -> int:
        return x * 2
    f1 = Dataflow([("x", int)])
    f1.output = f1.map(inc, names=["x"])
    f2 = Dataflow([("x", int)])
    f2.output = f2.map(dbl, names=["x"])
    combined = f1.extend(f2)
    out = combined.execute_local(Table([("x", int)], [(3,)]))
    assert out.rows[0].values == (8,)


def test_cascade_left_join():
    def simple(v: float) -> tuple[str, float]:
        return "s", 0.9 if v < 1 else 0.3
    def low(label: str, conf: float) -> bool:
        return conf < 0.85
    def complex_m(label: str, conf: float) -> tuple[str, float]:
        return "c", 0.99
    fl = Dataflow([("v", float)])
    s = fl.map(simple, names=["label", "conf"])
    c = s.filter(low).map(complex_m, names=["clabel", "cconf"])
    fl.output = s.join(c, how="left")
    out = fl.execute_local(Table([("v", float)], [(0.5,), (2.0,)]))
    d = out.to_dicts()
    assert d[0]["clabel"] is None          # confident: cascade skipped
    assert d[1]["clabel"] == "c"           # low confidence: escalated


def test_row_id_persists_through_pipeline():
    def f(x: int) -> int:
        return x + 1
    fl = Dataflow([("x", int)])
    fl.output = fl.map(f, names=["x"]).map(f, names=["x"])
    t = Table([("x", int)], [(1,), (2,)])
    in_ids = [r.row_id for r in t.rows]
    out = fl.execute_local(t)
    assert [r.row_id for r in out.rows] == in_ids
