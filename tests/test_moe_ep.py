"""Expert-parallel MoE (shard_map + all_to_all) vs the reference path.

Runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
because jax locks the device count at first init (the main test process must
keep seeing 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_tiny_config
    from repro.models import moe as moe_lib
    from repro.models.partition import AxisInfo
    import dataclasses

    cfg = get_tiny_config("arctic-480b")
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops -> exact
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    ax = AxisInfo(mesh=mesh, data=("data",), model="model")
    key = jax.random.PRNGKey(0)
    B, S, D = 4, 8, cfg.d_model
    x = jax.random.normal(key, (B, S, D), jnp.float32) * 0.3
    params = jax.tree.map(
        lambda t: t[0],
        moe_lib.moe_init(key, cfg, jnp.float32, 1))

    y_ref, aux_ref = moe_lib.moe_apply_reference(x, params, cfg)
    results = {}
    with mesh:
        for seq_sharded, dispatch in [(True, "all_to_all"),
                                      (False, "all_to_all"),
                                      (True, "allgather")]:
            y, aux = jax.jit(
                lambda x: moe_lib.moe_apply_ep(
                    x, params, cfg, ax, seq_sharded=seq_sharded,
                    dispatch=dispatch))(x)
            err = float(jnp.max(jnp.abs(
                y.astype(jnp.float32) - y_ref.astype(jnp.float32))))
            rel = err / (float(jnp.max(jnp.abs(y_ref))) + 1e-9)
            results[f"{seq_sharded}-{dispatch}"] = rel
    print(json.dumps(results))
""")


@pytest.mark.slow
def test_ep_paths_match_reference():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    results = json.loads(out.stdout.strip().splitlines()[-1])
    for name, rel in results.items():
        assert rel < 5e-3, (name, rel, results)
