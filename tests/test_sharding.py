"""Sharding spec rules: structure matches params; dims are divisible on the
production mesh axes (the dry-run exercises real lowering; these are fast
structural checks)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P, Mesh

from repro.configs import ARCH_IDS, get_config
from repro.launch import sharding as sh
from repro.models import build_model
from repro.models.partition import AxisInfo

MP = 16
DP = 16


class _FakeMesh:
    """Shape-only stand-in (no devices needed for spec math)."""
    shape = {"data": DP, "model": MP}
    axis_names = ("data", "model")


def _abstract_params(arch):
    cfg = get_config(arch)
    ax = AxisInfo(mesh=_FakeMesh(), data=("data",), model="model")
    model = build_model(cfg, ax)
    return cfg, ax, jax.eval_shape(model.init, jax.random.PRNGKey(0))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_cover_and_divide(arch):
    cfg, ax, params = _abstract_params(arch)
    specs = sh.param_pspecs(params, cfg, ax, mode="train")
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    axis_size = {"data": DP, "model": MP, "pod": 2}
    for p, s in zip(flat_p, flat_s):
        assert isinstance(s, P)
        assert len(s) <= p.ndim, (p.shape, s)
        for dim, names in zip(p.shape, s):
            if names is None:
                continue
            names = names if isinstance(names, tuple) else (names,)
            total = 1
            for n in names:
                total *= axis_size[n]
            assert dim % total == 0, (arch, p.shape, s)


@pytest.mark.parametrize("arch", ["yi-9b", "arctic-480b", "rwkv6-1.6b"])
def test_big_leaves_are_fully_sharded_for_train(arch):
    """ZeRO goal: every >=100M-param leaf must shard over both axes."""
    cfg, ax, params = _abstract_params(arch)
    specs = sh.param_pspecs(params, cfg, ax, mode="train")
    # jax.tree.flatten_with_path is missing in jax 0.4.x; use tree_util
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for (path, p), s in zip(flat, flat_s):
        if p.size < 100e6:
            continue
        used = {n for names in s if names
                for n in (names if isinstance(names, tuple) else (names,))}
        assert "model" in used and "data" in used, (
            jax.tree_util.keystr(path), p.shape, s)


def test_opt_state_specs_adafactor():
    cfg, ax, params = _abstract_params("arctic-480b")
    pspecs = sh.param_pspecs(params, cfg, ax, mode="train")
    ospecs = sh.opt_state_pspecs(params, pspecs, "adafactor")
    from repro.training import optim
    ostate = jax.eval_shape(lambda: optim.adafactor_init(
        jax.tree.map(lambda t: jnp.zeros(t.shape, t.dtype), params)))
    # structure must line up leaf-for-leaf
    jax.tree.map(lambda a, b: None, ostate["v"], ospecs["v"],
                 is_leaf=lambda x: isinstance(x, (P, jax.ShapeDtypeStruct)))


def test_batch_pspecs_long_500k_unsharded():
    from repro.configs.shapes import LONG_500K, DECODE_32K
    cfg = get_config("rwkv6-1.6b")
    ax = AxisInfo(mesh=_FakeMesh(), data=("data",), model="model",
                  shard_batch=False)
    specs = sh.batch_pspecs(cfg, ax, LONG_500K)
    assert specs["tokens"] == P(None, None)
    ax2 = AxisInfo(mesh=_FakeMesh(), data=("data",), model="model")
    specs2 = sh.batch_pspecs(cfg, ax2, DECODE_32K)
    assert specs2["tokens"] == P(("data",), None) or specs2["tokens"] == P(
        "data", None)
