"""End-to-end request tracing, histogram metrics, SLO-miss attribution.

* span/trace model: ``kind@node`` names, tail-keep policy (SLO-miss /
  error / shed / retried traces always kept), deterministic head
  sampling, bounded kept ring;
* runtime integration: a traced request's timeline carries
  admission -> queue -> exec -> demux spans, batched members link to ONE
  shared batch span, and the Chrome exporter renders it all;
* adversarial paths: a hedged request keeps exactly one winning exec
  span with the loser marked cancelled; a crash-requeued item's spans
  chain across executors; a shed request's trace is always kept with the
  shed reason — even at 0% head sampling;
* metric primitives: log-bucketed mergeable histograms, windowed
  counters, prefix-filtered snapshots that stay live under concurrent
  writers;
* fault-aware estimator: measured fault pressure inflates the predicted
  p99 (zero rates leave it exactly unchanged);
* clock audit: every rate window and trace timestamp reads the ONE
  monotonic clock in ``repro.obs.clock``.
"""
import json
import threading
import time

import pytest

from repro.core.dataflow import Dataflow
from repro.core.table import Table
from repro.obs import (Histogram, HistogramSnapshot, Tracer, WindowedCounter,
                       attribute, export_chrome, to_chrome_events, to_json)
from repro.obs.attribution import REQUEST_NODE
from repro.obs.clock import now as obs_now
from repro.profiling.estimator import FaultStats
from repro.runtime.netmodel import NetModel
from repro.runtime.runtime import Runtime
from repro.serving.admission import AdmissionController, ClassPolicy, \
    Overloaded
from repro.serving.faults import FaultPlan


def _t(i=1):
    return Table([("i", int)], [(i,)])


def _flow(seen=None, service_s=0.0, batching=True):
    def fn(i: int) -> int:
        if seen is not None:
            seen.append(i)
        if service_s:
            time.sleep(service_s)
        return i + 1

    fl = Dataflow([("i", int)])
    fl.output = fl.map(fn, names=["i"], batching=batching)
    return fl


def _traced_runtime(sample_rate=1.0, **kw):
    return Runtime(n_cpu=kw.pop("n_cpu", 2), net=NetModel(scale=0.0),
                   tracer=Tracer(enabled=True, sample_rate=sample_rate),
                   **kw)


# ---------------------------------------------------------------------------
# span / trace model (unit)
# ---------------------------------------------------------------------------

def test_span_name_carries_node():
    tr = Tracer(enabled=True, sample_rate=1.0)
    t = tr.start("d")
    s = t.span("exec@stage1", 1.0, 2.0, link=7, executor="e0")
    assert s.kind == "exec" and s.node == "stage1"
    assert s.duration_s == pytest.approx(1.0)
    assert s.link == 7 and s.attrs["executor"] == "e0"
    a = t.span("admission", 1.0, 1.0)
    assert a.kind == "admission" and a.node is None


def test_tail_keep_policy_and_reason_priority():
    tr = Tracer(enabled=True, sample_rate=0.0)
    # nothing went wrong, not head-sampled: dropped
    t = tr.start("d")
    assert t.finish() is False and t.kept_reason is None
    # retried (via event) is kept at 0% sampling
    t = tr.start("d")
    t.event("retry@n", attempt=2)
    assert t.retried and t.finish() is True
    assert t.kept_reason == "retried"
    # slo_miss outranks everything
    t = tr.start("d")
    t.event("retry@n")
    assert t.finish(slo_miss=True) is True
    assert t.kept_reason == "slo_miss"
    # finish is idempotent: second close neither keeps nor double-counts
    kept_before = tr.stats()["kept"]
    assert t.finish(slo_miss=True) is False
    assert tr.stats()["kept"] == kept_before
    # hedge_launch flips hedged (observability flag, not a keep reason)
    t = tr.start("d")
    t.event("hedge_launch@n", delay_s=0.01)
    assert t.hedged


def test_deterministic_head_sampling_is_exact():
    for rate, expect in ((0.0, 0), (0.1, 100), (1.0, 1000)):
        tr = Tracer(enabled=True, sample_rate=rate)
        kept = sum(1 for _ in range(1000) if tr.start("d").finish())
        assert kept == expect, f"rate={rate}"


def test_kept_ring_is_bounded():
    tr = Tracer(enabled=True, sample_rate=1.0, capacity=16)
    for _ in range(100):
        tr.start("d").finish()
    assert tr.stats()["kept"] == 100          # policy counted them all
    assert len(tr.kept()) == 16               # ring kept the newest 16


def test_disabled_tracer_returns_none():
    tr = Tracer(enabled=False, sample_rate=1.0)
    assert tr.start("d") is None
    assert tr.stats()["started"] == 0


# ---------------------------------------------------------------------------
# metric primitives (unit)
# ---------------------------------------------------------------------------

def test_histogram_percentiles_within_bucket_error():
    h = Histogram()
    vals = [i / 1000.0 for i in range(1, 1001)]    # 1ms .. 1s uniform
    for v in vals:
        h.record(v)
    assert h.n == 1000
    assert h.mean == pytest.approx(sum(vals) / len(vals))
    # log-bucketed: <=12.5% relative overestimate (growth 1.25), never under
    for p, true in ((50, 0.5), (99, 0.99)):
        est = h.percentile(p)
        assert true * 0.999 <= est <= true * 1.25, (p, est)
    assert h.percentile(100) == pytest.approx(1.0)


def test_histogram_snapshots_merge():
    a, b = Histogram(), Histogram()
    for v in (0.001, 0.002, 0.004):
        a.record(v)
    for v in (0.1, 0.2):
        b.record(v)
    m = a.snapshot().merge(b.snapshot())
    assert m.n == 5
    assert m.total == pytest.approx(0.307)
    assert m.vmin == pytest.approx(0.001)
    assert m.vmax == pytest.approx(0.2)
    # merged percentile == percentile of the union recorded directly
    u = Histogram()
    for v in (0.001, 0.002, 0.004, 0.1, 0.2):
        u.record(v)
    assert m.percentile(50) == pytest.approx(u.percentile(50))
    assert HistogramSnapshot.merge_all([a.snapshot(), b.snapshot()]).n == 5
    with pytest.raises(ValueError):
        m.merge(Histogram(lo=1e-3).snapshot())


def test_windowed_counter_windows_by_event_time():
    c = WindowedCounter(slot_s=0.25, horizon_s=10.0)
    for t in (100.0, 100.1, 100.2, 105.0):
        c.note(t)
    assert c.total == 4
    assert c.count(1.0, now=100.5) == 3       # the burst, not the late one
    assert c.count(1.0, now=105.0) == 1
    assert c.rate(10.0, now=105.0) == pytest.approx(0.4)
    # memory stays bounded well past the horizon
    for i in range(100_000):
        c.note(200.0 + i * 0.01)
    assert len(c._slots) <= 2 * int(c.horizon_s / c.slot_s) + 1


# ---------------------------------------------------------------------------
# runtime metric store: histograms, prefix filtering, concurrency
# ---------------------------------------------------------------------------

def test_metrics_snapshot_prefix_filtering():
    rt = Runtime(n_cpu=1, net=NetModel(scale=0.0))
    try:
        rt.record_metric("dag/a/latency_s", 0.01)
        rt.record_metric("dag/b/latency_s", 0.02)
        rt.record_metric("faults/crash_t", obs_now())
        assert set(rt.metrics_snapshot(prefix="dag/a/")) == \
            {"dag/a/latency_s"}
        both = rt.metrics_snapshot(prefix=("dag/a/", "faults/"))
        assert set(both) == {"dag/a/latency_s", "faults/crash_t"}
        # unfiltered view still returns everything
        assert set(rt.metrics_snapshot()) >= \
            {"dag/a/latency_s", "dag/b/latency_s", "faults/crash_t"}
        # the histogram twin of a latency series answers percentiles
        h = rt.metric_histogram("dag/a/latency_s")
        assert h is not None and h.n == 1
        # the counter twin of a *_t series answers rates without a scan
        assert rt.metric_rate("faults/crash_t", window_s=60.0) > 0
    finally:
        rt.stop()


def test_metrics_snapshot_live_under_concurrent_writers():
    rt = Runtime(n_cpu=1, net=NetModel(scale=0.0))
    stop = threading.Event()

    def hammer(k):
        while not stop.is_set():
            rt.record_metric(f"dag/w{k}/latency_s", 0.001)
            rt.record_metric(f"dag/w{k}/done_t", obs_now())

    threads = [threading.Thread(target=hammer, args=(k,), daemon=True)
               for k in range(4)]
    try:
        for t in threads:
            t.start()
        deadline = time.perf_counter() + 1.0
        reads = 0
        while time.perf_counter() < deadline:
            snap = rt.metrics_snapshot(prefix="dag/w0/")
            assert all(k.startswith("dag/w0/") for k in snap)
            reads += 1
        # the filtered read path must stay fast while writers hammer the
        # store: a coarse floor catches an accidental O(all-keys-copied)
        # or lock-convoy regression
        assert reads > 50
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=2.0)
        rt.stop()


# ---------------------------------------------------------------------------
# end-to-end: spans on the serving path
# ---------------------------------------------------------------------------

def test_traced_request_has_full_span_chain():
    rt = _traced_runtime(sample_rate=1.0)
    try:
        fl = _flow(batching=True)
        fl.deploy(rt, name="e2e")
        assert fl.execute(_t(1)).result(timeout=10).rows[0].values[0] == 2
        kept = rt.tracer.kept("e2e")
        assert len(kept) == 1
        tr = kept[0]
        assert tr.kept_reason == "sampled" and tr.finished
        kinds = [s.kind for s in tr.spans]
        for kind in ("admission", "queue", "exec", "demux"):
            assert kind in kinds, kinds
        node = next(s.node for s in tr.spans if s.kind == "exec")
        assert node in rt.dags["e2e"].nodes
        # admission precedes queue precedes exec start; demux after exec
        by = {s.kind: s for s in tr.spans}
        assert by["admission"].t0 <= by["queue"].t0 <= by["exec"].t1
        assert by["demux"].t1 >= by["exec"].t0
        # the exec span carries the measured queue/service split
        assert by["exec"].attrs["attempts"] == 1
        assert by["exec"].attrs["exec_s"] >= 0.0
    finally:
        rt.stop()


def test_batched_members_share_one_linked_batch_span():
    rt = _traced_runtime(sample_rate=1.0, batch_wait_ms=20.0)
    try:
        fl = _flow(batching=True)
        fl.deploy(rt, name="bt")
        futs = [fl.execute(_t(i)) for i in range(4)]
        for f in futs:
            f.result(timeout=10)
        kept = rt.tracer.kept("bt")
        assert len(kept) == 4
        links = {s.link for t in kept for s in t.spans
                 if s.kind == "exec" and s.link is not None}
        assert links, "exec spans must link to their batch span"
        batch = rt.tracer.batch_spans(links)
        # all members that merged share the SAME batch span (one span per
        # merged dispatch, not per member)
        assert sum(b.attrs["n_requests"] for b in batch) == 4
        for b in batch:
            assert b.kind == "batch"
            assert b.attrs["size"] >= 1
    finally:
        rt.stop()


def test_shed_trace_always_kept_with_reason():
    rt = _traced_runtime(sample_rate=0.0)     # tail-keep only
    try:
        _flow().deploy(rt, name="sh")
        rt.set_admission("sh", AdmissionController(classes={
            "best_effort": ClassPolicy("best_effort", priority=0,
                                       rate=0.001, burst=1)}))
        rt.call_dag("sh", _t(1), klass="best_effort").result(timeout=10)
        shed = rt.call_dag("sh", _t(2), klass="best_effort")
        with pytest.raises(Overloaded):
            shed.result(timeout=10)
        kept = rt.tracer.kept("sh")
        assert len(kept) == 1                 # ONLY the shed one
        tr = kept[0]
        assert tr.kept_reason == "shed"
        assert tr.shed_reason == "rate_limit"
        adm = next(s for s in tr.spans if s.kind == "admission")
        assert adm.attrs["action"] == "shed"
        assert adm.attrs["reason"] == "rate_limit"
    finally:
        rt.stop()


def test_slo_missed_trace_kept_at_zero_sampling():
    rt = _traced_runtime(sample_rate=0.0)
    try:
        fl = _flow(service_s=0.05)
        fl.deploy(rt, name="miss")
        fut = rt.call_dag("miss", _t(1), deadline_s=0.5)
        assert fut.result(timeout=10).rows[0].values[0] == 2
        # fast request under a generous deadline: dropped
        assert rt.tracer.kept("miss") == []
        slow = rt.call_dag("miss", _t(2), deadline_s=0.01)
        try:
            slow.result(timeout=10)
        except Exception:
            pass                              # expiry is also an SLO miss
        kept = rt.tracer.kept("miss")
        assert len(kept) == 1 and kept[0].slo_miss
        assert kept[0].kept_reason == "slo_miss"
    finally:
        rt.stop()


# ---------------------------------------------------------------------------
# adversarial paths: hedging, crash requeue
# ---------------------------------------------------------------------------

def test_hedged_trace_one_winning_exec_span():
    rt = _traced_runtime(sample_rate=1.0, n_cpu=3, hang_timeout_s=30.0)
    try:
        seen = []
        fl = _flow(seen, batching=False)
        dep = fl.deploy(rt, name="h")
        fl.execute(_t(1)).result(timeout=10)
        seen.clear()
        rt.tracer.clear()
        rt.configure_hedging("h", dep.dag.output, 0.03)
        rt.set_fault_plan(FaultPlan(seed=5).hang(rate=1.0, hang_s=0.8,
                                                 limit=1))
        assert fl.execute(_t(3)).result(timeout=10).rows[0].values[0] == 4
        rt.set_fault_plan(None)
        kept = rt.tracer.kept("h")
        assert len(kept) == 1
        tr = kept[0]
        assert tr.hedged
        hl = [s for s in tr.spans if s.kind == "hedge_launch"]
        assert len(hl) == 1 and hl[0].attrs["delay_s"] == \
            pytest.approx(0.03)
        # exactly ONE exec span — the winner's; the loser never delivers
        execs = [s for s in tr.spans if s.kind == "exec"]
        assert len(execs) == 1
        assert execs[0].attrs["attempts"] == 2    # primary + hedge ran
        assert execs[0].attrs["executor"] is not None
        # loser cancellation: the straggler wakes, finds the token
        # claimed, and skips — user code ran exactly once
        time.sleep(1.0)
        assert seen == [3]
    finally:
        rt.stop()


def test_loser_cancellation_is_marked_and_replayable():
    # deterministic loser cancellation: the winner claims the token
    # BEFORE the loser's executor dequeues its clone, so the skip path
    # logs ("cancelled", loser_id) — and the replay helper turns it into
    # a cancelled@node span on the trace
    from repro.runtime.executor import Executor, WorkItem
    from repro.runtime.kvs import KVS
    from repro.runtime.runtime import _trace_exec_events
    a = Executor(KVS(), NetModel(scale=0.0))
    b = Executor(KVS(), NetModel(scale=0.0))
    try:
        gate = threading.Event()
        blocker = WorkItem(fn=lambda tables, ctx: gate.wait(5.0),
                           tables=[_t()], produced_on=[None],
                           callback=lambda r, e, x: None)
        done = threading.Event()
        item = WorkItem(fn=lambda tables, ctx: tables[0],
                        tables=[_t()], produced_on=[None],
                        callback=lambda r, e, x: done.set())
        a.submit(blocker)                 # wedge A behind the gate
        time.sleep(0.05)
        a.submit(item)                    # the loser, stuck behind it
        b.submit(item.clone())            # the winner, runs immediately
        assert done.wait(5.0)
        gate.set()                        # A wakes, dequeues the loser
        deadline = time.perf_counter() + 5.0
        while time.perf_counter() < deadline:
            if any(e[0] == "cancelled" for e in item.attempt_log):
                break
            time.sleep(0.01)
        log = list(item.attempt_log)
        cancelled = [e for e in log if e[0] == "cancelled"]
        assert len(cancelled) == 1 and cancelled[0][1] == a.id
        assert sum(1 for e in log if e[0] == "done") == 1
        # replay onto a trace: the loser shows up as a cancelled@ span
        tr = Tracer(enabled=True, sample_rate=1.0).start("d")
        _trace_exec_events(tr, "n1", log)
        spans = [s for s in tr.spans if s.kind == "cancelled"]
        assert len(spans) == 1
        assert spans[0].node == "n1"
        assert spans[0].attrs["executor"] == a.id
    finally:
        a.stop()
        b.stop()


def test_crash_requeued_trace_chains_across_executors():
    rt = _traced_runtime(sample_rate=0.0, n_cpu=3,
                         detector_interval_s=0.02)
    try:
        fl = _flow(batching=False)
        fl.deploy(rt, name="cr")
        fl.execute(_t(1)).result(timeout=10)
        rt.set_fault_plan(FaultPlan(seed=1).crash(rate=1.0, limit=1))
        assert fl.execute(_t(5)).result(timeout=10).rows[0].values[0] == 6
        rt.set_fault_plan(None)
        kept = rt.tracer.kept("cr")
        assert len(kept) == 1, \
            "a crash-requeued request is tail-kept at 0% sampling"
        tr = kept[0]
        assert tr.kept_reason == "retried" and tr.retried
        rq = [s for s in tr.spans if s.kind == "requeue"]
        assert len(rq) >= 1
        execs = [s for s in tr.spans if s.kind == "exec"]
        assert len(execs) == 1                # exactly one delivery
        # the span chain names BOTH executors: the requeue's target (or
        # the winner) differs from nothing — at minimum the winning
        # executor is recorded and >=2 attempts started
        assert execs[0].attrs["attempts"] >= 2
        assert execs[0].attrs["requeues"] >= 1
        # timeline ordering: the requeue happened inside the exec span
        assert execs[0].t0 <= rq[0].t0 <= execs[0].t1
    finally:
        rt.stop()


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def _run_traced_chain(rt, name="exp", n=3):
    fl = _flow(batching=True)
    fl.deploy(rt, name=name)
    futs = [fl.execute(_t(i)) for i in range(n)]
    for f in futs:
        f.result(timeout=10)
    return rt.tracer.kept(name)


def test_json_export_roundtrips(tmp_path):
    rt = _traced_runtime(sample_rate=1.0)
    try:
        kept = _run_traced_chain(rt)
        doc = json.loads(to_json(kept))
        assert len(doc) == 3
        assert all(t["kept_reason"] == "sampled" for t in doc)
        assert all(any(s["name"].startswith("exec@") for s in t["spans"])
                   for t in doc)
    finally:
        rt.stop()


def test_chrome_export_is_perfetto_shaped(tmp_path):
    rt = _traced_runtime(sample_rate=1.0, batch_wait_ms=20.0)
    try:
        _run_traced_chain(rt, name="chrome", n=4)
        path = tmp_path / "trace.json"
        export_chrome(rt.tracer, str(path), dag="chrome")
        doc = json.loads(path.read_text())
        evs = doc["traceEvents"]
        assert doc["displayTimeUnit"] == "ms"
        phases = {e["ph"] for e in evs}
        assert {"X", "M"} <= phases
        cats = {e.get("cat") for e in evs if e["ph"] == "X"}
        for cat in ("admission", "queue", "exec", "demux", "batch",
                    "request"):
            assert cat in cats, cats
        # every complete event is JSON-clean µs with non-negative duration
        for e in evs:
            if e["ph"] == "X":
                assert e["dur"] >= 0 and e["ts"] >= 0
        # flow arrows connect member exec spans to the shared batch span
        starts = [e for e in evs if e["ph"] == "s"]
        finishes = [e for e in evs if e["ph"] == "f"]
        assert starts and finishes
        assert {e["id"] for e in finishes} <= {e["id"] for e in starts}
        # batch spans live on their own process lane
        pids = {e["pid"] for e in evs if e.get("cat") == "batch"}
        assert pids and pids.isdisjoint(
            {e["pid"] for e in evs if e.get("cat") == "exec"})
    finally:
        rt.stop()


# ---------------------------------------------------------------------------
# attribution
# ---------------------------------------------------------------------------

def test_attribution_names_slow_node_dominant():
    rt = Runtime(n_cpu=4, net=NetModel(scale=0.0), batch_wait_ms=1.0,
                 tracer=Tracer(enabled=True, sample_rate=1.0))
    try:
        def fast(i: int) -> int:
            time.sleep(0.0003)
            return i

        def slow(i: int) -> int:
            time.sleep(0.004)
            return i

        fl = Dataflow([("i", int)])
        n1 = fl.map(fast, names=["i"], batching=True)
        n2 = n1.map(slow, names=["i"], batching=True)
        n3 = n2.map(fast, names=["i"], batching=True)
        fl.output = n3
        fl.deploy(rt, name="chain")
        futs = []
        for k in range(12):
            futs.append(rt.call_dag("chain", _t(k), deadline_s=0.010))
            time.sleep(0.003)
        for f in futs:
            try:
                f.result(timeout=10)
            except Exception:
                pass
        kept = rt.tracer.kept("chain")
        assert len(kept) == 12
        att = attribute(kept)
        node, component, seconds = att.dominant()
        assert node.endswith("/2:map"), (node, component)
        assert component == "service"
        assert seconds > 0
        # the report table renders and names the dominant contributor
        text = att.table()
        assert "dominant contributor:" in text
        assert node in text
        d = att.to_dict()
        assert d["dominant"]["node"] == node
        assert set(d["nodes"][node]) >= {"queue_s", "service_s", "total_s"}
    finally:
        rt.stop()


def test_attribution_folds_admission_and_slo_only_filter():
    tr = Tracer(enabled=True, sample_rate=1.0)
    t = tr.start("d")
    t.span("admission", 0.0, 0.002, action="admit")
    t.span("queue@n1", 0.002, 0.004)
    t.span("exec@n1", 0.004, 0.010, queue_s=0.001, exec_s=0.005,
           attempts=1)
    t.span("demux@n1", 0.010, 0.011)
    t.finish()
    t2 = tr.start("d")
    t2.span("admission", 0.0, 0.001, action="admit")
    t2.finish(slo_miss=True)
    att = attribute(tr.kept())
    assert att.n_traces == 2 and att.n_miss == 1
    assert att.nodes[REQUEST_NODE].admission_s == pytest.approx(0.003)
    n1 = att.nodes["n1"]
    assert n1.queue_s == pytest.approx(0.002 + 0.001)   # queue span + wait
    assert n1.service_s == pytest.approx(0.005)
    assert n1.transfer_s == pytest.approx(0.001)
    # slo_only drops the clean trace
    only = attribute(tr.kept(), slo_only=True)
    assert only.n_traces == 1 and only.n_miss == 1


def test_attribution_classifies_retry_gap():
    tr = Tracer(enabled=True, sample_rate=1.0)
    t = tr.start("d")
    t.span("retry@n1", 0.004, 0.004, attempt=2)
    # 10ms wall, 1ms queue + 3ms exec measured: 6ms unexplained gap on a
    # retried node is retry overhead, not service
    t.span("exec@n1", 0.0, 0.010, queue_s=0.001, exec_s=0.003,
           attempts=2)
    t.finish()
    att = attribute(tr.kept())
    n1 = att.nodes["n1"]
    assert n1.retry_s == pytest.approx(0.006)
    assert n1.service_s == pytest.approx(0.003)


# ---------------------------------------------------------------------------
# fault-aware estimator
# ---------------------------------------------------------------------------

def test_fault_stats_inflation():
    f = FaultStats()
    # zero rates: exactly unchanged
    assert f.inflate_p99(0.1, arrival_rate=100.0) == 0.1
    f = FaultStats(retry_rate=10.0, requeue_rate=10.0, detection_s=0.05)
    # 20% of requests disturbed: p99 * 1.2 + 0.2 * detection
    assert f.disturbed_fraction(100.0) == pytest.approx(0.2)
    assert f.inflate_p99(0.1, 100.0) == pytest.approx(0.1 * 1.2 + 0.01)
    # inflation is monotone in fault pressure and capped at p=1
    assert f.inflate_p99(0.1, 100.0) < \
        FaultStats(retry_rate=50.0, detection_s=0.05).inflate_p99(0.1, 100.0)
    assert FaultStats(retry_rate=1e9).disturbed_fraction(1.0) == 1.0


def test_controller_detail_carries_fault_inflation():
    # exercised end-to-end in test_slo_controller; here: the windowed
    # fault counters feed FaultStats through a live runtime
    rt = Runtime(n_cpu=1, net=NetModel(scale=0.0))
    try:
        now = obs_now()
        for _ in range(5):
            rt.record_metric("faults/retry_t", now)
        assert rt.metric_rate("faults/retry_t", window_s=10.0) >= 0.5
    finally:
        rt.stop()


# ---------------------------------------------------------------------------
# clock audit
# ---------------------------------------------------------------------------

def test_rate_windows_share_the_monotonic_clock():
    import repro.obs.clock as clock
    import repro.profiling.controller as controller
    import repro.runtime.runtime as runtime
    import repro.serving.admission as admission
    import repro.serving.retry as retry
    assert clock.now is time.perf_counter
    for mod in (runtime, admission, controller, retry):
        assert getattr(mod, "_mono") is clock.now, mod.__name__
    # trace timestamps come from the same clock: a span recorded "now"
    # nests inside perf_counter readings taken around it
    tr = Tracer(enabled=True, sample_rate=1.0)
    t0 = time.perf_counter()
    t = tr.start("d")
    s = t.event("retry@n")
    t1 = time.perf_counter()
    assert t0 <= s.t0 <= t1
