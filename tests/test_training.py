import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_tiny_config
from repro.models import build_model
from repro.training import checkpoint, optim
from repro.training.data import DataConfig, SyntheticLM
from repro.training.train_step import init_train_state, make_train_step


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    init, update = optim.make_optimizer(
        "adamw", optim.OptConfig(lr=0.1, warmup_steps=1, weight_decay=0.0))
    state = init(params)
    for _ in range(200):
        grads = jax.tree.map(lambda w: 2 * w, params)
        params, state, _ = update(params, grads, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_adafactor_minimizes_quadratic():
    params = {"w": jnp.ones((4, 4)) * 3.0}
    init, update = optim.make_optimizer(
        "adafactor", optim.OptConfig(lr=0.1, warmup_steps=1,
                                     weight_decay=0.0))
    state = init(params)
    for _ in range(200):
        grads = jax.tree.map(lambda w: 2 * w, params)
        params, state, _ = update(params, grads, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.2


def test_adafactor_factored_state_shapes():
    params = {"big": jnp.zeros((256, 512)), "small": jnp.zeros((8,))}
    init, _ = optim.make_optimizer("adafactor")
    st = init(params)
    assert st["v"]["big"]["vr"].shape == (256,)
    assert st["v"]["big"]["vc"].shape == (512,)
    assert st["v"]["small"]["v"].shape == (8,)


def test_grad_clip_scale():
    tree = {"a": jnp.ones((10,)) * 100.0}
    scale, norm = optim.clip_scale(tree, 1.0)
    assert float(norm) > 1.0
    assert float(scale) == pytest.approx(1.0 / float(norm), rel=1e-5)


def test_synthetic_data_deterministic():
    cfg = DataConfig(vocab_size=100, seq_len=32, batch_size=4, seed=7)
    a = SyntheticLM(cfg).batch()
    b = SyntheticLM(cfg).batch()
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 32)
    assert a["tokens"].max() < 100
    # labels are next-token-shifted
    # (tokens[t+1] == labels[t] by construction of the same sequence)


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_tiny_config("yi-9b")
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    path = str(tmp_path / "ckpt")
    checkpoint.save(path, state, 3)
    assert checkpoint.latest_step(path) == 3
    restored = checkpoint.restore(path, state)
    ok = jax.tree.map(
        lambda a, b: np.array_equal(np.asarray(a), np.asarray(b)),
        state, restored)
    assert all(jax.tree.leaves(ok))


def test_tiny_model_learns_synthetic():
    """End-to-end: loss on the motif dataset drops substantially."""
    cfg = get_tiny_config("yi-9b")
    model = build_model(cfg)
    opt = optim.OptConfig(lr=3e-3, warmup_steps=10)
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  batch_size=8, seed=0, num_motifs=4))
    losses = []
    for _ in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.batch().items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.0, losses[::6]


def test_grad_accum_matches_single_batch():
    """accum=2 over a duplicated microbatch == plain step on one micro."""
    import dataclasses
    cfg = get_tiny_config("glm4-9b")
    model1 = build_model(cfg)
    cfg2 = dataclasses.replace(cfg, grad_accum=2)
    model2 = build_model(cfg2)
    key = jax.random.PRNGKey(1)
    params = model1.init(key)
    state = {"params": params,
             "opt": optim.adamw_init(params)}
    tok = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    batch1 = {"tokens": tok}
    batch2 = {"tokens": jnp.concatenate([tok, tok])}
    s1, m1 = make_train_step(model1)(state, batch1)
    s2, m2 = make_train_step(model2)({"params": params,
                                      "opt": optim.adamw_init(params)},
                                     batch2)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-2)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        s1["params"], s2["params"])
    assert max(jax.tree.leaves(d)) < 1e-2
