"""Architecture-specific feature semantics (beyond shape smoke tests)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_tiny_config
from repro.models import build_model
from repro.models.transformer import block_layout
from repro.models import rglru as rglru_lib


def test_gemma2_local_global_block_layout():
    cfg = get_tiny_config("gemma2-9b")
    specs, n_blocks = block_layout(cfg)
    assert len(specs) == 2
    assert specs[0].window == cfg.sliding_window   # local layer
    assert specs[1].window == 0                    # global layer
    # long-context mode windows the global layers (DESIGN §5)
    specs_lc, _ = block_layout(cfg, long_context=True)
    assert specs_lc[1].window == cfg.sliding_window


def test_gemma2_softcap_bounds_logits():
    cfg = get_tiny_config("gemma2-9b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    # blow up the embedding scale: softcap must still bound final logits
    params["embed"] = params["embed"] * 100.0
    logits, _ = m.logits(params, {"tokens": jnp.ones((1, 8), jnp.int32)},
                         remat=False)
    assert float(jnp.max(jnp.abs(logits))) <= cfg.final_logit_softcap + 1e-3


def test_vlm_cross_attention_gate_starts_closed_then_opens():
    cfg = get_tiny_config("llama-3.2-vision-11b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jnp.ones((1, 8), jnp.int32)
    media_a = jnp.zeros((1, cfg.num_media_tokens, cfg.d_model), jnp.bfloat16)
    media_b = (jax.random.normal(
        jax.random.PRNGKey(1),
        (1, cfg.num_media_tokens, cfg.d_model)) * 0.1).astype(jnp.bfloat16)
    la, _ = m.logits(params, {"tokens": toks, "media": media_a}, remat=False)
    lb, _ = m.logits(params, {"tokens": toks, "media": media_b}, remat=False)
    # gate = tanh(0) = 0 at init: media must have NO effect (llama3.2 design)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-5)
    # open the gates: media must now change the logits
    for blk in params["blocks"].values():
        if "cross" in blk:
            blk["cross"]["gate"] = jnp.ones_like(blk["cross"]["gate"])
    la, _ = m.logits(params, {"tokens": toks, "media": media_a}, remat=False)
    lb, _ = m.logits(params, {"tokens": toks, "media": media_b}, remat=False)
    assert float(jnp.max(jnp.abs(la - lb))) > 1e-3


def test_whisper_encoder_frames_affect_decoder():
    cfg = get_tiny_config("whisper-medium")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jnp.ones((1, 8), jnp.int32)
    fa = jnp.zeros((1, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    fb = (jax.random.normal(jax.random.PRNGKey(1),
                            (1, cfg.encoder_seq, cfg.d_model))
          * 0.1).astype(jnp.bfloat16)
    la, _ = m.logits(params, {"tokens": toks, "frames": fa}, remat=False)
    lb, _ = m.logits(params, {"tokens": toks, "frames": fb}, remat=False)
    assert float(jnp.max(jnp.abs(la - lb))) > 1e-3  # cross-attn is ungated


def test_rglru_pattern_two_rec_one_attn():
    cfg = get_tiny_config("recurrentgemma-2b")
    pattern, n_blocks, rest = rglru_lib.layout(cfg)
    assert pattern == ["rec", "rec", "attn"]
    types = rglru_lib.layer_types(cfg)
    assert len(types) == cfg.num_layers
    assert types.count("attn") == cfg.num_layers // 3


def test_mqa_cache_has_single_kv_head():
    cfg = get_tiny_config("granite-34b")
    assert cfg.num_kv_heads == 1
    m = build_model(cfg)
    cache = m.init_cache(batch=2, cache_len=8)
    assert cache["k0"].shape[-2] == 1  # Kp == kv heads without a mesh


def test_moe_aux_loss_nonzero_and_dense_residual_present():
    cfg = get_tiny_config("arctic-480b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    assert "aux_mlp" in params["blocks"]["0"]       # dense residual
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    _, aux = m.logits(params, {"tokens": toks}, remat=False)
    assert float(aux) > 0.0


def test_llama4_interleaved_moe():
    cfg = get_tiny_config("llama4-maverick-400b-a17b")
    specs, n_blocks = block_layout(cfg)
    assert len(specs) == 2
    assert not specs[0].is_moe and specs[1].is_moe
    assert specs[1].aux_mlp  # shared expert


def test_rwkv_decay_in_unit_interval():
    cfg = get_tiny_config("rwkv6-1.6b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    # w = exp(-exp(w0 + lora)) must be in (0, 1): check w0 produces that
    w = jnp.exp(-jnp.exp(params["blocks"]["w0"]))
    assert float(w.min()) > 0.0 and float(w.max()) < 1.0


def test_sliding_window_limits_attention_reach():
    """A token far outside the window must not influence a local-only arch
    configured with window smaller than the distance."""
    cfg = dataclasses.replace(get_tiny_config("gemma2-9b"),
                              sliding_window=8, num_layers=2)
    # long_context mode windows BOTH layers (gemma2 long_context_windowed)
    m = build_model(cfg, long_context=True)
    params = m.init(jax.random.PRNGKey(0))
    base = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0,
                              cfg.vocab_size)
    changed = base.at[0, 0].set((base[0, 0] + 1) % cfg.vocab_size)
    la, _ = m.logits(params, {"tokens": base}, remat=False)
    lb, _ = m.logits(params, {"tokens": changed}, remat=False)
    # last position is > window away from position 0 in both layers
    np.testing.assert_allclose(np.asarray(la[:, -1]), np.asarray(lb[:, -1]),
                               atol=1e-2)
    assert float(jnp.max(jnp.abs(la[:, 0] - lb[:, 0]))) > 1e-3
