import pytest

from repro.core.table import Table, Row, schema_compatible


def test_insert_and_columns():
    t = Table([("a", int), ("b", str)])
    t.insert((1, "x"))
    t.insert((2, "y"))
    assert len(t) == 2
    assert t.columns == ["a", "b"]
    assert t.column("a") == [1, 2]
    assert t.column_index("b") == 1


def test_row_ids_unique_and_persistent():
    t = Table([("a", int)], [(1,), (2,), (3,)])
    ids = [r.row_id for r in t.rows]
    assert len(set(ids)) == 3
    r2 = t.rows[0].replace((99,))
    assert r2.row_id == t.rows[0].row_id
    assert r2.values == (99,)


def test_arity_mismatch():
    t = Table([("a", int), ("b", int)])
    with pytest.raises(ValueError):
        t.insert((1,))


def test_scalar_insert():
    t = Table([("a", int)])
    t.insert(5)
    assert t.rows[0].values == (5,)


def test_with_rows_preserves_schema_changes_grouping():
    t = Table([("a", int)], [(1,)], grouping=None)
    t2 = t.with_rows(t.rows, grouping="a")
    assert t2.grouping == "a"
    assert t2.schema == t.schema


def test_dict_roundtrip():
    t = Table.from_dicts([("a", int), ("b", str)],
                         [{"a": 1, "b": "x"}, {"a": 2, "b": "y"}])
    assert t.to_dicts() == [{"a": 1, "b": "x"}, {"a": 2, "b": "y"}]


def test_schema_compat():
    assert schema_compatible([("a", int)], [("z", int)])
    assert not schema_compatible([("a", int)], [("a", str)])
    assert not schema_compatible([("a", int)], [("a", int), ("b", int)])
