import pytest

from repro.core.table import Table, Row, schema_compatible


def test_insert_and_columns():
    t = Table([("a", int), ("b", str)])
    t.insert((1, "x"))
    t.insert((2, "y"))
    assert len(t) == 2
    assert t.columns == ["a", "b"]
    assert t.column("a") == [1, 2]
    assert t.column_index("b") == 1


def test_row_ids_unique_and_persistent():
    t = Table([("a", int)], [(1,), (2,), (3,)])
    ids = [r.row_id for r in t.rows]
    assert len(set(ids)) == 3
    r2 = t.rows[0].replace((99,))
    assert r2.row_id == t.rows[0].row_id
    assert r2.values == (99,)


def test_arity_mismatch():
    t = Table([("a", int), ("b", int)])
    with pytest.raises(ValueError):
        t.insert((1,))


def test_scalar_insert():
    t = Table([("a", int)])
    t.insert(5)
    assert t.rows[0].values == (5,)


def test_with_rows_preserves_schema_changes_grouping():
    t = Table([("a", int)], [(1,)], grouping=None)
    t2 = t.with_rows(t.rows, grouping="a")
    assert t2.grouping == "a"
    assert t2.schema == t.schema


def test_dict_roundtrip():
    t = Table.from_dicts([("a", int), ("b", str)],
                         [{"a": 1, "b": "x"}, {"a": 2, "b": "y"}])
    assert t.to_dicts() == [{"a": 1, "b": "x"}, {"a": 2, "b": "y"}]


def test_schema_compat():
    assert schema_compatible([("a", int)], [("z", int)])
    assert not schema_compatible([("a", int)], [("a", str)])
    assert not schema_compatible([("a", int)], [("a", int), ("b", int)])


# ---------------------------------------------------------------------------
# DeviceTable: device-resident columnar batches
# ---------------------------------------------------------------------------

def _dt_table(jnp, n=3, dim=4):
    import jax
    return Table([("x", jax.Array)],
                 [(jnp.ones(dim) * (i + 1),) for i in range(n)])


def test_device_table_roundtrip_preserves_identity():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.core.table import DeviceTable

    t = _dt_table(jnp)
    t.rows[1].group = "g"
    dt = DeviceTable.from_table(t, pad_to=4)
    assert len(dt) == 3 and dt.cap == 4 and dt.donatable
    assert dt.column_index("x") == 0
    back = dt.to_table()
    assert [r.row_id for r in back.rows] == [r.row_id for r in t.rows]
    assert back.rows[1].group == "g"
    for a, b in zip(back.rows, t.rows):
        import numpy as np
        np.testing.assert_allclose(np.asarray(a.values[0]),
                                   np.asarray(b.values[0]))


def test_device_table_rejects_ragged_rows():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.core.table import DeviceTable

    t = Table([("x", jax.Array)], [(jnp.ones(4),), (jnp.ones(8),)])
    with pytest.raises(ValueError):
        DeviceTable.from_table(t)


def test_device_table_take_pads_and_masks():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    import numpy as np

    from repro.core.table import DeviceTable

    t = _dt_table(jnp, n=4)
    dt = DeviceTable.from_table(t, pad_to=4)
    part = dt.take([1, 2], pad_to=4)       # re-padded to the bucket
    assert part.nrows == 2 and part.cap == 4 and part.mask is not None
    out = part.to_table()
    assert [r.row_id for r in out.rows] == [t.rows[1].row_id,
                                            t.rows[2].row_id]
    np.testing.assert_allclose(np.asarray(out.rows[0].values[0]),
                               np.full(4, 2.0))


def test_device_table_host_copy_accounting():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.core.table import (DeviceTable, HOST_COPIES,
                                  reset_host_copies)

    reset_host_copies()
    dt = DeviceTable.from_table(_dt_table(jnp), pad_to=4)
    assert HOST_COPIES == {"stacks": 1, "gathers": 0}
    dt.take([0, 1])                        # device-side: no host copy
    assert HOST_COPIES == {"stacks": 1, "gathers": 0}
    dt.to_table()
    assert HOST_COPIES == {"stacks": 1, "gathers": 1}
