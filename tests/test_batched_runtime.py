"""Batched dispatch path through the runtime + Batcher robustness.

* the merged table feeds straight into the batched callable (one vmapped
  XLA dispatch per batch), results demultiplex back per request without
  per-request waiter threads;
* empty requests and zero-row tables don't crash the batch;
* duplicate ``row_id``s across requests demux exactly (no duplication, no
  drops — the old set-membership filter did both);
* ``locality_key`` steers batched placement to cache-warm executors;
* per-node batch-size/latency metrics land in ``Runtime.metrics``;
* ``Batcher`` close/drain is safe under concurrent submitters.
"""
import threading
import time

import numpy as np
import pytest

from repro.core.dataflow import Dataflow
from repro.core.table import Row, Table
from repro.runtime.netmodel import NetModel
from repro.runtime.runtime import Runtime
from repro.serving.batcher import Batcher


@pytest.fixture
def rt():
    r = Runtime(n_cpu=4, net=NetModel(scale=0.0), batch_wait_ms=5.0)
    yield r
    r.stop()


def _batched_flow(rt, fn=None):
    if fn is None:
        def fn(x: int) -> int:
            return x * 10
    fl = Dataflow([("x", int)])
    fl.output = fl.map(fn, names=["y"], batching=True)
    fl.deploy(rt)
    return fl


def test_batched_demux_concurrent_requests(rt):
    fl = _batched_flow(rt)
    futs = [fl.execute(Table([("x", int)], [(i,)])) for i in range(12)]
    outs = [f.result(timeout=10).rows[0].values[0] for f in futs]
    assert outs == [i * 10 for i in range(12)]
    b = rt._batchers[next(iter(rt._batchers))]
    assert max(b.batch_sizes) > 1


def test_empty_table_request_through_batching(rt):
    """A zero-row request used to crash the batch fn (merged[0] on an
    empty merge) — it must come back as an empty result instead."""
    fl = _batched_flow(rt)
    empty = fl.execute(Table([("x", int)]))
    full = fl.execute(Table([("x", int)], [(3,)]))
    assert len(empty.result(timeout=10)) == 0
    assert full.result(timeout=10).rows[0].values[0] == 30


def test_all_empty_batch(rt):
    fl = _batched_flow(rt)
    futs = [fl.execute(Table([("x", int)])) for _ in range(4)]
    assert all(len(f.result(timeout=10)) == 0 for f in futs)


def test_duplicate_row_ids_demux_exactly(rt):
    """Two requests sharing a row_id each get exactly their own row back
    (the old set-membership demux handed both rows to both requests)."""
    fl = _batched_flow(rt)
    t1 = Table([("x", int)])
    t1.insert(Row((7,), row_id=999))
    t2 = Table([("x", int)])
    t2.insert(Row((8,), row_id=999))
    f1, f2 = fl.execute(t1), fl.execute(t2)
    r1, r2 = f1.result(timeout=10), f2.result(timeout=10)
    assert len(r1) == 1 and len(r2) == 1
    assert sorted([r1.rows[0].values[0], r2.rows[0].values[0]]) == [70, 80]


def test_batched_filter_demux_by_row_id(rt):
    """When the fn drops rows (count changes), demux falls back to row-id
    matching and dropped rows simply vanish from their request."""
    def keep_even(x: int) -> bool:
        return x % 2 == 0

    fl = Dataflow([("x", int)])
    fl.output = fl.filter(keep_even, batching=True)
    fl.deploy(rt)
    futs = [fl.execute(Table([("x", int)], [(i,)])) for i in range(6)]
    outs = [f.result(timeout=10) for f in futs]
    assert [len(o) for o in outs] == [1, 0, 1, 0, 1, 0]


def test_batch_metrics_recorded(rt):
    fl = _batched_flow(rt)
    futs = [fl.execute(Table([("x", int)], [(i,)])) for i in range(6)]
    for f in futs:
        f.result(timeout=10)
    size_keys = [k for k in rt.metrics if k.endswith("/size")]
    lat_keys = [k for k in rt.metrics if k.endswith("/latency_s")]
    exec_keys = [k for k in rt.metrics if k.endswith("/exec_s")]
    assert size_keys and lat_keys and exec_keys
    assert sum(rt.metrics[size_keys[0]]) == 6
    assert all(v >= 0 for v in rt.metrics[lat_keys[0]])


def test_batched_error_reaches_every_request(rt):
    def boom(x: int) -> int:
        raise RuntimeError("model exploded")

    fl = _batched_flow(rt, fn=boom)
    futs = [fl.execute(Table([("x", int)], [(i,)])) for i in range(3)]
    for f in futs:
        with pytest.raises(RuntimeError, match="model exploded"):
            f.result(timeout=10)


def test_locality_key_propagates_into_batched_dispatch():
    """Batched nodes get cache-local placement: with a fused lookup and
    batching, requests land on the executor already caching the ref."""
    rt = Runtime(n_cpu=4, net=NetModel(scale=0.0), batch_wait_ms=2.0)
    try:
        rt.kvs.put("hot", np.zeros(1000), charge=False)
        ex = rt.pool.by_class("cpu")[2]
        ex.cache.get("hot")                 # warm exactly one executor

        def use(key: str, lookup) -> int:
            return 1

        fl = Dataflow([("key", str)])
        fl.output = fl.lookup("key", column=True).map(
            use, names=["v"], batching=True)
        fl.deploy(rt, locality=True)
        for _ in range(6):
            fl.execute(Table([("key", str)],
                             [("hot",)])).result(timeout=10)
        # all lookups after the first warm hit the cached executor
        assert ex.cache.hits >= 5
    finally:
        rt.stop()


# ---------------------------------------------------------------------------
# Device-resident pipelines through the runtime
# ---------------------------------------------------------------------------

def _device_chain_flow(jax, jnp, batching_first=False):
    from repro.core.dataflow import Dataflow

    def g1(x: jax.Array) -> jax.Array:
        return jnp.tanh(x * 1.01 + 0.1)

    def g2(x: jax.Array) -> jax.Array:
        return x * x - 0.5 * x

    fl = Dataflow([("x", jax.Array)])
    fl.output = fl.map(g1, names=["x"], gpu=True, batching=batching_first) \
        .map(g2, names=["x"], gpu=True)
    return fl, (g1, g2)


def test_device_chain_performs_exactly_one_device_get(monkeypatch):
    """A two-GPU-node chain hands a DeviceTable from the first node's
    executor callback straight to the second node: ONE host->device stack
    at entry, ONE device_get at the output boundary — not one per node."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.core.compiler import compile_flow
    from repro.core.passes import LowerJaxChainsPass, PassPipeline
    from repro.core.table import Table as T

    gets = {"n": 0}
    real_get = jax.device_get

    def counting_get(*a, **kw):
        gets["n"] += 1
        return real_get(*a, **kw)

    rt2 = Runtime(n_cpu=2, n_gpu=1, net=NetModel(scale=0.0))
    try:
        fl, (g1, g2) = _device_chain_flow(jax, jnp)
        # no fusion pass: the two maps stay separate DAG nodes, each
        # individually lowered (min_ops=1) -> a device-resident edge
        dep = compile_flow(fl, rt2, pipeline=PassPipeline(
            [LowerJaxChainsPass(min_ops=1)]))
        nodes = dep.dag.topo()
        assert [n.device_resident for n in nodes] == [True, True]
        assert [n.emits_device for n in nodes] == [True, False]
        # host (numpy) request payloads, as they arrive off the network —
        # the chain entry then pays uploads only, and the single counted
        # device_get is the output-boundary gather
        t = T([("x", jax.Array)],
              [(np.linspace(-1.0, 1.0, 8) * (i + 1),) for i in range(3)])
        # warm the executables (compile-time device_gets are not the claim)
        dep.execute(t).result(timeout=30)
        monkeypatch.setattr(jax, "device_get", counting_get)
        out = dep.execute(t).result(timeout=30)
        monkeypatch.undo()
        assert gets["n"] == 1
        assert [r.row_id for r in out.rows] == [r.row_id for r in t.rows]
        for r_in, r_out in zip(t.rows, out.rows):
            np.testing.assert_allclose(
                np.asarray(r_out.values[0]),
                np.asarray(g2(g1(r_in.values[0]))), rtol=1e-6)
    finally:
        rt2.stop()


def test_device_chain_demux_after_batching_node():
    """A request-batching first stage emits ONE merged DeviceTable; the
    demux slices it per request on the device (no host copy) and each
    request's slice flows through the second stage correctly."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.core.compiler import compile_flow
    from repro.core.passes import LowerJaxChainsPass, PassPipeline
    from repro.core.table import Table as T

    rt2 = Runtime(n_cpu=2, n_gpu=1, net=NetModel(scale=0.0),
                  batch_wait_ms=3.0)
    try:
        fl, (g1, g2) = _device_chain_flow(jax, jnp, batching_first=True)
        dep = compile_flow(fl, rt2, pipeline=PassPipeline(
            [LowerJaxChainsPass(min_ops=1)]))
        assert [n.emits_device for n in dep.dag.topo()] == [True, False]
        futs = [dep.execute(T([("x", jax.Array)],
                              [(jnp.ones(8) * (i + 1),),
                               (jnp.ones(8) * (i + 10),)]))
                for i in range(6)]
        for i, f in enumerate(futs):
            out = f.result(timeout=30)
            assert len(out) == 2
            for j, scale in enumerate((i + 1, i + 10)):
                np.testing.assert_allclose(
                    np.asarray(out.rows[j].values[0]),
                    np.asarray(g2(g1(jnp.ones(8) * scale))), rtol=1e-6)
    finally:
        rt2.stop()


def test_device_demux_fanout_does_not_donate_shared_slices():
    """A batching device node feeding TWO device consumers: the demuxed
    per-request slice reaches both, so neither may donate its buffers —
    donation would delete arrays the sibling still needs."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.core.compiler import compile_flow
    from repro.core.dataflow import Dataflow
    from repro.core.passes import LowerJaxChainsPass, PassPipeline
    from repro.core.table import Table as T

    def g1(x: jax.Array) -> jax.Array:
        return jnp.tanh(x * 1.01 + 0.1)

    def g2(x: jax.Array) -> jax.Array:
        return x * 2.0

    def g3(x: jax.Array) -> jax.Array:
        return x + 1.0

    rt2 = Runtime(n_cpu=2, n_gpu=1, net=NetModel(scale=0.0),
                  batch_wait_ms=3.0)
    try:
        fl = Dataflow([("x", jax.Array)])
        a = fl.map(g1, names=["x"], gpu=True, batching=True)
        fl.output = a.map(g2, names=["x"], gpu=True).union(
            a.map(g3, names=["x"], gpu=True))
        dep = compile_flow(fl, rt2, pipeline=PassPipeline(
            [LowerJaxChainsPass(min_ops=1)]))
        emitter = next(n for n in dep.dag.nodes.values() if n.batching)
        assert emitter.emits_device
        futs = [dep.execute(T([("x", jax.Array)],
                              [(jnp.ones(8) * (i + 1),)]))
                for i in range(6)]
        for i, f in enumerate(futs):
            out = f.result(timeout=30)       # donation bug: one branch
            assert len(out) == 2             # ran on deleted arrays
            got = sorted(float(np.asarray(r.values[0])[0]) for r in out.rows)
            h = float(np.asarray(g1(jnp.ones(8) * (i + 1)))[0])
            want = sorted([h * 2.0, h + 1.0])
            np.testing.assert_allclose(got, want, rtol=1e-6)
    finally:
        rt2.stop()


def test_device_edge_consumer_pinned_to_producer_executor():
    """With several GPU executors, a node consuming a DeviceTable must run
    on the executor that produced it — the batch lives in that machine's
    device memory, so placing the consumer elsewhere would be the very
    host/network hop the residency analysis claims to eliminate."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.core.compiler import compile_flow
    from repro.core.passes import LowerJaxChainsPass, PassPipeline
    from repro.core.table import DeviceTable
    from repro.core.table import Table as T

    rt2 = Runtime(n_cpu=2, n_gpu=3, net=NetModel(scale=0.0), seed=7)
    shipped = []
    orig_charge = rt2.net.charge
    rt2.net.charge = lambda nbytes: (shipped.append(nbytes),
                                     orig_charge(nbytes))[1]
    try:
        fl, (g1, g2) = _device_chain_flow(jax, jnp)
        dep = compile_flow(fl, rt2, pipeline=PassPipeline(
            [LowerJaxChainsPass(min_ops=1)]))
        assert [n.emits_device for n in dep.dag.topo()] == [True, False]
        for i in range(8):
            out = dep.execute(T([("x", jax.Array)],
                                [(jnp.ones(8) * (i + 1),),
                                 (jnp.ones(8) * (i + 2),)])) \
                .result(timeout=30)
            assert len(out) == 2
        # no DeviceTable ever crossed executors -> no network charge for
        # device-resident inputs (host inputs come from the source: free)
        assert shipped == []
    finally:
        rt2.stop()


def test_device_residency_off_restores_per_node_gathers():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.core.ir import PhysicalPlan
    from repro.core.passes import LowerJaxChainsPass, PassPipeline
    from repro.runtime.dag import RuntimeDag

    fl, _ = _device_chain_flow(jax, jnp)
    plan = PassPipeline([LowerJaxChainsPass(min_ops=1)]).run(
        PhysicalPlan.from_dataflow(fl))
    dag = RuntimeDag.from_plan(plan, "staged", device_resident=False)
    assert all(not n.emits_device for n in dag.nodes.values())


# ---------------------------------------------------------------------------
# Adaptive batch-wait deadline
# ---------------------------------------------------------------------------

def test_adaptive_wait_full_window_under_dense_traffic():
    b = Batcher(lambda args: list(args), max_batch=64, max_wait_ms=100.0)
    try:
        for i in range(8):                    # back-to-back arrivals
            b.submit(i)
        assert b.effective_wait() == b.max_wait
    finally:
        b.close()


def test_adaptive_wait_shrinks_toward_zero_when_sparse():
    """After sparse arrivals (gaps beyond the window) a lone request must
    not sit out the full wait window."""
    b = Batcher(lambda args: list(args), max_batch=64, max_wait_ms=300.0)
    try:
        for i in range(3):                    # train the gap EWMA: ~0.5s
            b.call(i, timeout=5.0)
            time.sleep(0.5)
        assert b.effective_wait() < 0.05
        t0 = time.perf_counter()
        b.call(99, timeout=5.0)               # lone request
        assert time.perf_counter() - t0 < 0.15   # far below the 0.3s window
        # gap samples are clamped, so a dense burst after the idle spell
        # recovers the full window within a few arrivals (submit, not
        # call: a sequential caller's gaps include the wait itself)
        for i in range(10):
            b.submit(i)
        assert b.effective_wait() == b.max_wait
    finally:
        b.close()


def test_adaptive_wait_disabled_keeps_fixed_deadline():
    b = Batcher(lambda args: list(args), max_batch=4, max_wait_ms=50.0,
                adaptive_wait=False)
    try:
        b.call(1, timeout=5.0)
        time.sleep(0.2)
        b.call(2, timeout=5.0)
        assert b.effective_wait() == b.max_wait
    finally:
        b.close()


# ---------------------------------------------------------------------------
# Batcher close/drain robustness
# ---------------------------------------------------------------------------

def test_batcher_close_fails_queued_items_fast():
    started = threading.Event()

    def slow_fn(args):
        started.set()
        time.sleep(0.3)
        return [a for a in args]

    b = Batcher(slow_fn, max_batch=1, max_wait_ms=1.0)
    b.submit(1)                      # occupies the loop in slow_fn
    started.wait(2.0)
    tail = b.submit(2)               # queued behind the slow batch
    t0 = time.perf_counter()
    b.close()
    assert tail.event.wait(1.0)      # failed promptly, not after timeout
    assert isinstance(tail.error, RuntimeError)
    assert time.perf_counter() - t0 < 2.0


def test_batcher_submit_after_close_raises():
    b = Batcher(lambda args: list(args))
    b.close()
    with pytest.raises(RuntimeError):
        b.submit(1)
    b.close()                        # idempotent


def test_batcher_close_race_under_concurrent_submitters():
    """Hammer submit() from many threads while close() lands: every call
    must either complete or fail fast — nothing hangs, nothing is lost."""
    b = Batcher(lambda args: [a * 2 for a in args],
                max_batch=4, max_wait_ms=0.5)
    results, errors = [], []
    lock = threading.Lock()

    def submitter(i):
        try:
            r = b.call(i, timeout=5.0)
            with lock:
                results.append(r)
        except (RuntimeError, TimeoutError) as e:
            with lock:
                errors.append(e)

    threads = [threading.Thread(target=submitter, args=(i,))
               for i in range(32)]
    for i, t in enumerate(threads):
        t.start()
        if i == 16:
            time.sleep(0.005)
            b.close()
    for t in threads:
        t.join(timeout=6.0)
        assert not t.is_alive()
    assert len(results) + len(errors) == 32
    assert all(isinstance(r, int) for r in results)
    # nothing may sit in the queue after close
    assert b.q.empty()


def test_batcher_drain_on_reregistration(rt):
    """Re-registering under the SAME dag name retires the old batchers;
    requests before and after the swap both complete."""
    from repro.core.compiler import compile_flow

    def mk():
        def model(x: int) -> int:
            return x * 10
        fl = Dataflow([("x", int)])
        fl.output = fl.map(model, names=["y"], batching=True)
        return compile_flow(fl, rt, name="redep")

    d1 = mk()
    assert d1.execute(Table([("x", int)], [(1,)])) \
        .result(timeout=10).rows[0].values[0] == 10
    d2 = mk()                        # re-registers "redep"
    assert d2.execute(Table([("x", int)], [(2,)])) \
        .result(timeout=10).rows[0].values[0] == 20
    # the old deployment's batcher was retired (and closed once drained)
    assert rt._batchers                     # fresh batcher exists
