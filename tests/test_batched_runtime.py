"""Batched dispatch path through the runtime + Batcher robustness.

* the merged table feeds straight into the batched callable (one vmapped
  XLA dispatch per batch), results demultiplex back per request without
  per-request waiter threads;
* empty requests and zero-row tables don't crash the batch;
* duplicate ``row_id``s across requests demux exactly (no duplication, no
  drops — the old set-membership filter did both);
* ``locality_key`` steers batched placement to cache-warm executors;
* per-node batch-size/latency metrics land in ``Runtime.metrics``;
* ``Batcher`` close/drain is safe under concurrent submitters.
"""
import threading
import time

import numpy as np
import pytest

from repro.core.dataflow import Dataflow
from repro.core.table import Row, Table
from repro.runtime.netmodel import NetModel
from repro.runtime.runtime import Runtime
from repro.serving.batcher import Batcher


@pytest.fixture
def rt():
    r = Runtime(n_cpu=4, net=NetModel(scale=0.0), batch_wait_ms=5.0)
    yield r
    r.stop()


def _batched_flow(rt, fn=None):
    if fn is None:
        def fn(x: int) -> int:
            return x * 10
    fl = Dataflow([("x", int)])
    fl.output = fl.map(fn, names=["y"], batching=True)
    fl.deploy(rt)
    return fl


def test_batched_demux_concurrent_requests(rt):
    fl = _batched_flow(rt)
    futs = [fl.execute(Table([("x", int)], [(i,)])) for i in range(12)]
    outs = [f.result(timeout=10).rows[0].values[0] for f in futs]
    assert outs == [i * 10 for i in range(12)]
    b = rt._batchers[next(iter(rt._batchers))]
    assert max(b.batch_sizes) > 1


def test_empty_table_request_through_batching(rt):
    """A zero-row request used to crash the batch fn (merged[0] on an
    empty merge) — it must come back as an empty result instead."""
    fl = _batched_flow(rt)
    empty = fl.execute(Table([("x", int)]))
    full = fl.execute(Table([("x", int)], [(3,)]))
    assert len(empty.result(timeout=10)) == 0
    assert full.result(timeout=10).rows[0].values[0] == 30


def test_all_empty_batch(rt):
    fl = _batched_flow(rt)
    futs = [fl.execute(Table([("x", int)])) for _ in range(4)]
    assert all(len(f.result(timeout=10)) == 0 for f in futs)


def test_duplicate_row_ids_demux_exactly(rt):
    """Two requests sharing a row_id each get exactly their own row back
    (the old set-membership demux handed both rows to both requests)."""
    fl = _batched_flow(rt)
    t1 = Table([("x", int)])
    t1.insert(Row((7,), row_id=999))
    t2 = Table([("x", int)])
    t2.insert(Row((8,), row_id=999))
    f1, f2 = fl.execute(t1), fl.execute(t2)
    r1, r2 = f1.result(timeout=10), f2.result(timeout=10)
    assert len(r1) == 1 and len(r2) == 1
    assert sorted([r1.rows[0].values[0], r2.rows[0].values[0]]) == [70, 80]


def test_batched_filter_demux_by_row_id(rt):
    """When the fn drops rows (count changes), demux falls back to row-id
    matching and dropped rows simply vanish from their request."""
    def keep_even(x: int) -> bool:
        return x % 2 == 0

    fl = Dataflow([("x", int)])
    fl.output = fl.filter(keep_even, batching=True)
    fl.deploy(rt)
    futs = [fl.execute(Table([("x", int)], [(i,)])) for i in range(6)]
    outs = [f.result(timeout=10) for f in futs]
    assert [len(o) for o in outs] == [1, 0, 1, 0, 1, 0]


def test_batch_metrics_recorded(rt):
    fl = _batched_flow(rt)
    futs = [fl.execute(Table([("x", int)], [(i,)])) for i in range(6)]
    for f in futs:
        f.result(timeout=10)
    size_keys = [k for k in rt.metrics if k.endswith("/size")]
    lat_keys = [k for k in rt.metrics if k.endswith("/latency_s")]
    exec_keys = [k for k in rt.metrics if k.endswith("/exec_s")]
    assert size_keys and lat_keys and exec_keys
    assert sum(rt.metrics[size_keys[0]]) == 6
    assert all(v >= 0 for v in rt.metrics[lat_keys[0]])


def test_batched_error_reaches_every_request(rt):
    def boom(x: int) -> int:
        raise RuntimeError("model exploded")

    fl = _batched_flow(rt, fn=boom)
    futs = [fl.execute(Table([("x", int)], [(i,)])) for i in range(3)]
    for f in futs:
        with pytest.raises(RuntimeError, match="model exploded"):
            f.result(timeout=10)


def test_locality_key_propagates_into_batched_dispatch():
    """Batched nodes get cache-local placement: with a fused lookup and
    batching, requests land on the executor already caching the ref."""
    rt = Runtime(n_cpu=4, net=NetModel(scale=0.0), batch_wait_ms=2.0)
    try:
        rt.kvs.put("hot", np.zeros(1000), charge=False)
        ex = rt.pool.by_class("cpu")[2]
        ex.cache.get("hot")                 # warm exactly one executor

        def use(key: str, lookup) -> int:
            return 1

        fl = Dataflow([("key", str)])
        fl.output = fl.lookup("key", column=True).map(
            use, names=["v"], batching=True)
        fl.deploy(rt, locality=True)
        for _ in range(6):
            fl.execute(Table([("key", str)],
                             [("hot",)])).result(timeout=10)
        # all lookups after the first warm hit the cached executor
        assert ex.cache.hits >= 5
    finally:
        rt.stop()


# ---------------------------------------------------------------------------
# Batcher close/drain robustness
# ---------------------------------------------------------------------------

def test_batcher_close_fails_queued_items_fast():
    started = threading.Event()

    def slow_fn(args):
        started.set()
        time.sleep(0.3)
        return [a for a in args]

    b = Batcher(slow_fn, max_batch=1, max_wait_ms=1.0)
    b.submit(1)                      # occupies the loop in slow_fn
    started.wait(2.0)
    tail = b.submit(2)               # queued behind the slow batch
    t0 = time.perf_counter()
    b.close()
    assert tail.event.wait(1.0)      # failed promptly, not after timeout
    assert isinstance(tail.error, RuntimeError)
    assert time.perf_counter() - t0 < 2.0


def test_batcher_submit_after_close_raises():
    b = Batcher(lambda args: list(args))
    b.close()
    with pytest.raises(RuntimeError):
        b.submit(1)
    b.close()                        # idempotent


def test_batcher_close_race_under_concurrent_submitters():
    """Hammer submit() from many threads while close() lands: every call
    must either complete or fail fast — nothing hangs, nothing is lost."""
    b = Batcher(lambda args: [a * 2 for a in args],
                max_batch=4, max_wait_ms=0.5)
    results, errors = [], []
    lock = threading.Lock()

    def submitter(i):
        try:
            r = b.call(i, timeout=5.0)
            with lock:
                results.append(r)
        except (RuntimeError, TimeoutError) as e:
            with lock:
                errors.append(e)

    threads = [threading.Thread(target=submitter, args=(i,))
               for i in range(32)]
    for i, t in enumerate(threads):
        t.start()
        if i == 16:
            time.sleep(0.005)
            b.close()
    for t in threads:
        t.join(timeout=6.0)
        assert not t.is_alive()
    assert len(results) + len(errors) == 32
    assert all(isinstance(r, int) for r in results)
    # nothing may sit in the queue after close
    assert b.q.empty()


def test_batcher_drain_on_reregistration(rt):
    """Re-registering under the SAME dag name retires the old batchers;
    requests before and after the swap both complete."""
    from repro.core.compiler import compile_flow

    def mk():
        def model(x: int) -> int:
            return x * 10
        fl = Dataflow([("x", int)])
        fl.output = fl.map(model, names=["y"], batching=True)
        return compile_flow(fl, rt, name="redep")

    d1 = mk()
    assert d1.execute(Table([("x", int)], [(1,)])) \
        .result(timeout=10).rows[0].values[0] == 10
    d2 = mk()                        # re-registers "redep"
    assert d2.execute(Table([("x", int)], [(2,)])) \
        .result(timeout=10).rows[0].values[0] == 20
    # the old deployment's batcher was retired (and closed once drained)
    assert rt._batchers                     # fresh batcher exists
