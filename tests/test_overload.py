"""Overload protection: deadline-aware admission control, request
classes, and degraded serving.

* typed fast-fail: shed requests carry ``Overloaded`` (class, reason,
  estimate); requests whose deadline passes in a queue carry
  ``DeadlineExceeded`` and never reach a dispatch;
* the Batcher orders batches earliest-deadline-first and expires
  past-deadline items before dispatch (``expired`` counter + on_drop);
* ``Batcher.call``'s timeout path counts the item as completed, so
  ``quiescent()`` cannot wedge generation retirement;
* the admission gate is priority-ordered: a class's estimate is computed
  at the arrival rate of traffic at-or-above its priority, so
  best-effort traffic sheds/degrades first while interactive traffic is
  modeled against only its peers;
* an open-loop burst at 3x the saturating rate: interactive goodput p99
  meets the SLO while best_effort is shed/degraded, counters reconcile.
"""
import threading
import time

import pytest

from repro.core.dataflow import Dataflow
from repro.core.lowering import DegradePolicy, active_degrade, \
    degraded_execution
from repro.core.table import Table
from repro.runtime.netmodel import NetModel
from repro.runtime.runtime import Runtime
from repro.serving.admission import (AdmissionController, ClassPolicy,
                                     DeadlineExceeded, Overloaded,
                                     TokenBucket)
from repro.serving.batcher import Batcher


# ---------------------------------------------------------------------------
# Batcher: EDF ordering, expiry, call-timeout accounting
# ---------------------------------------------------------------------------

def test_batcher_call_timeout_counts_as_completed():
    """A timed-out ``call`` must not leave the accepted-minus-completed
    counter dangling: pre-fix, ``quiescent()`` stayed False forever and
    wedged generation retirement."""
    release = threading.Event()

    def fn(args):
        release.wait(10.0)
        return [a * 2 for a in args]

    b = Batcher(fn, max_batch=1, max_wait_ms=0.0)
    try:
        # the first item occupies the flush loop; the second times out
        # while queued behind it
        first = b.submit(1)
        with pytest.raises(TimeoutError):
            b.call(2, timeout=0.15)
        release.set()
        assert first.event.wait(5.0)
        deadline = time.perf_counter() + 5.0
        while not b.quiescent():
            assert time.perf_counter() < deadline, \
                "timed-out call wedged quiescent()"
            time.sleep(0.01)
    finally:
        release.set()
        b.close()


def test_batcher_call_returns_result_and_stays_quiescent():
    b = Batcher(lambda args: [a + 1 for a in args], max_batch=4,
                max_wait_ms=0.0)
    try:
        assert b.call(41, timeout=5.0) == 42
        assert b.quiescent()
    finally:
        b.close()


def test_batcher_orders_batch_earliest_deadline_first():
    release = threading.Event()
    seen = []

    def fn(args):
        if args == ["gate"]:
            release.wait(10.0)
        else:
            seen.extend(args)
        return list(args)

    # long fixed window so the three queued items pool into ONE flush
    b = Batcher(fn, max_batch=4, max_wait_ms=100.0, adaptive_wait=False)
    try:
        gate = b.submit("gate")
        time.sleep(0.25)                 # gate batch dispatched alone,
        now = time.perf_counter()        # its fn now blocks the loop
        b.submit("late", deadline_t=now + 30.0)
        b.submit("soon", deadline_t=now + 10.0)
        b.submit("never")                # deadline-less rides behind
        release.set()
        assert gate.event.wait(5.0)
        deadline = time.perf_counter() + 5.0
        while len(seen) < 3 and time.perf_counter() < deadline:
            time.sleep(0.01)
        # submitted late-soon-never; dispatched earliest-deadline-first
        assert seen == ["soon", "late", "never"]
    finally:
        release.set()
        b.close()


def test_batcher_expires_past_deadline_items_before_dispatch():
    release = threading.Event()
    ran, dropped = [], []

    def fn(args):
        if args == ["gate"]:
            release.wait(10.0)
        else:
            ran.extend(args)
        return list(args)

    b = Batcher(fn, max_batch=4, max_wait_ms=0.0,
                on_drop=lambda args, err: dropped.append((args, err)))
    try:
        gate = b.submit("gate")
        time.sleep(0.05)                 # gate's fn occupies the loop
        doomed = b.submit("doomed",
                          deadline_t=time.perf_counter() - 0.001)
        ok = b.submit("ok")
        release.set()
        assert gate.event.wait(5.0)
        assert doomed.event.wait(5.0)
        assert isinstance(doomed.error, DeadlineExceeded)
        assert ok.event.wait(5.0) and ok.error is None
        assert "doomed" not in ran          # never reached a dispatch
        assert b.expired == 1
        assert len(dropped) == 1 and dropped[0][0] == "doomed"
        assert b.quiescent()
    finally:
        release.set()
        b.close()


# ---------------------------------------------------------------------------
# admission gate: token buckets, priority ordering, degrade-not-shed
# ---------------------------------------------------------------------------

def test_token_bucket_limits_and_refills():
    tb = TokenBucket(rate=100.0, burst=2)
    assert tb.try_take() and tb.try_take()
    assert not tb.try_take()
    time.sleep(0.03)                     # ~3 tokens refilled (cap 2)
    assert tb.try_take()


def test_admission_rate_limit_sheds_with_reason():
    adm = AdmissionController(classes={
        "best_effort": ClassPolicy("best_effort", priority=0,
                                   rate=1.0, burst=1)})
    first = adm.admit("best_effort")
    second = adm.admit("best_effort")
    assert first.admitted
    assert second.action == "shed" and second.reason == "rate_limit"
    c = adm.snapshot()
    assert c["best_effort/offered"] == 2
    assert c["best_effort/admitted"] + c["best_effort/shed"] == 2


class _RateGate(AdmissionController):
    """Estimator stub: p99 proportional to the modeled arrival rate, so
    the priority ordering is observable without a real plan."""

    def _estimate_p99(self, lam: float) -> float:
        return 0.01 * lam


def test_priority_ordered_estimate_degrades_low_priority_first():
    adm = _RateGate(plan=object(), profile=object(), reestimate_s=0.0)
    # ~40 offered interactive + 40 best_effort inside the measurement
    # window: best_effort is modeled at the TOTAL rate (priority 0
    # competes with everything) while interactive sees only its peers
    for _ in range(40):
        adm.admit("interactive", deadline_s=0.5)
        adm.admit("best_effort", deadline_s=0.5)
    d_hi = adm.admit("interactive", deadline_s=0.5)
    d_lo = adm.admit("best_effort", deadline_s=0.5)
    assert d_hi.action == "admit", d_hi
    # best_effort's estimate exceeds its deadline -> degraded rather
    # than shed, because its default policy carries a DegradePolicy
    assert d_lo.action == "degrade", d_lo
    assert d_lo.reason == "deadline_risk"
    assert isinstance(d_lo.degrade, DegradePolicy)
    assert d_lo.estimate_s is not None \
        and d_lo.estimate_s > (d_hi.estimate_s or 0.0)


def test_unknown_class_rides_at_the_bottom():
    adm = AdmissionController()
    d = adm.admit("mystery")
    assert d.admitted
    assert adm.policy("mystery").priority == 0


# ---------------------------------------------------------------------------
# degraded execution context: the router consults the active policy
# ---------------------------------------------------------------------------

def test_degraded_execution_is_scoped_and_restores():
    assert active_degrade() is None
    pol = DegradePolicy(per_row=True, bucket_cap=4)
    with degraded_execution(pol):
        assert active_degrade() is pol
        with degraded_execution(None):
            assert active_degrade() is None
        assert active_degrade() is pol
    assert active_degrade() is None


# ---------------------------------------------------------------------------
# runtime integration: typed sheds, pre-dispatch expiry
# ---------------------------------------------------------------------------

def _sleepy_flow(seen, service_s=0.01):
    def slow(i: int) -> int:
        seen.append(i)
        time.sleep(service_s)
        return i

    fl = Dataflow([("i", int)])
    fl.output = fl.map(slow, names=["i"], batching=True)
    return fl


def test_call_dag_shed_carries_typed_overloaded():
    rt = Runtime(n_cpu=2, net=NetModel(scale=0.0))
    seen = []
    try:
        _sleepy_flow(seen).deploy(rt, name="ov")
        rt.set_admission("ov", AdmissionController(classes={
            "best_effort": ClassPolicy("best_effort", priority=0,
                                       rate=0.001, burst=1)}))
        ok = rt.call_dag("ov", Table([("i", int)], [(1,)]),
                         klass="best_effort")
        assert ok.result(timeout=10).rows[0].values[0] == 1
        shed = rt.call_dag("ov", Table([("i", int)], [(2,)]),
                           klass="best_effort")
        with pytest.raises(Overloaded) as ei:
            shed.result(timeout=10)
        assert ei.value.klass == "best_effort"
        assert ei.value.reason == "rate_limit"
        snap = rt.metrics_snapshot()
        assert len(snap.get("dag/ov/shed_t", [])) == 1
        assert len(snap.get("admission/ov/best_effort/shed_t", [])) == 1
        # a shed is NOT an error: the controller must not read overload
        # protection as failure
        assert "dag/ov/error_t" not in snap
        assert 2 not in seen                 # shed before any dispatch
    finally:
        rt.stop()


def test_expired_request_fails_fast_and_never_dispatches():
    rt = Runtime(n_cpu=2, net=NetModel(scale=0.0), batch_wait_ms=80.0)
    seen = []
    try:
        dep = _sleepy_flow(seen).deploy(rt, name="exp")
        # the batcher holds its window open for 80ms; a 10ms deadline
        # passes while the request waits -> expired pre-dispatch
        fut = rt.call_dag("exp", Table([("i", int)], [(7,)]),
                          deadline_s=0.01)
        with pytest.raises(DeadlineExceeded):
            fut.result(timeout=10)
        assert 7 not in seen, "expired request reached a dispatch"
        snap = rt.metrics_snapshot()
        assert len(snap.get("dag/exp/expired_t", [])) == 1
        node = next(n for n in dep.dag.nodes.values() if n.batching)
        assert len(snap.get(
            f"batch/exp/{node.name}/expired_t", [])) == 1
        assert "dag/exp/error_t" not in snap
    finally:
        rt.stop()


def test_deadline_honored_without_admission_controller():
    """No gate installed: call_dag still enforces an explicit deadline
    (expiry in the batcher), it just never sheds."""
    rt = Runtime(n_cpu=2, net=NetModel(scale=0.0), batch_wait_ms=60.0)
    seen = []
    try:
        _sleepy_flow(seen).deploy(rt, name="nd")
        fut = rt.call_dag("nd", Table([("i", int)], [(3,)]),
                          deadline_s=0.005)
        with pytest.raises(DeadlineExceeded):
            fut.result(timeout=10)
    finally:
        rt.stop()


# ---------------------------------------------------------------------------
# the burst: 3x saturating rate, open loop
# ---------------------------------------------------------------------------

def test_overload_burst_protects_interactive_class():
    """Open-loop burst at ~3x the deployment's saturating rate.  The
    gate + deadlines must (a) keep interactive goodput p99 within SLO,
    (b) never dispatch an expired request, (c) shed with the typed
    error, (d) reconcile counters: offered == served + shed + expired
    (+ zero untyped errors)."""
    from repro.profiling import (BucketStats, FlowProfile, NodeConfig,
                                 OpLatencyCurve, PlanConfig)

    service_s = 0.01
    rt = Runtime(n_cpu=2, net=NetModel(scale=0.0), max_batch=4,
                 batch_wait_ms=2.0)
    seen = []
    try:
        dep = _sleepy_flow(seen, service_s).deploy(rt, name="burst")
        capacity = 2 / service_s                     # ~200 rows/s
        # honest curves for the gate's estimator: per-row cost is the
        # sleep; every op in the plan gets one so the critical path is
        # modeled end to end
        curves = {}
        cfg = PlanConfig(nodes={})
        for o in dep.plan.ops:
            c = OpLatencyCurve(key=o.op_id, name=o.op.name,
                               per_row_s=service_s)
            for bkt in (1, 2, 4):
                c.buckets[bkt] = BucketStats(
                    mean_s=service_s * bkt, p99_s=service_s * bkt * 1.2,
                    cv=0.05, runs=3, out_bytes=8 * bkt)
            curves[o.op_id] = c
            cfg.nodes[o.op_id] = NodeConfig(
                max_batch=4, batch_wait_ms=2.0, batched_lowering=True,
                target_replicas=2)
        slo_s = 0.6
        adm = AdmissionController(
            dep.plan, FlowProfile(curves=curves), cfg, net=rt.net,
            classes={
                "interactive": ClassPolicy("interactive", priority=2,
                                           default_deadline_s=slo_s),
                "best_effort": ClassPolicy(
                    "best_effort", priority=0,
                    rate=0.1 * capacity, burst=5,
                    degrade=DegradePolicy(per_row=True, bucket_cap=4),
                    default_deadline_s=0.05),
            })
        rt.set_admission("burst", adm)

        offered_rate = 3.0 * capacity
        duration = 1.2
        lat_lock = threading.Lock()
        inter_lat, shed_fail_lat = [], []
        futs = []       # (klass, sent_i, future)
        i = 0
        t_start = time.perf_counter()
        while time.perf_counter() - t_start < duration:
            klass = "interactive" if i % 5 == 0 else "best_effort"
            t_send = time.perf_counter()
            f = rt.call_dag("burst", Table([("i", int)], [(i,)]),
                            klass=klass)

            def _lat(fut, t0=t_send, k=klass):
                dt = time.perf_counter() - t0
                exc = fut.exception()
                with lat_lock:
                    if exc is None and k == "interactive":
                        inter_lat.append(dt)
                    elif isinstance(exc, Overloaded) \
                            and not isinstance(exc, DeadlineExceeded):
                        shed_fail_lat.append(dt)
            f.add_done_callback(_lat)
            futs.append((klass, i, f))
            i += 1
            # open loop: pace arrivals, never wait on completions
            next_t = t_start + i / offered_rate
            pause = next_t - time.perf_counter()
            if pause > 0:
                time.sleep(pause)

        outcomes = {"ok": 0, "shed": 0, "expired": 0, "error": 0}
        expired_ids = []
        for klass, rid, f in futs:
            try:
                f.result(timeout=30)
                outcomes["ok"] += 1
            except DeadlineExceeded:
                outcomes["expired"] += 1
                expired_ids.append(rid)
            except Overloaded as e:            # (c) typed shed
                outcomes["shed"] += 1
                assert e.klass == "best_effort", \
                    "interactive traffic must not be shed"
            except Exception:
                outcomes["error"] += 1

        offered = len(futs)
        assert offered > 100                   # the burst actually ran
        # (d) reconciliation — every request has exactly one outcome,
        # and the gate's counters agree with the observed outcomes
        assert sum(outcomes.values()) == offered
        assert outcomes["error"] == 0, outcomes
        gate = adm.snapshot()
        ga = sum(v for k, v in gate.items() if k.endswith("/admitted"))
        gd = sum(v for k, v in gate.items() if k.endswith("/degraded"))
        gs = sum(v for k, v in gate.items() if k.endswith("/shed"))
        go = sum(v for k, v in gate.items() if k.endswith("/offered"))
        assert go == offered
        assert ga + gd + gs == go
        assert gs == outcomes["shed"]
        assert ga + gd == outcomes["ok"] + outcomes["expired"]
        # overload actually hit best_effort: a large share shed/degraded
        assert gs + gd > 0.3 * go, gate
        # (b) expired requests never reached a dispatch
        ran = set(seen)
        for rid in expired_ids:
            assert rid not in ran, \
                f"expired request {rid} reached a dispatch"
        # (a) interactive goodput: most served, and served within SLO
        n_inter = sum(1 for k, _, _ in futs if k == "interactive")
        with lat_lock:
            ilat = sorted(inter_lat)
            slat = list(shed_fail_lat)
        assert len(ilat) >= 0.7 * n_inter, \
            (len(ilat), n_inter, outcomes)
        p99 = ilat[min(len(ilat) - 1, int(0.99 * len(ilat)))]
        assert p99 <= slo_s, f"interactive p99 {p99 * 1e3:.0f}ms"
        # sheds fail FAST: well under the interactive SLO budget
        if slat:
            assert max(slat) < 0.1 * slo_s
        # no batcher wedges: every batcher drains to quiescent
        deadline = time.perf_counter() + 10.0
        while time.perf_counter() < deadline:
            with rt._batchers_lock:
                bs = list(rt._batchers.values())
            if all(b.quiescent() for b in bs):
                break
            time.sleep(0.02)
        else:
            pytest.fail("batcher failed to drain after the burst")
    finally:
        rt.stop()
