import textwrap

import pytest

from repro.configs import get_config, SHAPES
from repro.roofline import analysis, flops, hw


def test_shape_bytes():
    assert analysis._shape_bytes("bf16[8,64]") == 8 * 64 * 2
    assert analysis._shape_bytes("f32[2,3,4]") == 96
    assert analysis._shape_bytes("(bf16[8], f32[4])") == 16 + 16
    assert analysis._shape_bytes("pred[16]") == 16


def test_collective_parse_simple():
    hlo = textwrap.dedent("""
    ENTRY %main (a: bf16[8]) -> bf16[8] {
      %x = bf16[8,64]{1,0} all-gather(%a), dimensions={1}
      %y = f32[16]{0} all-reduce(%x), to_apply=%add
      ROOT %r = bf16[8]{0} copy(%y)
    }
    """)
    out = analysis.collective_bytes(hlo)
    assert out["all-gather"] == 8 * 64 * 2
    assert out["all-reduce"] == 64


def test_collective_trip_count_multiplier():
    hlo = textwrap.dedent("""
    %body (p: (s32[], bf16[8])) -> (s32[], bf16[8]) {
      %g = bf16[8,4]{1,0} all-gather(%p), dimensions={1}
      ROOT %t = (s32[], bf16[8]) tuple(%g)
    }

    %cond (p: (s32[], bf16[8])) -> pred[] {
      %limit = s32[] constant(24)
      ROOT %c = pred[] compare(%p, %limit), direction=LT
    }

    ENTRY %main (a: bf16[8]) -> bf16[8] {
      %w = (s32[], bf16[8]) while(%a), condition=%cond, body=%body
      %top = bf16[16]{0} all-reduce(%w), to_apply=%add
      ROOT %r = bf16[8]{0} copy(%w)
    }
    """)
    out = analysis.collective_bytes_corrected(hlo)
    assert out["all-gather"] == 24 * 8 * 4 * 2
    assert out["all-reduce"] == 32


def test_roofline_terms_and_bottleneck():
    r = analysis.Roofline(flops=197e12, hbm_bytes=819e9, coll_bytes=0,
                          model_flops=197e12, chips=1)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(1.0)
    assert r.bottleneck in ("compute", "memory")
    r2 = analysis.Roofline(flops=1, hbm_bytes=1, coll_bytes=200e9 * 4)
    assert r2.bottleneck == "collective"


@pytest.mark.parametrize("arch,shape,expect_ratio_range", [
    ("yi-9b", "train_4k", (0.2, 1.0)),
    ("yi-9b", "decode_32k", (0.3, 1.05)),
    ("arctic-480b", "train_4k", (0.1, 1.0)),
    ("rwkv6-1.6b", "decode_32k", (0.5, 1.2)),
])
def test_analytic_estimator_sanity(arch, shape, expect_ratio_range):
    """Useful ratio = MODEL_FLOPS / executed must be in a sane band —
    executed >= useful (up to small approximation slack)."""
    cfg = get_config(arch)
    est = flops.estimate(cfg, SHAPES[shape], chips=256, mp=16)
    ratio = est.model_flops / est.step_flops
    lo, hi = expect_ratio_range
    assert lo <= ratio <= hi, (arch, shape, ratio)


def test_train_flops_dominated_by_backprop():
    cfg = get_config("yi-9b")
    tr = flops.estimate(cfg, SHAPES["train_4k"], chips=256, mp=16)
    assert tr.step_flops >= 3 * tr.fwd_flops


def test_decode_memory_bound():
    cfg = get_config("granite-34b")
    est = flops.estimate(cfg, SHAPES["decode_32k"], chips=256, mp=16)
    t_c = est.step_flops / 256 / hw.PEAK_FLOPS_BF16
    t_m = est.hbm_bytes_per_chip / hw.HBM_BW
    assert t_m > t_c  # decode is memory-bound on v5e
