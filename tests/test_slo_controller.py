"""Online SLO controller + runtime reconfiguration + autoscaler targets.

* ``Runtime.record_metric``/``metrics_snapshot`` are safe under concurrent
  executor-callback writers;
* the controller hot-applies batch bucket / batcher window changes to a
  LIVE deployment — no flow re-registration, zero executable re-traces;
* optimizer-suggested replica targets drive the ``Autoscaler`` (spike ->
  scale-up -> settle with slack) while the depth heuristic survives for
  untargeted functions.
"""
import threading
import time

import numpy as np
import pytest

try:
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jax = None

pytestmark = pytest.mark.skipif(jax is None, reason="requires jax")

from repro.core.dataflow import Dataflow
from repro.core.lowering import EXECUTABLE_CACHE, BatchedJittedFuse
from repro.core.table import Table
from repro.profiling import (BucketStats, FlowProfile, OpLatencyCurve,
                             SLOController)
from repro.runtime.autoscaler import Autoscaler, AutoscalerConfig
from repro.runtime.netmodel import NetModel
from repro.runtime.runtime import Runtime


def _curve(key, per_row_s=2e-3, base=2e-3, slope=1e-4,
           buckets=(1, 2, 4, 8, 16)):
    c = OpLatencyCurve(key=key, name=f"op{key}", per_row_s=per_row_s)
    for b in buckets:
        mean = base + slope * b
        c.buckets[b] = BucketStats(mean_s=mean, p99_s=1.2 * mean, cv=0.05,
                                   runs=3, out_bytes=64 * b)
    return c


# ---------------------------------------------------------------------------
# metrics thread-safety (satellite)
# ---------------------------------------------------------------------------

def test_metrics_concurrent_writers_and_snapshots():
    rt = Runtime(n_cpu=1, net=NetModel(scale=0.0))
    try:
        stop = threading.Event()
        errors = []

        def writer(i):
            for k in range(300):
                rt.record_metric(f"key/{i % 4}", float(k))

        def reader():
            while not stop.is_set():
                try:
                    snap = rt.metrics_snapshot()
                    for series in snap.values():
                        list(series)        # iterate a consistent copy
                except BaseException as e:  # pragma: no cover
                    errors.append(e)
                    return

        threads = [threading.Thread(target=writer, args=(i,))
                   for i in range(8)]
        r = threading.Thread(target=reader)
        r.start()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stop.set()
        r.join(timeout=2)
        assert not errors
        snap = rt.metrics_snapshot()
        assert sum(len(snap[f"key/{i}"]) for i in range(4)) == 8 * 300
    finally:
        rt.stop()


# ---------------------------------------------------------------------------
# live reconfiguration (acceptance: sparse -> dense changes the deployed
# config without re-registration, zero executable re-traces)
# ---------------------------------------------------------------------------

def _gpu_m1(x: jax.Array) -> jax.Array:
    return x * 2.0


def _gpu_m2(x: jax.Array) -> jax.Array:
    return x + 1.0


def test_controller_hot_applies_sparse_to_dense():
    rt = Runtime(n_cpu=2, n_gpu=1, net=NetModel(scale=0.0),
                 max_batch=4, batch_wait_ms=2.0)
    try:
        fl = Dataflow([("x", jax.Array)])
        fl.output = fl.map(_gpu_m1, names=["x"], gpu=True, batching=True) \
            .map(_gpu_m2, names=["x"], gpu=True, batching=True)
        dep = fl.deploy(rt, fusion=True)
        dag0 = rt.dags[dep.dag.name]
        node = next(n for n in dep.dag.nodes.values() if n.batching)
        op_id = node.plan_op_id
        assert isinstance(dep.plan.op(op_id).op, BatchedJittedFuse)

        # synthetic offline curve: strong batching win under load
        profile = FlowProfile(curves={op_id: _curve(op_id)})
        ctl = SLOController(rt, dep, slo_p99_s=0.2, profile=profile,
                            window_s=0.5, min_rate=1.0)

        def req():
            return Table([("x", jax.Array)],
                         [(jnp.ones(16, jnp.float32),)])

        # -- sparse phase: ~30/s, per-row wins ------------------------------
        futs = [dep.execute(req()) for _ in range(3)]
        for _ in range(6):
            futs.append(dep.execute(req()))
            time.sleep(0.03)
        for f in futs:
            f.result(timeout=10)
        ev1 = ctl.tick()
        assert ev1.kind == "apply", ev1
        cfg1 = ctl.applied.nodes[op_id]
        assert cfg1.max_batch == 1 and cfg1.batch_wait_ms == 0.0
        batcher = rt.batcher_for(dep.dag.name, node.name)
        assert batcher.max_wait == 0.0
        buckets_sparse = tuple(node.batch_buckets)

        # -- dense phase: a back-to-back burst, batching must win -----------
        time.sleep(0.6)                 # age the sparse timestamps out
        futs = [dep.execute(req()) for _ in range(80)]
        for f in futs:
            f.result(timeout=20)
        rate = ctl.arrival_rate()
        assert rate > 200.0, rate

        traces_before = EXECUTABLE_CACHE.traces()
        ev2 = ctl.tick()
        traces_after = EXECUTABLE_CACHE.traces()

        # the apply itself is pure control plane: ZERO re-traces
        assert traces_after == traces_before
        # no re-registration: same DAG object is live
        assert rt.dags[dep.dag.name] is dag0
        assert ev2.kind == "apply", ev2
        cfg2 = ctl.applied.nodes[op_id]
        # the deployed flow's batcher window and max-batch moved
        assert cfg2.max_batch > 1
        assert cfg2.batch_wait_ms > 0.0
        assert rt.batcher_for(dep.dag.name, node.name) is batcher
        assert batcher.max_wait == pytest.approx(
            cfg2.batch_wait_ms / 1e3)
        assert batcher.max_batch == cfg2.max_batch
        # and the node's padding buckets were retuned in place
        assert tuple(node.batch_buckets) != buckets_sparse
        assert dep.plan.op(op_id).op.bucket_sizes == \
            tuple(node.batch_buckets)

        # the reconfigured deployment still serves correctly
        out = dep.execute(req()).result(timeout=10)
        assert out.rows[0].values[0] == pytest.approx(
            np.ones(16, np.float32) * 2 + 1)
    finally:
        rt.stop()


def test_live_config_reads_competitive_from_expanded_topology():
    """After a competitive recompile the factor lives in the wait-any
    consumer's input count (CompetitivePass zeroes the replica ops'
    annotation) — the controller must read it back from the topology, and
    must not keep demanding a recompile for an already-expanded slot."""
    from repro.profiling import NodeConfig, PlanConfig
    rt = Runtime(n_cpu=2, net=NetModel(scale=0.0))
    try:
        def f(x: int) -> int:
            return x
        fl = Dataflow([("x", int)])
        fl.output = fl.map(f, names=["x"], high_variance=True)
        dep = fl.deploy(rt, competitive_exec=True, default_replicas=3)
        anyof_id = next(o.op_id for o in dep.plan.ops if o.wait_any)
        ctl = SLOController(rt, dep, slo_p99_s=0.05, profile=FlowProfile())
        live = ctl._live_config(None)
        assert live.nodes[anyof_id].competitive_replicas == 3
        replica_ids = dep.plan.op(anyof_id).inputs
        assert all(live.nodes[i].competitive_replicas == 3
                   for i in replica_ids)
        # a proposal demanding competitive on the (already wait-any) slot
        # is satisfied by the live topology: no recompile escalation
        proposal = PlanConfig(nodes={anyof_id: NodeConfig(
            competitive_replicas=3)})
        assert not ctl._needs_recompile(proposal)
    finally:
        rt.stop()


def test_arrival_rate_decays_after_traffic_stops():
    """The rate window is anchored on NOW, not on the newest request —
    a dead workload must read as idle, not as its last burst's rate."""
    rt = Runtime(n_cpu=1, net=NetModel(scale=0.0))
    try:
        def f(x: int) -> int:
            return x
        fl = Dataflow([("x", int)])
        fl.output = fl.map(f, names=["x"])
        dep = fl.deploy(rt)
        ctl = SLOController(rt, dep, slo_p99_s=0.05,
                            profile=FlowProfile(), window_s=0.4)
        now = time.perf_counter()
        for i in range(50):     # a burst that ended 2s ago
            rt.record_metric(f"dag/{dep.dag.name}/request_t",
                             now - 2.0 + i * 0.002)
        assert ctl.arrival_rate() == 0.0
        assert ctl.tick().kind == "idle"
    finally:
        rt.stop()


def test_plan_config_compile_without_fusion_still_lowers():
    """A config-driven recompile must realize the config's lowering and
    bucket overrides even when fusion is off (bare gpu maps lower with
    min_ops=1) — silently dropping them would defeat a replan."""
    from repro.profiling import NodeConfig, PlanConfig
    rt = Runtime(n_cpu=1, n_gpu=1, net=NetModel(scale=0.0))
    try:
        fl = Dataflow([("x", jax.Array)])
        fl.output = fl.map(_gpu_m1, names=["x"], gpu=True, batching=True)
        probe = fl.deploy(rt, fusion=False, plan_config=PlanConfig())
        op_id = next(iter(probe.plan.ops)).op_id
        cfg = PlanConfig(nodes={op_id: NodeConfig(
            max_batch=4, batch_buckets=(1, 2, 4), batched_lowering=True)})
        dep = fl.deploy(rt, fusion=False, plan_config=cfg)
        o = dep.plan.op(op_id)
        assert isinstance(o.op, BatchedJittedFuse)
        assert o.batch_buckets == (1, 2, 4)
        out = dep.execute(Table([("x", jax.Array)],
                                [(jnp.ones(4, jnp.float32),)]))
        assert out.result(timeout=10).rows[0].values[0] == pytest.approx(
            np.ones(4, np.float32) * 2)
    finally:
        rt.stop()


def test_configure_batching_before_first_dispatch():
    """Overrides set before a node's batcher exists are picked up at
    batcher creation."""
    rt = Runtime(n_cpu=2, net=NetModel(scale=0.0), max_batch=10,
                 batch_wait_ms=5.0)
    try:
        def f(x: int) -> int:
            return x * 10
        fl = Dataflow([("x", int)])
        fl.output = fl.map(f, names=["y"], batching=True)
        dep = fl.deploy(rt)
        node = next(n for n in dep.dag.nodes.values() if n.batching)
        assert rt.configure_batching(dep.dag.name, node.name, max_batch=3,
                                     batch_wait_ms=1.0)
        # unchanged values report no change
        assert not rt.configure_batching(dep.dag.name, node.name,
                                         max_batch=3, batch_wait_ms=1.0)
        out = dep.execute(Table([("x", int)], [(4,)])).result(timeout=10)
        assert out.rows[0].values[0] == 40
        b = rt.batcher_for(dep.dag.name, node.name)
        assert b.max_batch == 3 and b.max_wait == pytest.approx(1e-3)
    finally:
        rt.stop()


# ---------------------------------------------------------------------------
# autoscaler targets (satellite): spike -> scale-up -> settle with slack
# ---------------------------------------------------------------------------

def _wait_until(cond, timeout=6.0, interval=0.02):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if cond():
            return True
        time.sleep(interval)
    return False


def test_autoscaler_converges_to_target():
    rt = Runtime(n_cpu=2, net=NetModel(scale=0.0))
    scaler = None
    try:
        scaler = Autoscaler(rt.pool, {"fn": "cpu"},
                            AutoscalerConfig(interval_s=0.02, slack=2,
                                             min_replicas=1)).start()
        scaler.set_target("fn", 5)
        assert _wait_until(lambda: rt.pool.replica_count("fn") >= 5)
        scaler.set_target("fn", 1)
        # settles within target + slack (hysteresis makes this take a few
        # ticks), never below min_replicas
        assert _wait_until(lambda: rt.pool.replica_count("fn") <= 3)
        time.sleep(0.3)
        assert 1 <= rt.pool.replica_count("fn") <= 3
    finally:
        if scaler:
            scaler.stop()
        rt.stop()


def test_controller_autoscaler_bursty_traffic():
    """The combined loop: a traffic spike makes the optimizer demand
    replicas (M/M/c), the controller targets them on the autoscaler, the
    pool scales up; when traffic thins the next tick lowers the target and
    the pool settles back with slack."""
    rt = Runtime(n_cpu=2, net=NetModel(scale=0.0))
    scaler = None
    try:
        def heavy(x: int) -> int:
            time.sleep(0.004)
            return x + 1

        fl = Dataflow([("x", int)])
        fl.output = fl.map(heavy, names=["x"])
        dep = fl.deploy(rt)
        node = next(iter(dep.dag.nodes.values()))
        op_id = node.plan_op_id

        scaler = Autoscaler(rt.pool, {node.name: "cpu"},
                            AutoscalerConfig(interval_s=0.02, slack=2,
                                             min_replicas=1)).start()
        profile = FlowProfile(curves={op_id: _curve(
            op_id, per_row_s=4e-3, base=4e-3, slope=0.0, buckets=(1,))})
        ctl = SLOController(rt, dep, slo_p99_s=0.05, profile=profile,
                            autoscaler=scaler, window_s=0.5, min_rate=1.0)

        # -- spike: ~500/s => 2 erlangs at 4ms/req => needs >= 3 replicas --
        futs = []
        t_end = time.time() + 0.5
        while time.time() < t_end:
            futs.append(dep.execute(Table([("x", int)], [(1,)])))
            time.sleep(0.002)
        ev = ctl.tick()
        assert ev.arrival_rate > 200.0, ev
        target_hot = scaler.target(node.name)
        assert target_hot is not None and target_hot >= 2, ev
        assert _wait_until(
            lambda: rt.pool.replica_count(node.name) >= target_hot)
        for f in futs:
            f.result(timeout=30)

        # -- settle: thin trickle => target drops, pool trims with slack ---
        time.sleep(0.6)
        for _ in range(6):
            dep.execute(Table([("x", int)], [(1,)])).result(timeout=10)
            time.sleep(0.05)
        ev2 = ctl.tick()
        target_cool = scaler.target(node.name)
        assert target_cool is not None and target_cool < target_hot, ev2
        slack = scaler.cfg.slack
        assert _wait_until(lambda: rt.pool.replica_count(node.name)
                           <= target_cool + slack)
        assert rt.pool.replica_count(node.name) >= 1
    finally:
        if scaler:
            scaler.stop()
        rt.stop()


def test_depth_heuristic_untouched_without_target():
    """No target set -> the original queue-depth rule still scales up."""
    rt = Runtime(n_cpu=2, net=NetModel(scale=0.0))
    scaler = None
    try:
        def slow(x: int) -> int:
            time.sleep(0.02)
            return x

        fl = Dataflow([("x", int)])
        fl.output = fl.map(slow, names=["x"])
        dep = fl.deploy(rt)
        fname = next(iter(dep.dag.nodes))
        scaler = Autoscaler(rt.pool, {fname: "cpu"},
                            AutoscalerConfig(interval_s=0.02)).start()
        futs = [dep.execute(Table([("x", int)], [(i,)]))
                for i in range(40)]
        assert _wait_until(lambda: rt.pool.replica_count(fname) > 1)
        for f in futs:
            f.result(timeout=30)
    finally:
        if scaler:
            scaler.stop()
        rt.stop()
