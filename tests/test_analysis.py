"""Static plan verifier (repro.analysis): adversarial corpus + wiring.

One deliberately broken plan per diagnostic code (CF101..CF502), each
asserting the code fires EXACTLY once with an actionable hint; a
zero-false-positive sweep over every shipped example/benchmark flow;
compile_flow(verify=...) rejection before any XLA trace; CLI behavior;
and the control-plane span events (autoscaler / blue-green phases).
"""
import sys
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import (VerificationError, analyze, device_edge_info,
                            pass_snapshot, verify_pass_step)
from repro.analysis import cli as check_cli
from repro.analysis.diagnostics import CODES, Diagnostic, Report
from repro.analysis.infer import specs_from_table
from repro.core import operators as ops
from repro.core.compiler import compile_flow
from repro.core.dataflow import Dataflow
from repro.core.ir import PhysicalPlan
from repro.core.lowering import EXECUTABLE_CACHE, BatchedJittedFuse
from repro.core.operators import TypecheckError
from repro.core.passes import PassContext, PassPipeline, build_pipeline
from repro.core.table import Table
from repro.kernels.ops import kernel_step
from repro.obs import keys as K
from repro.obs.export import to_chrome_events
from repro.obs.trace import Tracer
from repro.profiling.optimizer import NodeConfig, PlanConfig
from repro.runtime.autoscaler import Autoscaler, AutoscalerConfig
from repro.runtime.runtime import Runtime

REPO_ROOT = Path(__file__).resolve().parent.parent


def one(report, code):
    """Assert ``code`` fired exactly once with an actionable hint."""
    diags = report.by_code(code)
    assert len(diags) == 1, \
        f"{code}: expected exactly 1, got {len(diags)}:\n{report.table()}"
    d = diags[0]
    assert d.hint, f"{code} has no fix hint"
    assert d.severity == CODES[code][1]
    return d


# -- step functions (module-level so annotations survive) -------------------

def _jid(x: jax.Array) -> jax.Array:
    return x * 2


def _jdot5(x: jax.Array) -> jax.Array:
    return jnp.dot(x, jnp.ones((5, 5)))       # rejects 1-d [8] rows


def _jbranch(x: jax.Array) -> jax.Array:
    if x.sum() > 0:                           # data-dependent control flow
        return x
    return -x


def _jreshape(x: jax.Array) -> jax.Array:
    return x.reshape(2, 2)                    # row [4] -> [2, 2]


def _pred_unannotated(x: jax.Array):
    return x.sum() > 0


def _pred_bool(x: jax.Array) -> bool:
    return True


def _nid(x: np.ndarray) -> np.ndarray:
    return x


def _nneg(x: np.ndarray) -> np.ndarray:
    return -x


def _gpu_chain(step2=_jid):
    fl = Dataflow([("x", jax.Array)])
    fl.output = (fl.map(_jid, names=["x"], gpu=True)
                 .map(step2, names=["x"], gpu=True))
    return fl


def _fanout_flow():
    """source -> a -> {b, c} -> union: op 1's edge fans out."""
    fl = Dataflow([("x", np.ndarray)])
    a = fl.map(_nid, names=["x"])
    b = a.map(_nid, names=["x"])
    c = a.map(_nneg, names=["x"])
    fl.output = b.union(c)
    return fl


def _raw(fl):
    return PhysicalPlan.from_dataflow(fl)


def _compiled(fl, **kw):
    return build_pipeline(**kw).run(_raw(fl), PassContext())


# -- abstract interpretation (CF101/CF102/CF103/CF104) ----------------------

def test_cf101_shape_mismatch_fires_once():
    plan = _raw(_gpu_chain(_jdot5))
    rep = analyze(plan, input_specs={
        "x": jax.ShapeDtypeStruct((8,), jnp.float32)})
    d = one(rep, "CF101")
    assert not rep.ok
    assert "rejects the inferred input shapes" in d.message


def test_cf102_untraceable_step_fires_once():
    plan = _raw(_gpu_chain(_jbranch))
    rep = analyze(plan, input_specs={
        "x": jax.ShapeDtypeStruct((8,), jnp.float32)})
    d = one(rep, "CF102")
    assert "not traceable" in d.message
    assert not rep.by_code("CF101")           # classified, not conflated


def test_cf103_kernel_tile_mismatch_fires_once():
    # S=64: block_k=32 divides, block_q=48 does not -> exactly one problem
    step = kernel_step("flash_attention", causal=True,
                       block_q=48, block_k=32)
    fl = Dataflow([("q", jax.Array), ("k", jax.Array), ("v", jax.Array)])
    fl.output = fl.map(step, names=["o"], gpu=True)
    spec = jax.ShapeDtypeStruct((2, 64, 16), jnp.float32)
    rep = analyze(_raw(fl),
                  input_specs={"q": spec, "k": spec, "v": spec})
    d = one(rep, "CF103")
    assert "block_q" in d.message and "block_k" not in d.message


def test_cf103_skipped_without_shapes():
    step = kernel_step("flash_attention", causal=True,
                       block_q=48, block_k=32)
    fl = Dataflow([("q", jax.Array), ("k", jax.Array), ("v", jax.Array)])
    fl.output = fl.map(step, names=["o"], gpu=True)
    assert analyze(_raw(fl)).ok               # no specs -> no false alarm


def test_cf104_unannotated_filter_on_gpu_fires_once():
    fl = Dataflow([("x", jax.Array)])
    fl.output = (fl.map(_jid, names=["x"], gpu=True)
                 .filter(_pred_unannotated, gpu=True))
    rep = analyze(_raw(fl))
    d = one(rep, "CF104")
    assert rep.ok                             # warning, not error
    assert "bool" in d.hint


def test_annotated_bool_filter_is_clean():
    fl = Dataflow([("x", jax.Array)])
    fl.output = (fl.map(_jid, names=["x"], gpu=True)
                 .filter(_pred_bool, gpu=True))
    rep = analyze(_raw(fl))
    assert not rep.by_code("CF104")


def test_filter_rejects_nonbool_annotation():
    def bad(x: jax.Array) -> int:
        return 1
    with pytest.raises(TypecheckError):
        ops.Filter(bad)


# -- IR invariants (CF2xx) --------------------------------------------------

def test_cf201_donated_fanout_fires_once():
    plan = _raw(_fanout_flow())
    plan = plan.with_ops([o.replace(donate=True) if o.op_id == 1 else o
                          for o in plan.ops])
    rep = analyze(plan)
    d = one(rep, "CF201")
    assert d.op_id == 1
    assert "2 consumers" in d.message


def test_cf202_device_edge_crossing_classes_fires_once():
    plan = _compiled(_gpu_chain(), plan_config=PlanConfig())
    assert isinstance(plan.op(1).op, BatchedJittedFuse)
    assert isinstance(plan.op(2).op, BatchedJittedFuse)
    # residency analysis agrees with the runtime: 1->2 is a device edge
    emits, donate = device_edge_info(plan)[1]
    assert emits and donate
    broken = plan.with_ops([o.replace(placement="cpu") if o.op_id == 2
                            else o for o in plan.ops])
    d = one(analyze(broken), "CF202")
    assert d.edge == (1, 2)


def test_cf203_wait_any_single_input_is_an_error():
    plan = _raw(_gpu_chain())
    plan = plan.with_ops([o.replace(wait_any=True) if o.op_id == 2 else o
                          for o in plan.ops])
    d = one(analyze(plan), "CF203")
    assert d.severity == "error"
    assert "race" in d.message


def test_cf203_unraced_replicas_is_a_warning():
    plan = _raw(_gpu_chain())
    plan = plan.with_ops([o.replace(replicas=3) if o.op_id == 1 else o
                          for o in plan.ops])
    rep = analyze(plan)
    diags = rep.by_code("CF203")
    assert len(diags) == 1 and diags[0].severity == "warning"
    assert rep.ok


def test_cf204_bucket_table_below_max_batch_fires_once():
    def batched(x: jax.Array) -> jax.Array:
        return x + 1
    fl = Dataflow([("x", jax.Array)])
    fl.output = (fl.map(_jid, names=["x"], gpu=True, batching=True)
                 .map(batched, names=["x"], gpu=True, batching=True))
    cfg = PlanConfig(nodes={2: NodeConfig(max_batch=8,
                                          batch_buckets=(1, 2))})
    plan = _compiled(fl, fusion=True, plan_config=cfg)
    fused = [o for o in plan.ops if isinstance(o.op, BatchedJittedFuse)]
    assert len(fused) == 1 and fused[0].op.bucket_sizes == (1, 2)
    rep = analyze(plan, plan_config=cfg)
    d = one(rep, "CF204")
    # a full merge of 8 pads past the top bucket (2); hint names the fix
    assert "8" in d.hint


def test_cf204_clean_when_buckets_cover():
    def batched(x: jax.Array) -> jax.Array:
        return x + 1
    fl = Dataflow([("x", jax.Array)])
    fl.output = (fl.map(_jid, names=["x"], gpu=True, batching=True)
                 .map(batched, names=["x"], gpu=True, batching=True))
    cfg = PlanConfig(nodes={2: NodeConfig(max_batch=8,
                                          batch_buckets=(1, 4, 8))})
    plan = _compiled(fl, fusion=True, plan_config=cfg)
    assert not analyze(plan, plan_config=cfg).by_code("CF204")


def test_cf205_zero_executor_class_fires_once():
    rt = Runtime(n_cpu=1, n_gpu=0)
    try:
        d = one(analyze(_raw(_gpu_chain()), runtime=rt), "CF205")
        assert "'gpu'" in d.message
    finally:
        rt.stop()


def test_cf206_all_reserved_class_fires_once():
    rt = Runtime(n_cpu=1, n_gpu=0)
    try:
        rt.pool.add_executor("gpu", reserved=True)
        rep = analyze(_raw(_gpu_chain()), runtime=rt)
        d = one(rep, "CF206")
        assert "reserved" in d.message
        assert not rep.by_code("CF205")
    finally:
        rt.stop()


# -- resource bounds (CF301) ------------------------------------------------

def _big_row_flow():
    def grow(x: jax.Array) -> jax.Array:
        return jnp.concatenate([x, x])
    fl = Dataflow([("x", jax.Array)])
    fl.output = (fl.map(_jid, names=["x"], gpu=True, batching=True)
                 .map(grow, names=["x"], gpu=True, batching=True))
    return fl


def test_cf301_over_budget_footprint_fires_once():
    plan = _compiled(_big_row_flow(), fusion=True)
    sample = Table([("x", jax.Array)], [(np.zeros(1024, np.float32),)])
    rep = analyze(plan, sample=sample, budget_bytes=64 << 10)
    d = one(rep, "CF301")
    assert "MiB" in d.message and "budget" in d.message


def test_cf301_clean_under_budget():
    plan = _compiled(_big_row_flow(), fusion=True)
    sample = Table([("x", jax.Array)], [(np.zeros(1024, np.float32),)])
    assert analyze(plan, sample=sample, budget_bytes=1 << 30).ok


# -- observability lint (CF401) ---------------------------------------------

def test_cf401_unknown_metric_key_fires_once():
    fl = Dataflow([("x", int)])

    def inc(x: int) -> int:
        return x + 1
    fl.output = fl.map(inc, names=["x"])
    rt = Runtime(n_cpu=1)
    try:
        rt.record_metric(K.dag("demo", "latency_s"), 0.01)   # registered
        rt.record_metric("bogus/unknown_series", 1.0)        # typo'd
        rep = analyze(_raw(fl), runtime=rt)
        d = one(rep, "CF401")
        assert "bogus/unknown_series" in d.message
        assert rep.ok                                        # warning
    finally:
        rt.stop()


def test_key_registry_grammar():
    assert K.known_key(K.dag("f", "latency_s"))
    assert K.known_key(K.batch(K.batch_prefix("f", "n/sub"), "size"))
    assert K.known_key(K.admission("f", "interactive", "shed_t"))
    assert K.known_key(K.fault("crash"))
    assert not K.known_key("dag/f/latency")       # wrong suffix
    assert not K.known_key("bogus/unknown_series")


# -- pipeline self-verification (CF501/CF502) -------------------------------

class _StampDonateFanOut:
    """A deliberately broken pass: forces donation on fan-out edges."""
    name = "stamp-donate"

    def run(self, plan, ctx):
        fanout = {}
        for o in plan.ops:
            for i in o.inputs:
                fanout[i] = fanout.get(i, 0) + 1
        return plan.with_ops([o.replace(donate=True)
                              if fanout.get(o.op_id, 0) > 1 else o
                              for o in plan.ops])


def test_cf501_pass_introducing_errors_fails_the_compile():
    pp = PassPipeline([_StampDonateFanOut()], verify=True)
    with pytest.raises(VerificationError) as ei:
        pp.run(_raw(_fanout_flow()), PassContext())
    msg = str(ei.value)
    assert "CF501" in msg and "stamp-donate" in msg
    assert len(ei.value.report.by_code("CF501")) == 1


def test_cf502_pass_changing_edge_types_fails_the_compile():
    def renamed(x: jax.Array) -> jax.Array:
        return x

    class Rename:
        name = "rename"

        def run(self, plan, ctx):
            return plan.with_ops([
                o.replace(op=ops.Map(renamed, ["y"])) if o.op_id == 2
                else o for o in plan.ops])

    pp = PassPipeline([Rename()], verify=True)
    with pytest.raises(VerificationError) as ei:
        pp.run(_raw(_gpu_chain()), PassContext())
    msg = str(ei.value)
    assert "CF502" in msg and "rename" in msg
    assert len(ei.value.report.by_code("CF502")) == 1


def test_verified_pipeline_accepts_the_real_passes():
    pp = build_pipeline(fusion=True, verify=True)
    plan = pp.run(_raw(_gpu_chain()), PassContext())
    assert any(isinstance(o.op, BatchedJittedFuse) for o in plan.ops)


def test_verify_pass_step_returns_next_snapshot():
    plan = _raw(_gpu_chain())
    snap = pass_snapshot(plan)
    snap2 = verify_pass_step("noop", plan, snap)
    assert snap2[1] == snap[1]                # identical edge signature


# -- compile_flow(verify=...) rejects BEFORE any XLA trace ------------------

def test_compile_flow_rejects_donated_fanout_before_trace():
    rt = Runtime(n_cpu=1)
    try:
        pipeline = PassPipeline(build_pipeline(fusion=True).passes
                                + [_StampDonateFanOut()])
        t0 = EXECUTABLE_CACHE.traces()
        with pytest.raises(VerificationError) as ei:
            compile_flow(_fanout_flow(), rt, pipeline=pipeline,
                         verify="error", name="donated-fanout")
        assert ei.value.report.by_code("CF201")
        assert EXECUTABLE_CACHE.traces() == t0    # rejected pre-XLA
        assert "donated-fanout" not in rt.dags
    finally:
        rt.stop()


def test_compile_flow_rejects_over_budget_before_trace():
    rt = Runtime(n_cpu=1, n_gpu=1)
    try:
        sample = Table([("x", jax.Array)], [(np.zeros(1024, np.float32),)])
        t0 = EXECUTABLE_CACHE.traces()
        with pytest.raises(VerificationError) as ei:
            compile_flow(_big_row_flow(), rt, fusion=True, verify=True,
                         verify_input=sample, verify_budget_bytes=64 << 10,
                         name="over-budget")
        assert ei.value.report.by_code("CF301")
        assert EXECUTABLE_CACHE.traces() == t0
        assert "over-budget" not in rt.dags
    finally:
        rt.stop()


def test_compile_flow_verify_warn_attaches_report_and_serves():
    rt = Runtime(n_cpu=1, n_gpu=1)
    try:
        sample = Table([("x", jax.Array)], [(np.zeros(16, np.float32),)])
        dep = compile_flow(_gpu_chain(), rt, fusion=True, verify="warn",
                           verify_input=sample, name="warned")
        assert dep.verification is not None and dep.verification.ok
        out = dep.execute(sample).result(timeout=30)
        np.testing.assert_allclose(np.asarray(out.rows[0].values[0]),
                                   np.zeros(16))
    finally:
        rt.stop()


# -- regressions: crashes found linting the shipped flows -------------------

def test_lookup_fused_chain_does_not_crash_analysis():
    """Locality fusion merges a Lookup into its consumer chain; the
    verifier must skip (not crash on) the annotation-less sub-op."""
    def key_of(x: int) -> tuple[int, str]:
        return x, f"k{x}"

    def use(x: int, key: str, lookup) -> int:
        return x

    fl = Dataflow([("x", int)])
    fl.output = (fl.map(key_of, names=["x", "key"])
                 .lookup("key", column=True)
                 .map(use, names=["x"]))
    plan = _compiled(fl, fusion=True, locality=True)
    assert any(isinstance(o.op, ops.Fuse) and
               any(isinstance(s, ops.Lookup) for s in o.op.ops)
               for o in plan.ops)
    rep = analyze(plan, sample=Table([("x", int)], [(1,)]))
    assert rep.ok


def test_kernel_tile_check_skips_fused_groupby():
    """A fused chain carrying a GroupBy sub-op has steps without ``fn``;
    KernelTileCheck must not crash and must not guess shapes past it."""
    def tag(x: jax.Array) -> tuple[int, jax.Array]:
        return 0, x

    fl = Dataflow([("x", jax.Array)])
    fl.output = fl.map(tag, names=["g", "x"]).groupby("g").agg("sum", "x")
    plan = _compiled(fl, fusion=True)
    assert any(isinstance(o.op, ops.Fuse) and
               any(isinstance(s, ops.GroupBy) for s in o.op.ops)
               for o in plan.ops)
    rep = analyze(plan, input_specs={
        "x": jax.ShapeDtypeStruct((4,), jnp.float32)})
    assert rep.ok


def test_bucket_walk_adds_batch_dim_exactly_once():
    """Regression: the bucketed re-walk used to prepend the batch dim at
    EVERY step, so shape-sensitive step 2+ saw a doubled batch dim."""
    fl = Dataflow([("x", jax.Array)])
    fl.output = (fl.map(_jid, names=["x"], gpu=True, batching=True)
                 .map(_jreshape, names=["x"], gpu=True, batching=True))
    plan = _compiled(fl, fusion=True)
    assert any(isinstance(o.op, BatchedJittedFuse) for o in plan.ops)
    rep = analyze(plan, input_specs={
        "x": jax.ShapeDtypeStruct((4,), jnp.float32)})
    assert not rep.by_code("CF101"), rep.table()


def test_array_annotation_is_public():
    from repro.core.lowering import array_annotation
    assert array_annotation(jax.Array)
    assert not array_annotation(np.ndarray)   # numpy steps stay eager
    assert not array_annotation(int)


def test_stage_input_specs_drive_model_stage_inference():
    from repro.configs import get_tiny_config
    from repro.models.registry import (build_model, model_stage_op,
                                       stage_input_specs)
    model = build_model(get_tiny_config("yi-9b"))
    params = model.init(jax.random.PRNGKey(0))
    pre = model_stage_op(model, params, "prefill", seq_len=8, cache_len=16,
                         measure=False)
    dec = model_stage_op(model, params, "decode", seq_len=8, cache_len=16,
                         measure=False)
    specs = stage_input_specs(model, "decode", seq_len=8, cache_len=16)
    assert list(specs) == list(dec.names)      # column contract agrees
    fl = Dataflow([("tokens", jax.Array)])
    fl.output = fl.apply_op(pre, gpu=True).apply_op(dec, gpu=True)
    rep = analyze(_raw(fl), input_specs=stage_input_specs(
        model, "prefill", seq_len=8, cache_len=16))
    assert rep.ok, rep.table()


# -- zero false positives over everything we ship ---------------------------

def test_shipped_flows_have_zero_errors():
    if str(REPO_ROOT) not in sys.path:
        sys.path.insert(0, str(REPO_ROOT))    # benchmarks.common import
    paths = check_cli.discover([str(REPO_ROOT / "examples"),
                                str(REPO_ROOT / "benchmarks")])
    assert paths, "no example/benchmark modules discovered"
    n_flows = 0
    for path in paths:
        reports = check_cli.check_module(path)
        if reports is None:
            continue
        for name, report in reports:
            n_flows += 1
            assert report.ok, \
                f"{path.name}:{name} has errors:\n{report.table()}"
    assert n_flows >= 20      # every shipped flow stays opted in


# -- CLI ---------------------------------------------------------------------

def test_cli_list_codes(capsys):
    assert check_cli.main(["--list-codes"]) == 0
    out = capsys.readouterr().out
    for code in CODES:
        assert code in out


_BROKEN_MODULE = '''
import jax, jax.numpy as jnp
import numpy as np
from repro.core.dataflow import Dataflow
from repro.core.table import Table

def _a(x: jax.Array) -> jax.Array:
    return x * 2

def _b(x: jax.Array) -> jax.Array:
    return jnp.dot(x, jnp.ones((5, 5)))

def check_flows():
    fl = Dataflow([("x", jax.Array)])
    fl.output = (fl.map(_a, names=["x"], gpu=True)
                 .map(_b, names=["x"], gpu=True))
    return [{"name": "broken", "flow": fl, "compile": {},
             "sample": Table([("x", jax.Array)],
                             [(np.zeros(8, np.float32),)])}]
'''


def test_cli_exit_1_on_error_diagnostics(tmp_path, capsys):
    mod = tmp_path / "broken_flow.py"
    mod.write_text(_BROKEN_MODULE)
    assert check_cli.main([str(mod)]) == 1
    out = capsys.readouterr().out
    assert "CF101" in out and "1 error(s)" in out


def test_cli_exit_1_on_crashed_module(tmp_path):
    mod = tmp_path / "crashy.py"
    mod.write_text("raise RuntimeError('broken import')\n")
    assert check_cli.main([str(mod)]) == 1


def test_cli_skips_hookless_modules(tmp_path, capsys):
    (tmp_path / "plain.py").write_text("X = 1\n")
    assert check_cli.main([str(tmp_path / "plain.py")]) == 0
    assert "checked 0 flow(s)" in capsys.readouterr().out


# -- diagnostics plumbing ----------------------------------------------------

def test_unknown_code_rejected():
    with pytest.raises(ValueError):
        Diagnostic("CF999", "nope")


def test_report_table_and_ordering():
    r = Report("demo")
    r.add(Diagnostic("CF204", "later", op_id=2))
    r.add(Diagnostic("CF201", "first", op_id=1, hint="drop donate"))
    assert [d.code for d in r.sorted()] == ["CF201", "CF204"]
    t = r.table()
    assert "1 error(s), 1 warning(s)" in t and "drop donate" in t


def test_specs_from_table_skips_non_numeric_columns():
    t = Table([("url", str), ("x", jax.Array)],
              [("img://cat.jpg", np.zeros((3, 4), np.float32))])
    specs = specs_from_table(t)
    assert specs["url"] is None
    assert specs["x"].shape == (3, 4)


# -- control-plane span events (autoscaler / blue-green attribution) --------

class _StubPool:
    def __init__(self):
        self.added = []
        self.removed = []

    def add_replica(self, fname, rclass):
        self.added.append((fname, rclass))

    def remove_replica(self, fname):
        self.removed.append(fname)


def test_autoscaler_emits_replica_change_events():
    tr = Tracer()
    pool = _StubPool()
    sc = Autoscaler(pool, {"f": "cpu"}, AutoscalerConfig(), tracer=tr)
    sc._tick_target("f", "cpu", 1, 5)         # below target: scale up
    assert len(pool.added) == 4
    for _ in range(4):                        # hysteresis, then trim
        sc._tick_target("f", "cpu", 9, 5)
    assert pool.removed == ["f"]
    evs = tr.control_events(kind="scale")
    assert [e.attrs["action"] for e in evs] == ["replica_add",
                                                "replica_remove"]
    assert evs[0].attrs["count"] == 4 and evs[0].attrs["target"] == 5


def test_replanner_emits_swap_phase_events():
    from types import SimpleNamespace

    from repro.profiling.replan import BlueGreenReplanner
    tr = Tracer()
    stub = SimpleNamespace(
        runtime=SimpleNamespace(tracer=tr),
        deployed=SimpleNamespace(dag=SimpleNamespace(name="demo")))
    for phase in ("prepare", "warm", "canary", "swap"):
        BlueGreenReplanner._phase_event(stub, phase, 1.0, 2.0, ok=True)
    evs = tr.control_events(kind="replan")
    assert [e.attrs["phase"] for e in evs] == ["prepare", "warm",
                                               "canary", "swap"]
    assert all(e.name == "replan@demo" for e in evs)


def test_control_events_ring_and_export():
    tr = Tracer()
    tr.control_event("replan@d", 1.0, 2.0, phase="swap")
    tr.control_event("scale@f", 3.0, action="replica_add")   # instant
    assert tr.stats()["control_events"] == 2
    events = to_chrome_events([], [], tr.control_events())
    control = [e for e in events if e.get("cat") == "control"]
    assert {e["ph"] for e in control} == {"X", "i"}          # span + marker
    assert all(e["pid"] == 3 for e in control)
    tids = {e["name"]: e["tid"] for e in control}
    assert tids["replan@d"] != tids["scale@f"]   # one track per kind
    tr.clear()
    assert tr.stats()["control_events"] == 0


def test_disabled_tracer_drops_control_events():
    tr = Tracer(enabled=False)
    assert tr.control_event("replan@d", 1.0, 2.0) is None
    assert tr.control_events() == []
