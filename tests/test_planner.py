"""Cost-based automatic optimization selection (paper §7, implemented)."""
import time

import numpy as np
import pytest

from repro.core.dataflow import Dataflow
from repro.core.planner import auto_deploy, make_plan, profile_flow
from repro.core.table import Table
from repro.runtime.netmodel import NetModel
from repro.runtime.runtime import Runtime


def _cheap_chain(payload_kb=256, n=4):
    def ident(x: np.ndarray) -> np.ndarray:
        return x
    fl = Dataflow([("x", np.ndarray)])
    node = fl.source
    for _ in range(n):
        node = node.map(ident, names=["x"])
    fl.output = node
    sample = Table([("x", np.ndarray)],
                   [(np.zeros(payload_kb * 128, np.float64),)])
    return fl, sample


def test_profile_collects_stats():
    fl, sample = _cheap_chain()
    profiles = profile_flow(fl, sample, runs=3)
    assert len(profiles) == 4
    for p in profiles.values():
        assert p.out_bytes > 0 and p.runs == 3


def test_planner_fuses_hop_dominated_chain():
    fl, sample = _cheap_chain(payload_kb=1024)
    plan = make_plan(fl, sample, net=NetModel())
    assert plan.fusion, plan.notes


def test_planner_keeps_compute_heavy_ops_separate():
    def heavy(x: np.ndarray) -> np.ndarray:
        time.sleep(0.05)   # compute >> hop cost
        return x
    fl = Dataflow([("x", np.ndarray)])
    node = fl.source
    for _ in range(3):
        node = node.map(heavy, names=["x"])
    fl.output = node
    sample = Table([("x", np.ndarray)], [(np.zeros(16),)])
    plan = make_plan(fl, sample, net=NetModel(), runs=2)
    assert not plan.fusion, plan.notes  # autoscaling granularity preserved


def test_planner_flags_high_variance():
    import random
    rng = random.Random(0)

    def jittery(x: int) -> int:
        time.sleep(rng.choice([0.001, 0.001, 0.05]))
        return x
    fl = Dataflow([("x", int)])
    fl.output = fl.map(jittery, names=["x"])
    sample = Table([("x", int)], [(1,)])
    plan = make_plan(fl, sample, runs=9)
    assert plan.competitive_exec, plan.notes
    assert plan.replicas


def test_planner_enables_locality_for_big_lookups():
    def use(key: str, lookup) -> float:
        return float(np.sum(lookup))
    fl = Dataflow([("key", str)])
    fl.output = fl.lookup("key", column=True).map(use, names=["s"])
    rt = Runtime(n_cpu=2, net=NetModel(scale=0.0))
    try:
        rt.kvs.put("big", np.zeros(1 << 17), charge=False)  # 1 MB
        sample = Table([("key", str)], [("big",)])
        deployed, plan = auto_deploy(fl, rt, sample, runs=2)
        assert plan.locality, plan.notes
        out = deployed.execute(sample).result(timeout=10)
        assert out.rows[0].values[-1] == 0.0
    finally:
        rt.stop()


def test_auto_deploy_end_to_end_matches_local():
    fl, sample = _cheap_chain(payload_kb=64)
    expected = fl.execute_local(sample).to_dicts()
    rt = Runtime(n_cpu=2, net=NetModel(scale=0.0))
    try:
        deployed, plan = auto_deploy(fl, rt, sample, runs=2)
        got = deployed.execute(sample).result(timeout=10).to_dicts()
        assert len(got) == len(expected)
    finally:
        rt.stop()
