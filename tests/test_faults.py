"""Fault-tolerant execution: injection, detection, retries, hedging.

* seeded :class:`FaultPlan` injection is deterministic per executor —
  the same seed replays the same fault schedule;
* an executor crash mid-item is detected, its queued + in-flight work is
  requeued onto healthy replicas, the replica is replaced, and every
  caller still gets a typed answer (zero hangs);
* a crash during a blue/green swap finishes the in-flight requests on
  blue with zero drops, and blue still drains + retires;
* transient retries respect the request's deadline budget — a backoff
  that would land past the deadline is not taken;
* straggler hedging: the backup dispatch wins, the straggling loser is
  cancelled by the completion token (exactly-once delivery, no double
  execution of user code);
* at-least-once redispatch cannot double-apply KVS writes
  (``put_once``) or double-fire callbacks (``CompletionToken``);
* regression: ``ExecutorPool.remove_replica`` used to silently drop the
  removed worker's queued items — they are requeued now;
* the admission gate blends live executor queue depth into its
  deadline-risk estimate, and counts hedges as offered load;
* the SLO controller surfaces ``fault_rate`` next to ``error_rate``, and
  a retry storm counts as an SLO miss.
"""
import threading
import time

import pytest

from repro.core.dataflow import Dataflow
from repro.core.table import Table
from repro.profiling import FlowProfile, SLOController
from repro.runtime.autoscaler import Autoscaler, AutoscalerConfig
from repro.runtime.executor import ExecutorPool, WorkItem
from repro.runtime.kvs import KVS
from repro.runtime.netmodel import NetModel
from repro.runtime.runtime import Runtime
from repro.serving.admission import AdmissionController, ClassPolicy, \
    Overloaded
from repro.serving.faults import FaultInjector, FaultPlan
from repro.serving.retry import (CompletionToken, ExecutorLost, Permanent,
                                 RetryPolicy, Transient, TransientFault,
                                 is_transient)


def _flow(seen=None, service_s=0.0, batching=False):
    def fn(i: int) -> int:
        if seen is not None:
            seen.append(i)
        if service_s:
            time.sleep(service_s)
        return i + 1

    fl = Dataflow([("i", int)])
    fl.output = fl.map(fn, names=["i"], batching=batching)
    return fl


def _t(i=1):
    return Table([("i", int)], [(i,)])


# ---------------------------------------------------------------------------
# taxonomy + retry policy (unit)
# ---------------------------------------------------------------------------

def test_error_taxonomy():
    assert is_transient(TransientFault("x"))
    assert is_transient(ExecutorLost("x"))
    assert is_transient(ConnectionError("x"))
    assert not is_transient(Permanent("x"))
    assert not is_transient(ValueError("x"))     # unknown = permanent
    # a Permanent subclass of Transient stays permanent (checked first)
    class Both(Transient, Permanent):
        pass
    assert not is_transient(Both("x"))


def test_retry_policy_respects_deadline_budget():
    pol = RetryPolicy(max_attempts=5, base_s=0.010, multiplier=1.0,
                      cap_s=0.010, jitter=0.0)
    now = 100.0
    err = TransientFault("x")
    # plenty of budget: retry
    assert pol.next_delay(0, err, now, deadline_t=now + 1.0) == \
        pytest.approx(0.010)
    # backoff would land past the deadline: delivered instead
    assert pol.next_delay(0, err, now, deadline_t=now + 0.005) is None
    # attempts exhausted
    assert pol.next_delay(4, err, now, deadline_t=now + 1.0) is None
    # permanent errors never retry
    assert pol.next_delay(0, ValueError("x"), now,
                          deadline_t=now + 1.0) is None


def test_completion_token_claims_exactly_once():
    tok = CompletionToken()
    results = [tok.claim(f"e{i}") for i in range(5)]
    assert results.count(True) == 1
    assert tok.claimed and tok.winner == "e0"


# ---------------------------------------------------------------------------
# injection determinism (unit)
# ---------------------------------------------------------------------------

def test_fault_injection_is_deterministic_per_executor():
    plan = FaultPlan(seed=42).crash(rate=0.3).transient(rate=0.2)

    def schedule(executor_id):
        inj = FaultInjector(FaultPlan(
            specs=list(plan.specs), seed=plan.seed))
        out = []
        for _ in range(200):
            f = inj.draw(executor_id, "cpu")
            out.append(f.kind if f is not None else None)
        return out

    a, b = schedule("cpu-exec-0"), schedule("cpu-exec-0")
    assert a == b
    assert any(k == "crash" for k in a)
    assert any(k == "transient" for k in a)
    # a different executor id sees a DIFFERENT (but also deterministic)
    # sequence: per-executor seeding, independent of interleaving
    assert schedule("cpu-exec-1") != a


def test_fault_spec_limit_and_classes():
    plan = FaultPlan(seed=0).crash(rate=1.0, limit=2, classes=["gpu"])
    inj = FaultInjector(plan)
    assert inj.draw("e0", "cpu") is None          # wrong class
    assert inj.draw("e0", "gpu").kind == "crash"
    assert inj.draw("e0", "gpu").kind == "crash"
    assert inj.draw("e0", "gpu") is None          # limit exhausted
    assert inj.snapshot() == {"crash": 2, "hang": 0, "transient": 0}


# ---------------------------------------------------------------------------
# crash detection + recovery (integration)
# ---------------------------------------------------------------------------

def test_crash_is_detected_requeued_and_replaced():
    rt = Runtime(n_cpu=3, net=NetModel(scale=0.0),
                 detector_interval_s=0.02)
    try:
        fl = _flow()
        fl.deploy(rt, name="f")
        assert fl.execute(_t()).result(timeout=10).rows[0].values[0] == 2
        n0 = len(rt.pool.executors)
        rt.set_fault_plan(FaultPlan(seed=1).crash(rate=1.0, limit=1))
        # the crashed attempt is requeued; the caller never notices
        assert fl.execute(_t(5)).result(timeout=10).rows[0].values[0] == 6
        rt.set_fault_plan(None)
        assert rt.pool.fault_counts["crash"] == 1
        assert rt.pool.fault_counts["requeued"] >= 1
        assert rt.pool.fault_counts["replaced"] == 1
        # dead replica excluded, replacement added: pool size restored
        healthy = [e for e in rt.pool.executors.values() if e.healthy]
        assert len(healthy) == n0
        snap = rt.metrics_snapshot()
        assert len(snap.get("faults/crash_t", [])) == 1
        # a RECOVERED crash is not a request error
        assert "dag/f/error_t" not in snap
    finally:
        rt.stop()


def test_wedged_executor_fails_over_in_flight_item():
    rt = Runtime(n_cpu=2, net=NetModel(scale=0.0), hang_timeout_s=0.15,
                 detector_interval_s=0.02)
    try:
        fl = _flow()
        fl.deploy(rt, name="w")
        assert fl.execute(_t()).result(timeout=10).rows[0].values[0] == 2
        # straggle well past the wedge timeout: the detector clones the
        # in-flight item onto a healthy replica
        rt.set_fault_plan(FaultPlan(seed=7).hang(rate=1.0, hang_s=2.0,
                                                 limit=1))
        t0 = time.perf_counter()
        assert fl.execute(_t(3)).result(timeout=10).rows[0].values[0] == 4
        assert time.perf_counter() - t0 < 1.5   # did not wait out the hang
        rt.set_fault_plan(None)
        assert rt.pool.fault_counts["wedge"] == 1
        assert len(rt.metrics_snapshot().get("faults/wedge_t", [])) == 1
    finally:
        rt.stop()


def test_no_healthy_replica_fails_typed_never_hangs():
    pool = ExecutorPool(KVS(), NetModel(scale=0.0), n_cpu=1)
    try:
        errors = []
        item = WorkItem(fn=lambda tables, ctx: tables[0],
                        tables=[_t()], produced_on=[None],
                        callback=lambda r, e, x: errors.append(e))
        # the ONLY replica is excluded: requeue must fail the item typed
        only = next(iter(pool.executors.values()))
        n = pool.requeue([item], "cpu", exclude={only.id})
        assert n == 0
        assert len(errors) == 1 and isinstance(errors[0], ExecutorLost)
        assert pool.fault_counts["lost"] == 1
    finally:
        pool.stop()


def test_autoscaler_replaces_failed_replica_below_min():
    pool = ExecutorPool(KVS(), NetModel(scale=0.0), n_cpu=2,
                        auto_replace=False)
    try:
        ids = list(pool.executors)
        pool.assign("f", ids)
        asc = Autoscaler(pool, {"f": "cpu"},
                         AutoscalerConfig(interval_s=0.02, min_replicas=2))
        asc.start()
        try:
            # fail one replica by hand (auto_replace off: replacement is
            # the autoscaler's job here)
            pool._handle_failure(pool.executors[ids[0]], "crash")
            assert pool.replica_count("f") == 1
            deadline = time.perf_counter() + 5.0
            while pool.replica_count("f") < 2:
                assert time.perf_counter() < deadline, \
                    "autoscaler never replaced the failed replica"
                time.sleep(0.01)
        finally:
            asc.stop()
    finally:
        pool.stop()


# ---------------------------------------------------------------------------
# crash during a blue/green swap (integration)
# ---------------------------------------------------------------------------

def test_crash_during_swap_finishes_on_blue_zero_drops():
    rt = Runtime(n_cpu=3, net=NetModel(scale=0.0), batch_wait_ms=2.0,
                 detector_interval_s=0.02)
    try:
        blue_seen, green_seen = [], []
        _flow(blue_seen, service_s=0.03, batching=True).deploy(
            rt, name="bg")
        # in-flight blue requests, with a crash injected mid-swap
        rt.set_fault_plan(FaultPlan(seed=3).crash(rate=1.0, limit=1))
        futs = [rt.call_dag("bg", _t(i)) for i in range(6)]
        # swap: green generation goes live while blue is still serving
        _flow(green_seen, batching=True).deploy(rt, name="bg")
        # zero drops: every blue request resolves, on blue's nodes
        got = sorted(f.result(timeout=10).rows[0].values[0] for f in futs)
        assert got == [i + 1 for i in range(6)]
        rt.set_fault_plan(None)
        assert rt.pool.fault_counts["crash"] == 1
        assert sorted(blue_seen) == list(range(6))
        assert green_seen == []
        # blue drains + retires despite the crash: batcher accounting
        # (accepted minus completed) survived the failover
        deadline = time.perf_counter() + 5.0
        while rt.sweep_retired() or rt._draining:
            assert time.perf_counter() < deadline, \
                "blue generation never drained after the crash"
            time.sleep(0.01)
        # green serves new traffic
        assert rt.call_dag("bg", _t(9)).result(
            timeout=10).rows[0].values[0] == 10
        assert 9 in green_seen
    finally:
        rt.stop()


# ---------------------------------------------------------------------------
# transient retries (integration)
# ---------------------------------------------------------------------------

def test_transient_fault_is_retried_to_success():
    rt = Runtime(n_cpu=2, net=NetModel(scale=0.0))
    try:
        seen = []
        fl = _flow(seen)
        fl.deploy(rt, name="r")
        fl.execute(_t()).result(timeout=10)
        rt.set_fault_plan(FaultPlan(seed=2).transient(rate=1.0, limit=1))
        assert fl.execute(_t(7)).result(timeout=10).rows[0].values[0] == 8
        snap = rt.metrics_snapshot()
        assert len(snap.get("dag/r/retry_t", [])) == 1
        assert "dag/r/error_t" not in snap       # recovered, not failed
    finally:
        rt.stop()


def test_exhausted_retries_deliver_the_typed_transient():
    rt = Runtime(n_cpu=2, net=NetModel(scale=0.0),
                 retry_policies={"default": RetryPolicy(
                     max_attempts=2, base_s=0.001, jitter=0.0)})
    try:
        fl = _flow()
        fl.deploy(rt, name="x")
        fl.execute(_t()).result(timeout=10)
        # every attempt faults: the caller gets the typed error, fast
        rt.set_fault_plan(FaultPlan(seed=4).transient(rate=1.0))
        with pytest.raises(Transient):
            fl.execute(_t()).result(timeout=10)
        rt.set_fault_plan(None)
        snap = rt.metrics_snapshot()
        assert len(snap.get("dag/x/retry_t", [])) == 1   # max_attempts=2
        assert len(snap.get("dag/x/error_t", [])) == 1   # the delivery
    finally:
        rt.stop()


def test_retry_never_taken_past_deadline_budget():
    rt = Runtime(n_cpu=2, net=NetModel(scale=0.0),
                 retry_policies={"default": RetryPolicy(
                     max_attempts=10, base_s=0.5, multiplier=1.0,
                     cap_s=0.5, jitter=0.0)})
    try:
        fl = _flow()
        fl.deploy(rt, name="d")
        fl.execute(_t()).result(timeout=10)
        rt.set_fault_plan(FaultPlan(seed=6).transient(rate=1.0))
        # 100ms budget, 500ms backoff: the (first) failure is delivered
        # immediately instead of burning the budget in backoff sleeps
        t0 = time.perf_counter()
        with pytest.raises(Transient):
            rt.call_dag("d", _t(), deadline_s=0.1).result(timeout=10)
        assert time.perf_counter() - t0 < 0.4
        rt.set_fault_plan(None)
        assert "dag/d/retry_t" not in rt.metrics_snapshot()
    finally:
        rt.stop()


# ---------------------------------------------------------------------------
# straggler hedging (integration)
# ---------------------------------------------------------------------------

def test_hedge_wins_and_cancels_straggling_loser():
    rt = Runtime(n_cpu=3, net=NetModel(scale=0.0), hang_timeout_s=30.0)
    try:
        seen = []
        fl = _flow(seen)
        dep = fl.deploy(rt, name="h")
        fl.execute(_t()).result(timeout=10)
        seen.clear()
        rt.configure_hedging("h", dep.dag.output, 0.03)
        # the primary straggles far past the hedge delay (but below the
        # wedge timeout: this is hedging's win, not the detector's)
        rt.set_fault_plan(FaultPlan(seed=5).hang(rate=1.0, hang_s=0.8,
                                                 limit=1))
        t0 = time.perf_counter()
        assert fl.execute(_t(3)).result(timeout=10).rows[0].values[0] == 4
        assert time.perf_counter() - t0 < 0.5    # did not wait out the hang
        rt.set_fault_plan(None)
        assert len(rt.metrics_snapshot().get("dag/h/hedge_t", [])) == 1
        # loser cancellation: when the straggler wakes it finds the token
        # claimed and skips execution — user code ran exactly once
        time.sleep(1.0)
        assert seen == [3]
        assert rt.pool.fault_counts["wedge"] == 0
    finally:
        rt.stop()


def test_hedge_suppressed_by_admission_gate_under_overload():
    adm = AdmissionController(
        classes={"interactive": ClassPolicy("interactive", priority=2)},
        queue_depth_fn=lambda: 100_000, queue_cost_s=1e-3)
    # 100s of modeled backlog vs a 50ms deadline: no headroom for backups
    assert adm.note_hedge("interactive", deadline_s=0.05) is False
    snap = adm.snapshot()
    assert snap["interactive/hedge_offered"] == 1
    assert snap["interactive/hedge_suppressed"] == 1
    # hedges count as offered load in the arrival window
    assert adm.rate_at_or_above(2, time.perf_counter()) > 0
    # with headroom (no deadline pressure) the hedge is admitted
    assert adm.note_hedge("interactive", deadline_s=None) is True


# ---------------------------------------------------------------------------
# idempotence under forced double execution
# ---------------------------------------------------------------------------

def test_double_execution_applies_kvs_write_once():
    pool = ExecutorPool(KVS(), NetModel(scale=0.0), n_cpu=2)
    try:
        ran, delivered = [], []
        gate = threading.Event()

        def fn(tables, ctx):
            ran.append(1)
            ctx.kvs_put("model/state", "v1")
            gate.wait(5.0)
            return tables[0]

        item = WorkItem(fn=fn, tables=[_t()], produced_on=[None],
                        callback=lambda r, e, x: delivered.append((r, e)),
                        dispatch_key=("req", "node", 0))
        # force at-least-once: the item AND its clone each execute
        e1, e2 = list(pool.executors.values())
        e1.submit(item)
        e2.submit(item.clone())
        deadline = time.perf_counter() + 5.0
        while len(ran) < 2:
            assert time.perf_counter() < deadline
            time.sleep(0.005)
        gate.set()
        deadline = time.perf_counter() + 5.0
        while sum(e.completed for e in (e1, e2)) < 2:
            assert time.perf_counter() < deadline
            time.sleep(0.005)
        # both executed, ONE delivered, ONE write applied
        assert len(ran) == 2
        assert len(delivered) == 1 and delivered[0][1] is None
        assert pool.kvs.stats["puts"] == 1
        assert pool.kvs.stats["dedup_puts"] == 1
        assert pool.kvs.get("model/state", charge=False) == "v1"
    finally:
        pool.stop()


# ---------------------------------------------------------------------------
# regression: remove_replica / stop() lost queued work
# ---------------------------------------------------------------------------

def test_remove_replica_requeues_instead_of_dropping():
    pool = ExecutorPool(KVS(), NetModel(scale=0.0), n_cpu=2)
    try:
        ids = list(pool.executors)
        pool.assign("f", ids)
        release = threading.Event()
        done = []

        def blocker(tables, ctx):
            release.wait(5.0)
            return tables[0]

        def quick(tables, ctx):
            return tables[0]

        victim = pool.executors[ids[-1]]     # remove_replica trims ids[-1]
        victim.submit(WorkItem(fn=blocker, tables=[_t()],
                               produced_on=[None],
                               callback=lambda r, e, x: done.append("b")))
        time.sleep(0.05)                     # let the blocker start
        for _ in range(3):
            victim.submit(WorkItem(fn=quick, tables=[_t()],
                                   produced_on=[None],
                                   callback=lambda r, e, x:
                                       done.append("q")))
        assert pool.remove_replica("f") == ids[-1]
        # pre-fix: the 3 queued items vanished, callbacks never fired
        deadline = time.perf_counter() + 5.0
        while done.count("q") < 3:
            assert time.perf_counter() < deadline, \
                f"queued items dropped by remove_replica: {done}"
            time.sleep(0.005)
        release.set()
        deadline = time.perf_counter() + 5.0
        while "b" not in done:
            assert time.perf_counter() < deadline
            time.sleep(0.005)
    finally:
        pool.stop()


def test_pool_stop_fails_leftover_items_typed():
    pool = ExecutorPool(KVS(), NetModel(scale=0.0), n_cpu=1)
    release = threading.Event()
    outcomes = []

    def blocker(tables, ctx):
        release.wait(5.0)
        return tables[0]

    ex = next(iter(pool.executors.values()))
    ex.submit(WorkItem(fn=blocker, tables=[_t()], produced_on=[None],
                       callback=lambda r, e, x: outcomes.append(e)))
    time.sleep(0.05)
    ex.submit(WorkItem(fn=blocker, tables=[_t()], produced_on=[None],
                       callback=lambda r, e, x: outcomes.append(e)))
    pool.stop()          # the queued second item must fail, not vanish
    release.set()
    deadline = time.perf_counter() + 5.0
    while len(outcomes) < 2:
        assert time.perf_counter() < deadline, \
            "pool.stop() stranded a queued item"
        time.sleep(0.005)
    assert any(isinstance(e, RuntimeError) for e in outcomes)


# ---------------------------------------------------------------------------
# queue-depth admission signal (satellite)
# ---------------------------------------------------------------------------

def test_queue_depth_sheds_with_its_own_reason():
    adm = AdmissionController(
        classes={"interactive": ClassPolicy("interactive", priority=2)},
        queue_depth_fn=lambda: 50_000, queue_cost_s=1e-3)
    d = adm.admit("interactive", deadline_s=0.05)
    assert not d.admitted
    assert d.reason == "queue_depth"
    assert d.estimate_s == pytest.approx(50.0)
    # empty queues: the same gate admits
    adm2 = AdmissionController(
        classes={"interactive": ClassPolicy("interactive", priority=2)},
        queue_depth_fn=lambda: 0, queue_cost_s=1e-3)
    assert adm2.admit("interactive", deadline_s=0.05).admitted


def test_runtime_autowires_pool_depth_into_admission():
    rt = Runtime(n_cpu=1, net=NetModel(scale=0.0))
    try:
        adm = AdmissionController(classes={
            "interactive": ClassPolicy("interactive", priority=2)})
        rt.set_admission("z", adm)
        assert adm.queue_depth_fn is not None
        assert adm.queue_depth_fn() == rt.pool.total_depth()
    finally:
        rt.stop()


# ---------------------------------------------------------------------------
# controller: fault_rate detail + retry-storm escalation (satellite)
# ---------------------------------------------------------------------------

def test_controller_surfaces_fault_rate_and_retry_storm():
    rt = Runtime(n_cpu=1, net=NetModel(scale=0.0))
    try:
        fl = _flow()
        dep = fl.deploy(rt, name="c")
        ctl = SLOController(rt, dep, slo_p99_s=1.0,
                            profile=FlowProfile(), window_s=5.0)
        now = time.perf_counter()
        rt.record_metric("faults/crash_t", now)
        rt.record_metric("dag/c/hedge_t", now)
        fr = ctl.fault_rate()
        assert fr["crash_rate"] > 0 and fr["hedge_rate"] > 0
        assert fr["storm"] == 0.0
        # a retry storm: recovery work dwarfs completions (arrivals are
        # spread so the tick's rate estimate clears the idle threshold)
        for i in range(5):
            rt.record_metric("dag/c/request_t", now - 2.0 + i * 0.4)
        for _ in range(40):
            rt.record_metric("dag/c/retry_t", now)
        fr = ctl.fault_rate()
        assert fr["storm"] == 1.0
        ev = ctl.tick()
        assert ev.detail["fault"]["storm"] == 1.0
        assert ev.detail["slo_ok"] is False      # the storm IS an SLO miss
    finally:
        rt.stop()
