"""Per-arch smoke tests (required): instantiate the REDUCED variant of each
assigned architecture, run one forward/train step on CPU, assert output
shapes + no NaNs; plus prefill->decode consistency vs the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_tiny_config, get_config
from repro.models import build_model
from repro.training.optim import OptConfig
from repro.training.train_step import init_train_state, make_train_step

B, S = 2, 16


def _batch(cfg, key, seq=S):
    batch = {"tokens": jax.random.randint(key, (B, seq), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["media"] = (jax.random.normal(
            key, (B, cfg.num_media_tokens, cfg.d_model)) * 0.02).astype(
                jnp.dtype(cfg.dtype))
    if cfg.family == "audio":
        batch["frames"] = (jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model)) * 0.02).astype(
                jnp.dtype(cfg.dtype))
    return batch


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_config_limits(arch):
    cfg = get_tiny_config(arch)
    assert cfg.num_layers <= 6
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, key):
    cfg = get_tiny_config(arch)
    model = build_model(cfg)
    params = model.init(key)
    logits, aux = model.logits(params, _batch(cfg, key), remat=False)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch, key):
    cfg = get_tiny_config(arch)
    model = build_model(cfg)
    state = init_train_state(model, key, OptConfig(name=cfg.optimizer))
    step = make_train_step(model, OptConfig(name=cfg.optimizer))
    new_state, metrics = step(state, _batch(cfg, key))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda p, q: float(jnp.sum(jnp.abs(
            p.astype(jnp.float32) - q.astype(jnp.float32)))),
            state["params"], new_state["params"]))
    assert delta > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch, key):
    cfg = get_tiny_config(arch)
    model = build_model(cfg)
    params = model.init(key)
    batch = _batch(cfg, key, seq=S + 1)
    toks = batch["tokens"]
    full, _ = model.logits(params, batch, remat=False)
    pre_batch = dict(batch)
    pre_batch["tokens"] = toks[:, :S]
    _, cache = model.prefill(params, pre_batch, cache_len=S + 4)
    dec, _ = model.decode_step(params, toks[:, S:S + 1],
                               jnp.full((B,), S, jnp.int32), cache)
    a = np.asarray(full[:, S].astype(jnp.float32))
    b = np.asarray(dec[:, 0].astype(jnp.float32))
    rel = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    assert rel < 0.06, f"{arch}: decode/forward mismatch {rel:.4f}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_numbers(arch):
    """Full configs expose the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "yi-9b": (48, 4096, 32, 4, 11008, 64000),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "rwkv6-1.6b": (24, 2048, 0, 0, 7168, 65536),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected
    if arch == "arctic-480b":
        assert (cfg.num_experts, cfg.num_experts_per_tok,
                cfg.dense_residual) == (128, 2, True)
    if arch == "llama4-maverick-400b-a17b":
        assert (cfg.num_experts, cfg.num_experts_per_tok,
                cfg.moe_layer_period) == (128, 1, 2)
    if arch == "gemma2-9b":
        assert cfg.sliding_window == 4096
        assert cfg.attn_logit_softcap == 50.0
    if arch == "recurrentgemma-2b":
        assert cfg.attn_layer_period == 3 and cfg.sliding_window == 2048
