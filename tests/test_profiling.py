"""Profiling subsystem: batch-sweep profiler curves, serialization
round-trips (FlowProfile + ChainProfile), the M/M/c + critical-path
estimator, and the SLO-aware configuration search."""
import json
import math

import numpy as np
import pytest

try:
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jax = None

pytestmark = pytest.mark.skipif(jax is None, reason="requires jax")

from repro.core.dataflow import Dataflow
from repro.core.ir import PhysicalPlan
from repro.core.lowering import BatchedJittedFuse, ChainProfile
from repro.core.passes import PassContext, build_pipeline
from repro.core.table import Table
from repro.profiling import (BucketStats, FlowProfile, LatencyEstimator,
                             NodeConfig, OpLatencyCurve, PlanConfig,
                             Workload, erlang_c, profile_plan, propose)
from repro.runtime.netmodel import NetModel


def _mul(x: jax.Array) -> jax.Array:
    return x * 2.0


def _add(x: jax.Array) -> jax.Array:
    return x + 1.0


def _lowered_plan():
    fl = Dataflow([("x", jax.Array)])
    fl.output = fl.map(_mul, names=["x"], gpu=True, batching=True) \
        .map(_add, names=["x"], gpu=True, batching=True)
    plan = PhysicalPlan.from_dataflow(fl)
    plan = build_pipeline(fusion=True).run(plan, PassContext())
    return fl, plan


def _sample(n=1):
    t = Table([("x", jax.Array)])
    for i in range(n):
        t.insert((jnp.ones(32, jnp.float32) * i,))
    return t


def _synthetic_curve(key, per_row_s=2e-3, base=2e-3, slope=1e-4,
                     buckets=(1, 2, 4, 8, 16)):
    """Strongly sublinear batched curve: batching pays off under load."""
    c = OpLatencyCurve(key=key, name=f"op{key}", per_row_s=per_row_s)
    for b in buckets:
        mean = base + slope * b
        c.buckets[b] = BucketStats(mean_s=mean, p99_s=1.2 * mean, cv=0.05,
                                   runs=3, out_bytes=64 * b)
    return c


# ---------------------------------------------------------------------------
# profiler
# ---------------------------------------------------------------------------

def test_profile_plan_sweeps_buckets_and_per_row():
    _, plan = _lowered_plan()
    fp = profile_plan(plan, _sample(), batch_sizes=(1, 2, 4), runs=2)
    assert len(fp.curves) == len(plan.ops)
    lowered = [o for o in plan.ops if isinstance(o.op, BatchedJittedFuse)]
    assert lowered, "expected a batched-lowered chain"
    for o in plan.ops:
        curve = fp.curves[o.op_id]
        assert sorted(curve.buckets) == [1, 2, 4]
        for st in curve.buckets.values():
            assert st.mean_s > 0 and st.runs == 2 and st.out_bytes > 0
            assert st.p99_s >= st.mean_s
    # the batched-lowered chain also measured its per-row executable
    assert fp.curves[lowered[0].op_id].per_row_s is not None


def test_flow_profile_json_roundtrip(tmp_path):
    _, plan = _lowered_plan()
    fp = profile_plan(plan, _sample(), batch_sizes=(1, 2), runs=2)
    d = fp.to_dict()
    # JSON-stable: survives an actual dump/load cycle unchanged
    fp2 = FlowProfile.from_dict(json.loads(json.dumps(d)))
    assert fp2.to_dict() == d
    p = tmp_path / "profile.json"
    fp.save(str(p))
    fp3 = FlowProfile.load(str(p))
    assert fp3.to_dict() == d
    for k, c in fp.curves.items():
        assert fp3.curves[k].service_s(3) == c.service_s(3)


def test_curve_service_model():
    c = _synthetic_curve(1)
    # exact bucket
    assert c.service_s(4) == c.buckets[4].mean_s
    # padded up to the next measured bucket (what batched exec pays)
    assert c.service_s(3) == c.buckets[4].mean_s
    # beyond the largest bucket: linear extrapolation
    assert c.service_s(32) == pytest.approx(c.buckets[16].mean_s * 2)
    assert c.row_s() == 2e-3


def test_curve_merge_chain_profile_refreshes_means():
    c = _synthetic_curve(1)
    prof = ChainProfile()
    for _ in range(4):
        prof.note_per_row(5e-3)
        prof.note_batched(8, 3e-3)
    assert c.merge_chain_profile(prof)
    assert c.per_row_s == pytest.approx(5e-3)
    assert c.buckets[8].mean_s == pytest.approx(3e-3)
    # tail ratio preserved on refresh
    assert c.buckets[8].p99_s == pytest.approx(1.2 * 3e-3)
    # merging identical data again reports no change
    assert not c.merge_chain_profile(prof)


# ---------------------------------------------------------------------------
# ChainProfile serialization (satellite)
# ---------------------------------------------------------------------------

def test_chain_profile_json_roundtrip():
    p = ChainProfile(alpha=0.4)
    for _ in range(5):
        p.note_per_row(1e-3)
        p.note_batched(4, 2e-3)     # first batched sample is discarded
        p.note_batched(16, 3e-3)
    q = ChainProfile.from_dict(json.loads(json.dumps(p.to_dict())))
    assert q.alpha == p.alpha
    assert q.per_row_s == pytest.approx(p.per_row_s)
    assert q.per_row_samples == p.per_row_samples
    assert q.batched_s == pytest.approx(p.batched_s)
    assert q.batched_samples == p.batched_samples
    # crossover consistency: the restored profile routes identically
    assert q.crossover_rows() == p.crossover_rows()
    assert p.crossover_rows() is not None
    for n in (1, 2, 3, 5, 8, 16):
        b = 4 if n <= 4 else 16
        assert q.prefer_per_row(n, b) == p.prefer_per_row(n, b)


def test_chain_profile_empty_roundtrip():
    p = ChainProfile()
    q = ChainProfile.from_dict(p.to_dict())
    assert q.per_row_s is None and q.batched_s == {}
    assert q.crossover_rows() is None


# ---------------------------------------------------------------------------
# estimator
# ---------------------------------------------------------------------------

def test_erlang_c_known_values():
    # M/M/1: P(wait) = rho
    assert erlang_c(1, 0.5) == pytest.approx(0.5)
    assert erlang_c(1, 0.0) == 0.0
    assert erlang_c(1, 1.0) == 1.0          # saturation
    assert erlang_c(2, 0.5) < erlang_c(1, 0.5)
    # monotone in offered load
    assert erlang_c(4, 3.0) > erlang_c(4, 1.0)


def _one_node_plan():
    def slow(x: jax.Array) -> jax.Array:
        return x
    fl = Dataflow([("x", jax.Array)])
    fl.output = fl.map(slow, names=["x"])
    return PhysicalPlan.from_dataflow(fl)


def test_estimator_replicas_and_rate_move_p99():
    plan = _one_node_plan()
    op_id = plan.ops[0].op_id
    fp = FlowProfile(curves={op_id: _synthetic_curve(op_id)})
    est = LatencyEstimator(fp, net=NetModel(scale=0.0))

    def p99(rate, c):
        cfg = PlanConfig(nodes={op_id: NodeConfig(target_replicas=c)})
        return est.estimate(plan, cfg, Workload(rate))

    # more replicas -> lower p99 at fixed rate (near saturation)
    assert p99(450.0, 2).p99_s < p99(450.0, 1).p99_s
    # higher rate -> higher p99 at fixed replicas
    assert p99(400.0, 1).p99_s > p99(100.0, 1).p99_s
    # saturated single replica flagged infeasible (service 2ms, 600/s)
    sat = p99(600.0, 1)
    assert not sat.feasible and not sat.meets(1.0)
    assert p99(600.0, 2).feasible


def test_estimator_batching_raises_throughput():
    plan = _one_node_plan()
    op_id = plan.ops[0].op_id
    fp = FlowProfile(curves={op_id: _synthetic_curve(op_id)})
    est = LatencyEstimator(fp, net=NetModel(scale=0.0))
    rate = 2000.0           # per-row: 2000 * 2ms = 4 erlangs, hopeless
    per_row = est.estimate(plan, PlanConfig(nodes={op_id: NodeConfig(
        max_batch=1, batched_lowering=False)}), Workload(rate))
    batched = est.estimate(plan, PlanConfig(nodes={op_id: NodeConfig(
        max_batch=16, batch_wait_ms=8.0, batched_lowering=True)}),
        Workload(rate))
    assert not per_row.feasible
    assert batched.feasible
    assert batched.p99_s < per_row.p99_s


def test_estimator_critical_path_and_wait_any():
    # diamond: source -> a -> (b slow | c fast) -> join
    def f(x: jax.Array) -> jax.Array:
        return x
    fl = Dataflow([("x", jax.Array)])
    a = fl.map(f, names=["x"])
    b = a.map(f, names=["x"])
    c = a.map(f, names=["x"])
    fl.output = b.anyof(c)
    plan = PhysicalPlan.from_dataflow(fl)
    ids = [o.op_id for o in plan.ops]
    curves = {i: _synthetic_curve(i, base=1e-3, slope=0.0) for i in ids}
    # make one branch much slower
    slow_id = ids[1]
    curves[slow_id] = _synthetic_curve(slow_id, base=50e-3, slope=0.0)
    est = LatencyEstimator(FlowProfile(curves=curves),
                           net=NetModel(scale=0.0))
    res = est.estimate(plan, PlanConfig(), Workload(10.0))
    # wait-any fires on the FAST branch: the slow op is off the path
    assert slow_id not in res.critical_path
    assert res.p99_s < 25e-3


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_propose_meets_slo_when_feasible():
    plan = _one_node_plan()
    op_id = plan.ops[0].op_id
    # batching allowed via the batching annotation
    plan = plan.with_ops([plan.ops[0].replace(batching=True)])
    fp = FlowProfile(curves={op_id: _synthetic_curve(op_id)})
    cfg = propose(plan, slo_p99=0.05, arrival_rate=2000.0, profile=fp,
                  net=NetModel(scale=0.0))
    assert cfg.predicted is not None
    assert cfg.predicted.meets(0.05), cfg.notes
    nc = cfg.nodes[op_id]
    # per-row at 2000/s is 4 erlangs: must batch and/or replicate
    assert nc.max_batch > 1 or nc.target_replicas > 1
    assert cfg.predicted.p99_s <= 0.05


def test_propose_prefers_per_row_when_sparse():
    plan = _one_node_plan()
    op_id = plan.ops[0].op_id
    plan = plan.with_ops([plan.ops[0].replace(batching=True)])
    fp = FlowProfile(curves={op_id: _synthetic_curve(op_id)})
    cfg = propose(plan, slo_p99=0.05, arrival_rate=20.0, profile=fp,
                  net=NetModel(scale=0.0))
    nc = cfg.nodes[op_id]
    # waiting (b-1)/lambda at 20/s dwarfs any batching win
    assert nc.max_batch == 1
    assert nc.batch_wait_ms == 0.0
    assert cfg.predicted.meets(0.05)


def test_propose_infeasible_reports_honestly():
    plan = _one_node_plan()
    op_id = plan.ops[0].op_id
    # brutal curve: 50ms/row, no batching win, SLO 10ms at 1000/s
    c = OpLatencyCurve(key=op_id, name="slow", per_row_s=50e-3)
    c.buckets[1] = BucketStats(mean_s=50e-3, p99_s=60e-3, cv=0.0, runs=2,
                               out_bytes=64)
    cfg = propose(plan, slo_p99=0.01, arrival_rate=1000.0,
                  profile=FlowProfile(curves={op_id: c}),
                  net=NetModel(scale=0.0), max_replicas=4)
    assert cfg.predicted is not None
    assert not cfg.predicted.meets(0.01)
    assert any("NOT met" in n for n in cfg.notes)


def test_plan_config_json_roundtrip():
    cfg = PlanConfig(nodes={
        1: NodeConfig(max_batch=8, batch_buckets=(1, 2, 4, 8),
                      batch_wait_ms=3.5, target_replicas=2),
        2: NodeConfig(batched_lowering=False, competitive_replicas=3,
                      placement="gpu"),
    }, slo_p99_s=0.05, arrival_rate=500.0, notes=["n"])
    d = json.loads(json.dumps(cfg.to_dict()))
    cfg2 = PlanConfig.from_dict(d)
    assert cfg2.nodes[1] == cfg.nodes[1]
    assert cfg2.nodes[2] == cfg.nodes[2]
    assert cfg2.slo_p99_s == 0.05 and cfg2.arrival_rate == 500.0
    assert cfg.bucket_overrides() == {1: (1, 2, 4, 8)}
    assert cfg.batched_overrides()[2] is False
    assert cfg.replica_overrides() == {2: 3}
    assert not cfg.differs_runtime(cfg2)
    assert not cfg.needs_recompile(cfg2)
    cfg2.nodes[1].batch_wait_ms = 9.0
    assert cfg.differs_runtime(cfg2)
    cfg2.nodes[2].batched_lowering = True
    assert cfg.needs_recompile(cfg2)


def test_plan_config_threads_through_pipeline():
    """PlanConfig per-op overrides reach the lowering pass: custom padding
    buckets land on the op's annotations, per-row lowering is honored."""
    fl, plan0 = _lowered_plan()
    lowered_id = next(o.op_id for o in plan0.ops
                      if isinstance(o.op, BatchedJittedFuse))
    cfg = PlanConfig(nodes={lowered_id: NodeConfig(
        max_batch=4, batch_buckets=(1, 2, 4), batched_lowering=True)})
    plan = PhysicalPlan.from_dataflow(fl)
    plan = build_pipeline(fusion=True, plan_config=cfg).run(
        plan, PassContext())
    o = plan.op(lowered_id)
    assert o.batch_buckets == (1, 2, 4)
    assert o.op.bucket_sizes == (1, 2, 4)
    # flip to per-row lowering
    cfg.nodes[lowered_id].batched_lowering = False
    plan = PhysicalPlan.from_dataflow(fl)
    plan = build_pipeline(fusion=True, plan_config=cfg).run(
        plan, PassContext())
    o = plan.op(lowered_id)
    assert not o.batchable and not isinstance(o.op, BatchedJittedFuse)
    assert o.op.name.startswith("jit[")


def test_apply_config_pass_stamps_competitive_and_placement():
    def f(x: int) -> int:
        return x + 1

    def g(x: int) -> int:
        return x - 1
    fl = Dataflow([("x", int)])
    # an unrelated high_variance-hinted op the config does NOT name: a
    # config-driven compile must not silently replicate it
    hv = fl.map(g, names=["x"], high_variance=True)
    fl.output = hv.map(f, names=["x"])
    plan = PhysicalPlan.from_dataflow(fl)
    hv_id, op_id = plan.ops[0].op_id, plan.ops[1].op_id
    cfg = PlanConfig(nodes={op_id: NodeConfig(competitive_replicas=3,
                                              placement="gpu")})
    out = build_pipeline(plan_config=cfg, jit_fusion=False).run(
        plan, PassContext())
    # competitive pass expanded ONLY the stamped op into 3 replicas +
    # wait-any; the high_variance hint alone did not expand
    anyof = [o for o in out.ops if o.wait_any]
    assert len(anyof) == 1 and anyof[0].op_id == op_id
    replicas = [o for o in out.ops
                if not o.wait_any and o.op_id != hv_id]
    assert len(replicas) == 3
    assert all(o.placement == "gpu" for o in replicas)
    assert sum(1 for o in out.ops if o.op_id == hv_id) == 1
