"""The example pipelines must run headlessly, end to end, through the
compiled serving path (``compile_flow``) — not a toy interpreted route.
Each example's ``run()`` returns the metrics dict asserted here.
"""
import importlib.util
import pathlib

import pytest

EXAMPLES = pathlib.Path(__file__).resolve().parents[1] / "examples"


def _load(name: str):
    spec = importlib.util.spec_from_file_location(
        f"_smoke_{name}", EXAMPLES / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_video_pipeline_smoke():
    r = _load("video_pipeline").run(frames=2)
    assert r["frames"] == 2
    assert r["labels_per_frame"] > 0
    assert r["controller"] in ("apply", "steady"), r
    assert r["median_ms"] < 60_000


def test_image_cascade_smoke():
    r = _load("image_cascade").run(images=3)
    assert r["images"] == 3
    assert len(r["labels"]) == 3 and all(r["labels"])
    assert 0 <= r["escalated"] <= 3


def test_decode_cascade_smoke():
    r = _load("decode_cascade").run(prompts=2, steps=2)
    assert r["tokens_match"], "fused cascade diverged from model loop"
    assert r["steady_ms"] < 60_000
