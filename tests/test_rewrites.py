from repro.core import operators as ops
from repro.core.dataflow import Dataflow
from repro.core.rewrites import (apply_rewrites, competitive, fuse_chains,
                                 fuse_lookups)
from repro.core.table import Table


def _chain_flow(n: int = 4):
    def inc(x: int) -> int:
        return x + 1
    fl = Dataflow([("x", int)])
    node = fl.source
    for _ in range(n):
        node = node.map(inc, names=["x"])
    fl.output = node
    return fl


def _op_nodes(flow):
    return [n for n in flow.sorted_nodes() if n.op is not None]


def test_fusion_collapses_chain():
    fl = _chain_flow(5)
    fused = fuse_chains(fl)
    nodes = _op_nodes(fused)
    assert len(nodes) == 1
    assert isinstance(nodes[0].op, ops.Fuse)
    assert len(nodes[0].op.ops) == 5


def test_fusion_preserves_semantics():
    fl = _chain_flow(5)
    t = Table([("x", int)], [(0,), (10,)])
    base = fl.execute_local(t)
    fused = fuse_chains(fl)
    out = fused.execute_local(t)
    assert out.to_dicts() == base.to_dicts()


def test_fusion_stops_at_fanout():
    def inc(x: int) -> int:
        return x + 1
    fl = Dataflow([("x", int)])
    a = fl.map(inc, names=["x"])
    b = a.map(inc, names=["x"])
    c = a.map(inc, names=["x"])       # a has two consumers
    fl.output = b.union(c)
    fused = fuse_chains(fl)
    kinds = [type(n.op).__name__ for n in _op_nodes(fused)]
    assert "Union" in kinds
    assert len(_op_nodes(fused)) == 4  # a, b, c, union — nothing collapsed


def test_fusion_respects_resource_class():
    def inc(x: int) -> int:
        return x + 1
    fl = Dataflow([("x", int)])
    a = fl.map(inc, names=["x"])                 # cpu
    b = a.map(inc, names=["x"], gpu=True)        # gpu
    fl.output = b
    fused = fuse_chains(fl)
    assert len(_op_nodes(fused)) == 2


def test_competitive_adds_replicas_and_anyof():
    import time, random
    def model(x: int) -> int:
        return x
    fl = Dataflow([("x", int)])
    fl.output = fl.map(model, names=["x"], competitive_replicas=3)
    rw = competitive(fl)
    nodes = _op_nodes(rw)
    anyofs = [n for n in nodes if isinstance(n.op, ops.AnyOf)]
    maps = [n for n in nodes if isinstance(n.op, ops.Map)]
    assert len(anyofs) == 1 and len(maps) == 3
    assert len(anyofs[0].upstreams) == 3
    out = rw.execute_local(Table([("x", int)], [(7,)]))
    assert out.rows[0].values == (7,)


def test_lookup_fusion():
    def use(key: str, lookup) -> int:
        return int(lookup)
    fl = Dataflow([("key", str)])
    lk = fl.lookup("key", column=True)
    fl.output = lk.map(use, names=["v"])
    rw = fuse_lookups(fl)
    nodes = _op_nodes(rw)
    assert len(nodes) == 1
    assert isinstance(nodes[0].op, ops.Fuse)
    assert isinstance(nodes[0].op.ops[0], ops.Lookup)


def test_apply_rewrites_typechecks():
    fl = _chain_flow(3)
    out = apply_rewrites(fl, fusion=True, competitive_exec=True,
                         locality=True)
    assert len(_op_nodes(out)) == 1
