"""Generate the §Dry-run and §Roofline markdown tables from results/dryrun.

  PYTHONPATH=src python scripts/make_experiments_tables.py > results/tables.md
"""
from __future__ import annotations

import glob
import json
import os
import sys

from repro.configs import ARCH_IDS, SHAPES
from repro.configs.base import human


def fmt_t(x):
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def load(results_dir):
    out = {}
    for f in glob.glob(os.path.join(results_dir, "*.json")):
        j = json.load(open(f))
        out[(j["arch"], j["shape"], j["mesh"])] = j
    return out


def main():
    results = load(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
    shapes = list(SHAPES)

    print("### Dry-run matrix (lower+compile status, peak bytes/chip)\n")
    print("| arch | shape | 16x16 (256 chips) | 2x16x16 (512 chips) |")
    print("|---|---|---|---|")
    for arch in ARCH_IDS:
        for shape in shapes:
            cells = []
            for mesh in ("16x16", "2x16x16"):
                j = results.get((arch, shape, mesh))
                if j is None:
                    cells.append("—")
                elif "skipped" in j:
                    cells.append("skip (full-attn)")
                elif "error" in j:
                    cells.append("FAIL")
                else:
                    peak = j["memory"]["peak_est_bytes"] / 1e9
                    fits = "fits" if peak <= 16 else "OVER"
                    cells.append(f"ok, peak {peak:.1f} GB ({fits})")
            print(f"| {arch} | {shape} | {cells[0]} | {cells[1]} |")

    print("\n### Roofline (single-pod 16x16, per chip; analytic executed-"
          "cost model + HLO-parsed collectives; multi-pod step bound for "
          "comparison)\n")
    print("| arch | shape | t_compute | t_memory | t_coll | bound | "
          "MODEL_FLOPs/exec | step bound | 2-pod bound |")
    print("|---|---|---|---|---|---|---|---|---|")
    for arch in ARCH_IDS:
        for shape in shapes:
            j = results.get((arch, shape, "16x16"))
            if not j or "roofline" not in j:
                status = "skip" if j and "skipped" in j else "—"
                print(f"| {arch} | {shape} | {status} | | | | | | |")
                continue
            r = j["roofline"]
            bound = max(r["t_compute_s"], r["t_memory_s"],
                        r["t_collective_s"])
            j2 = results.get((arch, shape, "2x16x16"))
            if j2 and "roofline" in j2:
                r2 = j2["roofline"]
                b2 = fmt_t(max(r2["t_compute_s"], r2["t_memory_s"],
                               r2["t_collective_s"]))
            else:
                b2 = "—"
            print(f"| {arch} | {shape} | {fmt_t(r['t_compute_s'])} | "
                  f"{fmt_t(r['t_memory_s'])} | {fmt_t(r['t_collective_s'])} "
                  f"| {r['bottleneck']} | {r['useful_ratio']:.2f} | "
                  f"{fmt_t(bound)} | {b2} |")

    print("\n### Collectives (single-pod, per chip per step, trip-count-"
          "corrected)\n")
    print("| arch | shape | all-gather | all-reduce | reduce-scatter | "
          "all-to-all | permute |")
    print("|---|---|---|---|---|---|---|")
    for arch in ARCH_IDS:
        for shape in shapes:
            j = results.get((arch, shape, "16x16"))
            if not j or "collectives" not in j:
                continue
            c = j["collectives"]
            def gb(k):
                v = c.get(k, 0) / 1e9
                return f"{v:.2f}GB" if v >= 0.01 else (
                    f"{c.get(k,0)/1e6:.1f}MB" if c.get(k, 0) else "0")
            print(f"| {arch} | {shape} | {gb('all-gather')} | "
                  f"{gb('all-reduce')} | {gb('reduce-scatter')} | "
                  f"{gb('all-to-all')} | {gb('collective-permute')} |")


if __name__ == "__main__":
    main()
