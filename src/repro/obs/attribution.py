"""SLO-miss attribution: fold kept traces into a per-node breakdown.

A kept trace says where one request's time went; attribution sums that
over many traces into "node X spends its time in component Y", where the
components are:

* ``admission`` — time in the admission decision (request-level; shown
  under the pseudo-node ``(request)``),
* ``queue`` — batcher window wait plus executor queue wait,
* ``service`` — actual user-function execution,
* ``transfer`` — demux/host-copy work splitting batched results,
* ``retry`` — overhead on attempts disturbed by retries/requeues
  (re-execution and backoff gaps),
* ``hedge`` — overhead attributable to hedged duplicates.

``Attribution.dominant()`` names the (node, component) pair that ate the
most time across SLO-missed traces — the controller surfaces it in its
tick detail and ``DeployedFlow.explain()`` prints the table.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

from repro.obs.trace import Trace

REQUEST_NODE = "(request)"
COMPONENTS = ("admission", "queue", "service", "transfer", "retry", "hedge")


@dataclasses.dataclass
class NodeBreakdown:
    """Seconds spent per component at one node, summed over traces."""
    node: str
    admission_s: float = 0.0
    queue_s: float = 0.0
    service_s: float = 0.0
    transfer_s: float = 0.0
    retry_s: float = 0.0
    hedge_s: float = 0.0
    n_spans: int = 0

    @property
    def total_s(self) -> float:
        return (self.admission_s + self.queue_s + self.service_s
                + self.transfer_s + self.retry_s + self.hedge_s)

    def component(self, name: str) -> float:
        return getattr(self, f"{name}_s")

    def add(self, component: str, seconds: float) -> None:
        setattr(self, f"{component}_s",
                getattr(self, f"{component}_s") + max(0.0, seconds))
        self.n_spans += 1

    def to_dict(self) -> Dict[str, float]:
        return {"node": self.node, "n_spans": self.n_spans,
                "total_s": self.total_s,
                **{f"{c}_s": getattr(self, f"{c}_s") for c in COMPONENTS}}


@dataclasses.dataclass
class Attribution:
    """Per-node component breakdown over a set of traces."""
    nodes: Dict[str, NodeBreakdown]
    n_traces: int
    n_miss: int
    n_shed: int
    n_error: int

    def dominant(self) -> Optional[Tuple[str, str, float]]:
        """(node, component, seconds) with the largest total; None when
        nothing was attributed."""
        best: Optional[Tuple[str, str, float]] = None
        for nb in self.nodes.values():
            for c in COMPONENTS:
                v = nb.component(c)
                if v > 0 and (best is None or v > best[2]):
                    best = (nb.node, c, v)
        return best

    def to_dict(self) -> Dict[str, object]:
        dom = self.dominant()
        return {
            "n_traces": self.n_traces, "n_miss": self.n_miss,
            "n_shed": self.n_shed, "n_error": self.n_error,
            "dominant": ({"node": dom[0], "component": dom[1],
                          "seconds": dom[2]} if dom else None),
            "nodes": {k: v.to_dict() for k, v in sorted(self.nodes.items())},
        }

    def table(self) -> str:
        """Fixed-width text table for ``DeployedFlow.explain()``."""
        lines = [f"{'node':<18} " + " ".join(f"{c:>10}" for c in COMPONENTS)
                 + f" {'total':>10}"]
        order = sorted(self.nodes.values(), key=lambda nb: -nb.total_s)
        for nb in order:
            cells = " ".join(f"{nb.component(c) * 1e3:>8.2f}ms"
                             for c in COMPONENTS)
            lines.append(f"{nb.node:<18} {cells} {nb.total_s * 1e3:>8.2f}ms")
        dom = self.dominant()
        if dom:
            lines.append(f"dominant contributor: {dom[1]}@{dom[0]} "
                         f"({dom[2] * 1e3:.2f}ms across {self.n_traces} "
                         f"traces, {self.n_miss} SLO misses)")
        return "\n".join(lines)


def _fold(trace: Trace, nodes: Dict[str, NodeBreakdown]) -> None:
    def nb(node: str) -> NodeBreakdown:
        b = nodes.get(node)
        if b is None:
            b = nodes[node] = NodeBreakdown(node)
        return b

    # which nodes saw retry/requeue vs hedge events on this trace —
    # classifies the unexplained gap inside that node's exec span
    retry_nodes = set()
    hedge_nodes = set()
    for s in trace.spans:
        if s.kind in ("retry", "requeue"):
            retry_nodes.add(s.node or REQUEST_NODE)
        elif s.kind == "hedge_launch":
            hedge_nodes.add(s.node or REQUEST_NODE)

    for s in trace.spans:
        node = s.node or REQUEST_NODE
        kind = s.kind
        if kind == "admission":
            nb(REQUEST_NODE).add("admission", s.duration_s)
        elif kind == "queue":
            nb(node).add("queue", s.duration_s)
        elif kind == "exec":
            qs = float(s.attrs.get("queue_s", 0.0) or 0.0)
            es = s.attrs.get("exec_s")
            es = float(es) if es is not None else s.duration_s
            b = nb(node)
            b.add("queue", qs)
            b.add("service", es)
            # gap not explained by queueing or execution: backoff delays,
            # lost first attempts, hedge duplicates
            gap = s.duration_s - qs - es
            if gap > 1e-9:
                if node in retry_nodes:
                    b.add("retry", gap)
                elif node in hedge_nodes:
                    b.add("hedge", gap)
                else:
                    b.add("queue", gap)
        elif kind == "demux":
            nb(node).add("transfer", s.duration_s)


def attribute(traces: Iterable[Trace],
              slo_only: bool = False) -> Attribution:
    """Fold traces (optionally only SLO-missed ones) into an
    :class:`Attribution`.  Shed traces always count toward admission —
    they never reached a node."""
    nodes: Dict[str, NodeBreakdown] = {}
    n = miss = shed = err = 0
    for t in traces:
        interesting = t.slo_miss or t.shed or t.error is not None
        if slo_only and not interesting:
            continue
        n += 1
        miss += 1 if t.slo_miss else 0
        shed += 1 if t.shed else 0
        err += 1 if t.error is not None else 0
        _fold(t, nodes)
    return Attribution(nodes=nodes, n_traces=n, n_miss=miss,
                       n_shed=shed, n_error=err)
