"""Observability: end-to-end request tracing, histogram metrics, and
SLO-miss attribution.

The serving stack makes many latency-affecting decisions per request
(admission shed/degrade, batch merging and EDF reordering, executor
queueing, retries, hedges, failover requeues, blue/green swaps).  This
package records WHERE each millisecond went so the SLO controller — and
a human — can answer "why did this request miss its deadline?":

* :mod:`repro.obs.trace` — ``Trace``/``Span``/``Tracer``: monotonic-clock
  spans on a per-request trace carried by ``RequestContext``; head
  sampling plus tail-based always-keep for SLO-miss/error/shed/retried
  traces; bounded ring buffer of kept traces.
* :mod:`repro.obs.metrics` — log-bucketed mergeable ``Histogram`` and
  time-``WindowedCounter``, the bounded replacements for unbounded
  per-key value lists.
* :mod:`repro.obs.export` — JSON and Chrome trace-event
  (``chrome://tracing`` / Perfetto) export of kept traces.
* :mod:`repro.obs.attribution` — folds kept traces into a per-node
  queue/service/transfer/retry/hedge breakdown; an SLO miss names its
  dominant contributor.
* :mod:`repro.obs.clock` — THE clock for rate-window timestamps
  (monotonic); every ``*_t`` metric series and every window anchor must
  use it, or rates silently window wall-clock values against monotonic
  anchors.
* :mod:`repro.obs.keys` — the canonical metric-series name registry:
  every recorded key is built by a formatter here, and the static
  verifier's CF401 lint checks recorded keys against it.
"""
from repro.obs import keys
from repro.obs.attribution import Attribution, NodeBreakdown, attribute
from repro.obs.clock import now
from repro.obs.export import (export_chrome, to_chrome_events, to_json,
                              write_chrome)
from repro.obs.metrics import Histogram, HistogramSnapshot, WindowedCounter
from repro.obs.trace import Span, Trace, Tracer

__all__ = [
    "Attribution", "NodeBreakdown", "attribute", "keys", "now",
    "export_chrome", "to_chrome_events", "to_json", "write_chrome",
    "Histogram", "HistogramSnapshot", "WindowedCounter",
    "Span", "Trace", "Tracer",
]
