"""The one clock for rate-window timestamps.

Every ``*_t`` metric series (arrival, shed, fault, retry, ... timestamps)
is windowed by readers against ``now() - window_s``.  That only works if
the WRITER and the READER use the same clock: a series recorded with
wall-clock ``time.time()`` (epoch seconds, steppable by NTP) windowed
against a ``time.monotonic()``/``perf_counter`` anchor is off by ~50
years and reads as permanently empty — rates silently stick at zero.

``now()`` is the process-wide monotonic timestamp every rate-window
writer and reader must use.  It is ``time.perf_counter`` (monotonic,
highest available resolution); the indirection exists so the choice is
made exactly once and the audit is a grep for ``time.time()`` /
``perf_counter()`` in metric paths.
"""
from __future__ import annotations

import time

#: seconds on the process-wide monotonic clock.  NOT epoch time: values
#: are only comparable within one process, which is all a rate window
#: ever compares.
now = time.perf_counter
