"""Low-overhead request tracing: spans on a per-request ``Trace``.

Model
-----
A :class:`Trace` is one request's timeline: a flat list of :class:`Span`
(name, monotonic ``[t0, t1)``, attrs).  Span names carry the node in the
suffix (``queue@stage1``, ``exec@stage1``, ``demux@stage1``); request
boundary spans (``admission``) have no node.  A merged batch emits ONE
batch-level span held by the :class:`Tracer` (not duplicated into every
member trace); member request spans link to it via ``link`` (the batch's
dispatch sequence number), which the Chrome exporter renders as flow
arrows.

Sampling
--------
Recording is cheap (list appends + ``perf_counter`` calls), so every
request gets a live trace while the tracer is enabled; RETENTION is what
is sampled.  At finish a trace is kept when it was **head-sampled**
(deterministic 1-in-N at ``sample_rate``) or when the **tail** says it
is interesting regardless of the coin flip: SLO-missed, errored, shed,
or retried traces are always kept — the traces an operator actually
asks about.  Kept traces live in a bounded ring (old traces fall off),
so steady-state memory is constant.

Thread-safety: spans are appended from executor callback threads and
hedge/retry timers; appends are list-atomic under the GIL and the keep
ring is lock-protected.  All timestamps are ``repro.obs.clock.now``
(monotonic) — never wall clock.
"""
from __future__ import annotations

import itertools
import threading
from collections import deque
from typing import Any, Deque, Dict, List, Optional

from repro.obs.clock import now

_trace_ids = itertools.count(1)

#: event names that flip a trace's tail-keep flags when recorded
_RETRY_EVENTS = frozenset({"retry", "requeue"})
_HEDGE_EVENTS = frozenset({"hedge_launch"})


class Span:
    """One timed region (or instant, when ``t1 == t0``) on a trace."""

    __slots__ = ("name", "t0", "t1", "attrs", "link")

    def __init__(self, name: str, t0: float, t1: float,
                 attrs: Optional[Dict[str, Any]] = None,
                 link: Optional[int] = None):
        self.name = name
        self.t0 = t0
        self.t1 = t1
        self.attrs = attrs or {}
        self.link = link

    @property
    def duration_s(self) -> float:
        return max(0.0, self.t1 - self.t0)

    @property
    def node(self) -> Optional[str]:
        """The node a ``kind@node`` span belongs to (None for request-
        boundary spans like ``admission``)."""
        _, sep, node = self.name.partition("@")
        return node if sep else None

    @property
    def kind(self) -> str:
        return self.name.partition("@")[0]

    def to_dict(self) -> Dict[str, Any]:
        d = {"name": self.name, "t0": self.t0, "t1": self.t1,
             "attrs": dict(self.attrs)}
        if self.link is not None:
            d["link"] = self.link
        return d

    def __repr__(self):
        return (f"Span({self.name}, {self.duration_s * 1e3:.3f}ms"
                f"{', link=' + str(self.link) if self.link else ''})")


class Trace:
    """One request's timeline.  Created by :meth:`Tracer.start`, carried
    on the request's ``RequestContext``, finished exactly once when the
    request resolves."""

    __slots__ = ("trace_id", "dag", "klass", "t0", "t1", "spans",
                 "sampled", "shed", "shed_reason", "error", "slo_miss",
                 "retried", "hedged", "finished", "deadline_s", "_tracer")

    def __init__(self, tracer: "Tracer", dag: str, klass: str,
                 t0: float, sampled: bool):
        self.trace_id = next(_trace_ids)
        self.dag = dag
        self.klass = klass
        self.t0 = t0
        self.t1: Optional[float] = None
        self.spans: List[Span] = []
        self.sampled = sampled
        self.shed = False
        self.shed_reason: Optional[str] = None
        self.error: Optional[str] = None
        self.slo_miss = False
        self.retried = False
        self.hedged = False
        self.finished = False
        self.deadline_s: Optional[float] = None
        self._tracer = tracer

    # -- recording -----------------------------------------------------------
    def span(self, name: str, t0: float, t1: Optional[float] = None,
             link: Optional[int] = None, **attrs) -> Span:
        s = Span(name, t0, t1 if t1 is not None else now(),
                 attrs or None, link)
        self.spans.append(s)
        return s

    def event(self, name: str, **attrs) -> Span:
        """A zero-duration marker (retry fired, hedge launched, requeue).
        Retry-ish events flip the tail-keep flag: a disturbed request's
        trace is always worth keeping."""
        t = now()
        kind = name.partition("@")[0]
        if kind in _RETRY_EVENTS:
            self.retried = True
        if kind in _HEDGE_EVENTS:
            self.hedged = True
        return self.span(name, t, t, **attrs)

    # -- lifecycle -----------------------------------------------------------
    def finish(self, *, error: Optional[BaseException] = None,
               slo_miss: bool = False, shed: bool = False,
               shed_reason: Optional[str] = None) -> bool:
        """Close the trace and apply the keep policy.  Idempotent (first
        close wins); returns whether the trace was kept."""
        if self.finished:
            return False
        self.finished = True
        self.t1 = now()
        if error is not None:
            self.error = f"{type(error).__name__}: {error}"
        self.slo_miss = self.slo_miss or slo_miss
        self.shed = self.shed or shed
        if shed_reason is not None:
            self.shed_reason = shed_reason
        return self._tracer._finish(self)

    @property
    def kept_reason(self) -> Optional[str]:
        if self.slo_miss:
            return "slo_miss"
        if self.error is not None:
            return "error"
        if self.shed:
            return "shed"
        if self.retried:
            return "retried"
        if self.sampled:
            return "sampled"
        return None

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.t1 is None else self.t1 - self.t0

    def to_dict(self) -> Dict[str, Any]:
        return {"trace_id": self.trace_id, "dag": self.dag,
                "klass": self.klass, "t0": self.t0, "t1": self.t1,
                "latency_s": self.latency_s,
                "kept_reason": self.kept_reason,
                "slo_miss": self.slo_miss, "shed": self.shed,
                "shed_reason": self.shed_reason, "error": self.error,
                "retried": self.retried, "hedged": self.hedged,
                "deadline_s": self.deadline_s,
                "spans": [s.to_dict() for s in self.spans]}

    def __repr__(self):
        lat = f"{self.latency_s * 1e3:.2f}ms" if self.t1 else "open"
        return (f"Trace(#{self.trace_id} {self.dag}/{self.klass} {lat}, "
                f"{len(self.spans)} spans, keep={self.kept_reason})")


class Tracer:
    """Owns the sampling policy and the bounded rings of kept traces and
    batch-level spans.

    ``sample_rate`` is HEAD sampling: the fraction of requests whose
    trace is kept even when nothing went wrong (deterministic 1-in-N so
    overhead and retention are load-independent, not coin-flip noisy).
    SLO-miss / error / shed / retried traces are kept regardless — the
    tail-based policy, decided at :meth:`Trace.finish`.

    ``enabled=False`` turns the whole subsystem into ``None`` checks on
    the hot path: ``start`` returns None and every instrumentation site
    is gated on it.
    """

    def __init__(self, *, enabled: bool = True, sample_rate: float = 0.0,
                 capacity: int = 256, batch_capacity: Optional[int] = None):
        self.enabled = enabled
        self.sample_rate = max(0.0, min(1.0, float(sample_rate)))
        self.capacity = int(capacity)
        self._kept: Deque[Trace] = deque(maxlen=self.capacity)
        # batch spans are shared by N member traces; keep enough that a
        # kept trace's linked batch span is still resolvable at export
        self._batches: Deque[Span] = deque(
            maxlen=batch_capacity or 4 * self.capacity)
        # control-plane events (autoscaler replica changes, blue/green
        # swap phases) ride their own bounded ring
        self._control: Deque[Span] = deque(
            maxlen=batch_capacity or 4 * self.capacity)
        self._lock = threading.Lock()
        self._offered = 0
        self.started = 0
        self.finished = 0
        self.kept_count = 0

    # -- lifecycle -----------------------------------------------------------
    def start(self, dag: str, klass: str = "interactive",
              t0: Optional[float] = None) -> Optional[Trace]:
        if not self.enabled:
            return None
        with self._lock:
            self._offered += 1
            self.started += 1
            # deterministic 1-in-N head sampling: request k is sampled
            # when floor(k*rate) > floor((k-1)*rate) — exactly rate*N of
            # any N consecutive requests, no RNG on the hot path
            r = self.sample_rate
            sampled = r >= 1.0 or (
                r > 0.0 and int(self._offered * r) > int(
                    (self._offered - 1) * r))
        return Trace(self, dag, klass, t0 if t0 is not None else now(),
                     sampled)

    def _finish(self, trace: Trace) -> bool:
        keep = bool(trace.sampled or trace.slo_miss or trace.error
                    or trace.shed or trace.retried)
        with self._lock:
            self.finished += 1
            if keep:
                self.kept_count += 1
                self._kept.append(trace)
        return keep

    # -- batch-level spans ---------------------------------------------------
    def record_batch(self, node: str, t0: float, t1: float,
                     link: int, **attrs) -> Span:
        """ONE span for a merged batch dispatch; member request spans
        point at it via the same ``link`` id."""
        s = Span(f"batch@{node}", t0, t1, attrs or None, link)
        with self._lock:
            self._batches.append(s)
        return s

    # -- control-plane events ------------------------------------------------
    def control_event(self, name: str, t0: Optional[float] = None,
                      t1: Optional[float] = None, **attrs) -> Optional[Span]:
        """A control-plane span (``replan@dag`` phases, ``scale@pool``
        replica changes): not tied to any request, kept in its own
        bounded ring and exported on a separate track — so a during-swap
        p99 blip lines up against the swap phase that caused it.  Instant
        when only ``t0`` (or neither) is given."""
        if not self.enabled:
            return None
        t0 = t0 if t0 is not None else now()
        s = Span(name, t0, t1 if t1 is not None else t0, attrs or None)
        with self._lock:
            self._control.append(s)
        return s

    def control_events(self, kind: Optional[str] = None) -> List[Span]:
        with self._lock:
            spans = list(self._control)
        if kind is not None:
            spans = [s for s in spans if s.kind == kind]
        return spans

    # -- reads ---------------------------------------------------------------
    def kept(self, dag: Optional[str] = None) -> List[Trace]:
        with self._lock:
            traces = list(self._kept)
        if dag is not None:
            traces = [t for t in traces if t.dag == dag]
        return traces

    def batch_spans(self, links: Optional[set] = None) -> List[Span]:
        with self._lock:
            spans = list(self._batches)
        if links is not None:
            spans = [s for s in spans if s.link in links]
        return spans

    def clear(self) -> None:
        with self._lock:
            self._kept.clear()
            self._batches.clear()
            self._control.clear()

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"started": self.started, "finished": self.finished,
                    "kept": self.kept_count, "buffered": len(self._kept),
                    "batch_spans": len(self._batches),
                    "control_events": len(self._control)}
