"""Canonical metric-series names (the obs key registry).

Every series the runtime records is built through a formatter here, so
the name grammar lives in ONE place instead of inline f-strings spread
across ``runtime.py``/``admission.py``/``controller.py`` — and the
static verifier's CF401 lint (:class:`repro.analysis.checks.
KeyRegistryCheck`) checks every *recorded* key against
:func:`known_key`, catching the typo'd series that would otherwise just
accumulate unread.

Grammar (``{}`` are caller-supplied path segments; node names may
themselves contain ``/``):

* ``dag/{dag}/{series}`` — per-DAG request stream
  (:data:`DAG_SERIES`)
* ``batch/{dag}/{node}/{series}`` (or ``batch/{node}/...`` for an
  unnamed DAG) — per-node batcher stream (:data:`BATCH_SERIES`)
* ``admission/{dag}/{class}/{series}`` — per-request-class gate
  outcomes (:data:`ADMISSION_SERIES`)
* ``faults/{kind}_t`` — injected-fault events (:data:`FAULT_KINDS`),
  plus :data:`FAULT_REQUEUED`
* ``replan/rollback`` — blue/green swap-backs
  (:data:`REPLAN_ROLLBACK`)

``*_t`` series are event timestamps (windowed counters); the rest are
value histograms (``Runtime.record_metric`` routes on the suffix).
New series: add the pattern here (or :func:`register_series` at
runtime) so the lint recognizes it.
"""
from __future__ import annotations

import re
from typing import List

# -- per-DAG request stream -------------------------------------------------

DAG_SERIES = ("request_t", "latency_s", "shed_t", "expired_t",
              "shed_latency_s", "error_latency_s", "error_t",
              "retry_t", "hedge_t")


def dag(dag_name: str, series: str) -> str:
    if series not in DAG_SERIES:
        raise ValueError(f"unknown dag series {series!r}")
    return f"dag/{dag_name}/{series}"


# -- per-node batcher stream ------------------------------------------------

BATCH_SERIES = ("size", "latency_s", "exec_s", "expired_t")


def batch_prefix(dag_name: str, node: str) -> str:
    """The per-node series prefix; generations of one DAG share it (the
    controller reads one continuous signal across a blue/green swap)."""
    return f"batch/{dag_name}/{node}" if dag_name else f"batch/{node}"


def batch(prefix: str, series: str) -> str:
    """``prefix`` is a :func:`batch_prefix` (node names contain ``/``,
    so the prefix is built once and reused per series)."""
    if series not in BATCH_SERIES:
        raise ValueError(f"unknown batch series {series!r}")
    return f"{prefix}/{series}"


# -- admission gate outcomes ------------------------------------------------

ADMISSION_SERIES = ("shed_t", "degraded_t")


def admission(dag_name: str, klass: str, series: str) -> str:
    if series not in ADMISSION_SERIES:
        raise ValueError(f"unknown admission series {series!r}")
    return f"admission/{dag_name}/{klass}/{series}"


#: the admission controller's internal per-request-class counters
#: (``gate.counters``) — not runtime metric series, but the same
#: single-source-of-truth rule
GATE_EVENTS = ("offered", "shed", "degraded", "admitted",
               "hedge_offered", "hedge_suppressed", "hedge_admitted")


def gate_counter(klass: str, event: str) -> str:
    if event not in GATE_EVENTS:
        raise ValueError(f"unknown gate event {event!r}")
    return f"{klass}/{event}"


# -- fault injection / replanning ------------------------------------------

FAULT_KINDS = ("crash", "wedge")
FAULT_REQUEUED = "faults/requeued_t"
REPLAN_ROLLBACK = "replan/rollback"


def fault(kind: str) -> str:
    if kind not in FAULT_KINDS:
        raise ValueError(f"unknown fault kind {kind!r}")
    return f"faults/{kind}_t"


# -- the registry lint ------------------------------------------------------

_PATTERNS: List[re.Pattern] = [
    re.compile(r"\Adag/.+/(" + "|".join(DAG_SERIES) + r")\Z"),
    re.compile(r"\Abatch/.+/(" + "|".join(BATCH_SERIES) + r")\Z"),
    re.compile(r"\Aadmission/[^/]+/[^/]+/("
               + "|".join(ADMISSION_SERIES) + r")\Z"),
    re.compile(r"\Afaults/(" + "|".join(FAULT_KINDS) + r")_t\Z"),
    re.compile(re.escape(FAULT_REQUEUED) + r"\Z"),
    re.compile(re.escape(REPLAN_ROLLBACK) + r"\Z"),
]


def register_series(pattern: str) -> None:
    """Teach the lint a new series shape (a full-match regex)."""
    _PATTERNS.append(re.compile(pattern))


def known_key(key: str) -> bool:
    """Does ``key`` match any registered series pattern?  The CF401
    lint calls this for every key the runtime actually recorded."""
    return any(p.fullmatch(key) for p in _PATTERNS)
