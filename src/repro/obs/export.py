"""Trace exporters: plain JSON and Chrome trace-event format.

The Chrome trace-event output loads directly in ``chrome://tracing`` or
https://ui.perfetto.dev: one "process" holds the request tracks (one
thread-track per kept trace), a second holds the batcher tracks (one per
node) where the ONE-span-per-merged-batch events live, and flow arrows
connect each request's ``exec@node`` span to the batch span that served
it (the ``link`` id).  Timestamps are microseconds relative to the
earliest exported span, so traces from the process-local monotonic clock
render at t=0.  A third process holds the control-plane track —
autoscaler replica changes and blue/green swap phases — so a during-swap
p99 blip in the request tracks lines up against the control event that
caused it.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional, Sequence

from repro.obs.trace import Span, Trace

_REQ_PID = 1
_BATCH_PID = 2
_CONTROL_PID = 3


def to_json(traces: Iterable[Trace], indent: Optional[int] = None) -> str:
    return json.dumps([t.to_dict() for t in traces], indent=indent)


def _clean(attrs: Dict[str, Any]) -> Dict[str, Any]:
    """JSON-serializable copy of span attrs (tuples of executor ids and
    floats survive; anything exotic is repr'd)."""
    out: Dict[str, Any] = {}
    for k, v in attrs.items():
        if isinstance(v, (str, int, float, bool)) or v is None:
            out[k] = v
        elif isinstance(v, (list, tuple)):
            out[k] = [x if isinstance(x, (str, int, float, bool))
                      or x is None else repr(x) for x in v]
        else:
            out[k] = repr(v)
    return out


def to_chrome_events(traces: Sequence[Trace],
                     batch_spans: Sequence[Span] = (),
                     control_spans: Sequence[Span] = ()) \
        -> List[Dict[str, Any]]:
    """Flatten traces + batch spans + control-plane spans into a
    chrome://tracing event list."""
    events: List[Dict[str, Any]] = []
    all_t0 = [s.t0 for t in traces for s in t.spans] + \
        [t.t0 for t in traces] + [s.t0 for s in batch_spans] + \
        [s.t0 for s in control_spans]
    if not all_t0:
        return events
    base = min(all_t0)

    def us(t: float) -> float:
        return (t - base) * 1e6

    events.append({"ph": "M", "name": "process_name", "pid": _REQ_PID,
                   "args": {"name": "requests"}})
    events.append({"ph": "M", "name": "process_name", "pid": _BATCH_PID,
                   "args": {"name": "batchers"}})
    if control_spans:
        events.append({"ph": "M", "name": "process_name",
                       "pid": _CONTROL_PID,
                       "args": {"name": "control-plane"}})

    node_tids: Dict[str, int] = {}
    for t in traces:
        tid = t.trace_id
        label = f"req#{t.trace_id} {t.dag}/{t.klass}"
        if t.kept_reason:
            label += f" [{t.kept_reason}]"
        events.append({"ph": "M", "name": "thread_name", "pid": _REQ_PID,
                       "tid": tid, "args": {"name": label}})
        # the whole-request envelope
        if t.t1 is not None:
            events.append({
                "ph": "X", "name": f"request:{t.dag}", "cat": "request",
                "pid": _REQ_PID, "tid": tid, "ts": us(t.t0),
                "dur": max(0.0, (t.t1 - t.t0) * 1e6),
                "args": {"klass": t.klass, "slo_miss": t.slo_miss,
                         "shed": t.shed, "error": t.error,
                         "kept": t.kept_reason}})
        for s in t.spans:
            ev = {"ph": "X", "name": s.name, "cat": s.kind,
                  "pid": _REQ_PID, "tid": tid, "ts": us(s.t0),
                  "dur": max(0.0, s.duration_s * 1e6),
                  "args": _clean(s.attrs)}
            events.append(ev)
            if s.link is not None:
                # flow arrow: this request span was served by batch
                # dispatch `link` — the "f" end; the batch span emits "s"
                events.append({"ph": "f", "bp": "e", "cat": "batch-link",
                               "name": "batch", "id": int(s.link),
                               "pid": _REQ_PID, "tid": tid,
                               "ts": us(s.t0) + 1})
    for s in batch_spans:
        node = s.node or "batch"
        tid = node_tids.setdefault(node, 1000 + len(node_tids))
        if tid == 1000 + len(node_tids) - 1:
            events.append({"ph": "M", "name": "thread_name",
                           "pid": _BATCH_PID, "tid": tid,
                           "args": {"name": f"batcher:{node}"}})
        events.append({"ph": "X", "name": s.name, "cat": "batch",
                       "pid": _BATCH_PID, "tid": tid, "ts": us(s.t0),
                       "dur": max(0.0, s.duration_s * 1e6),
                       "args": _clean(s.attrs)})
        if s.link is not None:
            events.append({"ph": "s", "cat": "batch-link", "name": "batch",
                           "id": int(s.link), "pid": _BATCH_PID,
                           "tid": tid, "ts": us(s.t0)})
    control_tids: Dict[str, int] = {}
    for s in control_spans:
        # one thread-track per event kind (replan, scale, ...); a
        # zero-duration span renders as an instant marker
        kind = s.kind
        if kind not in control_tids:
            control_tids[kind] = 2000 + len(control_tids)
            events.append({"ph": "M", "name": "thread_name",
                           "pid": _CONTROL_PID, "tid": control_tids[kind],
                           "args": {"name": f"control:{kind}"}})
        tid = control_tids[kind]
        if s.duration_s > 0.0:
            events.append({"ph": "X", "name": s.name, "cat": "control",
                           "pid": _CONTROL_PID, "tid": tid,
                           "ts": us(s.t0),
                           "dur": max(0.0, s.duration_s * 1e6),
                           "args": _clean(s.attrs)})
        else:
            events.append({"ph": "i", "name": s.name, "cat": "control",
                           "pid": _CONTROL_PID, "tid": tid,
                           "ts": us(s.t0), "s": "g",
                           "args": _clean(s.attrs)})
    return events


def write_chrome(path: str, traces: Sequence[Trace],
                 batch_spans: Sequence[Span] = (),
                 control_spans: Sequence[Span] = ()) -> int:
    """Write a chrome://tracing / Perfetto-loadable JSON file; returns
    the number of events written."""
    events = to_chrome_events(traces, batch_spans, control_spans)
    with open(path, "w") as f:
        json.dump({"traceEvents": events,
                   "displayTimeUnit": "ms"}, f)
    return len(events)


def export_chrome(tracer, path: str, dag: Optional[str] = None) -> int:
    """Export a tracer's kept traces (optionally one DAG's) plus the
    batch spans they link to and every control-plane event."""
    traces = tracer.kept(dag)
    links = {s.link for t in traces for s in t.spans if s.link is not None}
    control = getattr(tracer, "control_events", lambda: [])()
    return write_chrome(path, traces, tracer.batch_spans(links), control)
