"""Bounded metric primitives: log-bucketed histograms and windowed
counters.

The runtime's original metric store appended every observation to a
per-key python list.  Lists answer "give me the raw series" but make
every percentile read copy the whole series under the metrics lock, and
rate reads re-scan thousands of timestamps per controller tick.  The two
shapes of series get the two right structures:

* **latency-valued** series (``*_s``, ``*/size``): a :class:`Histogram`
  — log-spaced buckets, O(1) record, O(buckets) snapshot, and snapshots
  MERGE (sum counts bucket-wise), so per-node histograms roll up to a
  fleet view without raw data.
* **rate-valued** series (``*_t`` timestamp streams): a
  :class:`WindowedCounter` — counts binned into coarse time slots on the
  monotonic clock, so "events in the last W seconds" is a sum over
  ~W/slot integers instead of a scan over every timestamp ever kept.

Both are lock-free at this layer (callers serialize; the runtime records
under its metrics lock) and strictly bounded in memory.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence


class Histogram:
    """Log-bucketed histogram for positive-ish values (latencies, sizes).

    Bucket ``i`` holds values in ``[lo * growth**i, lo * growth**(i+1))``;
    values below ``lo`` land in bucket 0, values above the top in the
    overflow bucket.  With the defaults (1us floor, 100s ceiling, 1.25x
    growth) that is ~83 buckets at <=12.5% relative quantile error —
    plenty for "which stage ate the budget" questions.
    """

    __slots__ = ("lo", "growth", "_log_growth", "counts", "n",
                 "total", "vmin", "vmax")

    N_BUCKETS = 1 + int(math.log(100.0 / 1e-6) / math.log(1.25)) + 1

    def __init__(self, lo: float = 1e-6, growth: float = 1.25):
        self.lo = lo
        self.growth = growth
        self._log_growth = math.log(growth)
        self.counts = [0] * self.N_BUCKETS
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def _bucket(self, v: float) -> int:
        if v <= self.lo:
            return 0
        i = 1 + int(math.log(v / self.lo) / self._log_growth)
        return min(i, self.N_BUCKETS - 1)

    def record(self, v: float) -> None:
        self.counts[self._bucket(v)] += 1
        self.n += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def _bucket_hi(self, i: int) -> float:
        return self.lo * self.growth ** i

    def percentile(self, p: float) -> float:
        """Upper edge of the bucket holding the p-th percentile (<=12.5%
        relative overestimate by construction); exact for min/max ends."""
        if self.n == 0:
            return 0.0
        target = max(1, math.ceil(self.n * p / 100.0))
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= target:
                return min(self._bucket_hi(i), self.vmax)
        return self.vmax

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def snapshot(self) -> "HistogramSnapshot":
        return HistogramSnapshot(lo=self.lo, growth=self.growth,
                                 counts=list(self.counts), n=self.n,
                                 total=self.total,
                                 vmin=self.vmin if self.n else 0.0,
                                 vmax=self.vmax if self.n else 0.0)


@dataclasses.dataclass
class HistogramSnapshot:
    """An immutable, MERGEABLE copy of a histogram's state.  Merging sums
    counts bucket-wise — per-replica or per-node snapshots roll up to an
    aggregate with the same quantile error bound."""
    lo: float
    growth: float
    counts: List[int]
    n: int
    total: float
    vmin: float
    vmax: float

    def merge(self, other: "HistogramSnapshot") -> "HistogramSnapshot":
        if (other.lo, other.growth) != (self.lo, self.growth):
            raise ValueError("cannot merge histograms with different "
                             "bucket layouts")
        return HistogramSnapshot(
            lo=self.lo, growth=self.growth,
            counts=[a + b for a, b in zip(self.counts, other.counts)],
            n=self.n + other.n, total=self.total + other.total,
            vmin=min(self.vmin, other.vmin) if self.n and other.n
            else (self.vmin if self.n else other.vmin),
            vmax=max(self.vmax, other.vmax))

    def percentile(self, p: float) -> float:
        if self.n == 0:
            return 0.0
        target = max(1, math.ceil(self.n * p / 100.0))
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= target:
                return min(self.lo * self.growth ** i, self.vmax)
        return self.vmax

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def summary(self) -> Dict[str, float]:
        return {"n": self.n, "mean": self.mean,
                "p50": self.percentile(50), "p99": self.percentile(99),
                "min": self.vmin, "max": self.vmax}

    @staticmethod
    def merge_all(snaps: Sequence["HistogramSnapshot"]) \
            -> Optional["HistogramSnapshot"]:
        out: Optional[HistogramSnapshot] = None
        for s in snaps:
            out = s if out is None else out.merge(s)
        return out


class WindowedCounter:
    """Event counts binned into fixed-width time slots on the monotonic
    clock — answers "how many events in the last W seconds" in
    O(W / slot) regardless of total event volume.

    ``note(t)`` bins by the EVENT timestamp (callers pass the same
    monotonic stamp they would have appended to a ``*_t`` list), so
    series recorded with synthetic/backdated stamps still window
    correctly.  Slots older than ``horizon_s`` are pruned on write;
    memory is bounded by ``horizon_s / slot_s`` live slots.
    """

    __slots__ = ("slot_s", "horizon_s", "_slots", "total")

    def __init__(self, slot_s: float = 0.25, horizon_s: float = 120.0):
        self.slot_s = float(slot_s)
        self.horizon_s = float(horizon_s)
        self._slots: Dict[int, int] = {}
        self.total = 0

    def note(self, t: float, n: int = 1) -> None:
        slot = int(t / self.slot_s)
        self._slots[slot] = self._slots.get(slot, 0) + n
        self.total += n
        # amortized prune: drop slots past the horizon behind this write
        if len(self._slots) > 2 * int(self.horizon_s / self.slot_s):
            cut = slot - int(self.horizon_s / self.slot_s)
            for s in [s for s in self._slots if s < cut]:
                del self._slots[s]

    def count(self, window_s: float, now: float) -> int:
        """Events with timestamp in ``(now - window_s, now]`` (slot
        granularity: a slot counts when its START lies in the window)."""
        lo = int((now - window_s) / self.slot_s)
        hi = int(now / self.slot_s)
        if hi - lo > len(self._slots):
            return sum(c for s, c in self._slots.items() if lo <= s <= hi)
        return sum(self._slots.get(s, 0) for s in range(lo, hi + 1))

    def rate(self, window_s: float, now: float) -> float:
        return self.count(window_s, now) / max(window_s, 1e-9)
