"""The runtime facade: scheduler + client (Cloudburst analogue).

Scheduling policy (paper §2.3/§4):
* partition executors by resource class; per-function replica assignment
* locality-aware: prefer an executor whose cache holds the request's ref
  (dynamic dispatch: the ref is resolved by the *to-be-continued* half of a
  split DAG and fed back to the scheduler before the continuation is placed)
* wait-for-any: anyof nodes fire on the first completed upstream
* batching: batch-aware functions are fed buckets via a per-function Batcher
"""
from __future__ import annotations

import dataclasses
import itertools
import random
import threading
import time
from concurrent.futures import Future
from typing import Any, Dict, List, Optional

from repro.core.lowering import DEFAULT_BUCKETS, bucket_rows
from repro.core.table import DeviceTable, Table
from repro.runtime.dag import RuntimeDag, RuntimeNode
from repro.runtime.executor import ExecutorPool, WorkItem
from repro.runtime.kvs import KVS
from repro.runtime.netmodel import NetModel
from repro.serving.batcher import Batcher

_req_ids = itertools.count()


class Runtime:
    def __init__(self, *, n_cpu: int = 4, n_gpu: int = 0,
                 net: Optional[NetModel] = None,
                 cache_bytes: int = 2 << 30,
                 max_batch: int = 10, batch_wait_ms: float = 2.0,
                 seed: int = 0):
        self.net = net or NetModel()
        self.kvs = KVS(self.net)
        self.pool = ExecutorPool(self.kvs, self.net, n_cpu=n_cpu, n_gpu=n_gpu,
                                 cache_bytes=cache_bytes)
        self.dags: Dict[str, RuntimeDag] = {}
        self.plans: Dict[str, Any] = {}     # dag name -> PhysicalPlan
        self.max_batch = max_batch
        self.batch_wait_ms = batch_wait_ms
        self._batchers: Dict[str, Batcher] = {}
        self._batchers_lock = threading.Lock()
        self._retired_batchers: List[Batcher] = []
        self._rng = random.Random(seed)
        # metrics are appended from executor callback threads and read by
        # the SLO controller: every access goes through _metrics_lock so
        # snapshots are consistent (do not mutate self.metrics directly —
        # use record_metric / metrics_snapshot)
        self.metrics: Dict[str, List[float]] = {}
        self._metrics_lock = threading.Lock()
        # per-node batching overrides (SLO optimizer PlanConfig): node
        # name -> {"max_batch": int, "batch_wait_ms": float}; consulted at
        # batcher creation and hot-applied to live batchers
        self._node_batch_cfg: Dict[str, Dict[str, float]] = {}

    # -- registration ---------------------------------------------------------
    def register_dag(self, dag: RuntimeDag, plan=None):
        """Register a runtime DAG; ``plan`` (the PhysicalPlan it was lowered
        from) is kept for introspection/debugging.  Re-registering under an
        existing name drops the old deployment's batchers (their closures
        captured the old nodes)."""
        dag.validate()
        old = self.dags.get(dag.name)
        if old is not None:
            # detach the old deployment's batchers: their closures captured
            # the old nodes, but they must still drain in-flight requests
            with self._batchers_lock:
                for node_name in old.nodes:
                    b = self._batchers.pop(node_name, None)
                    if b is not None:
                        self._retired_batchers.append(b)
        # close retired batchers that have drained (bounds thread leakage
        # across repeated re-registrations)
        still_draining = []
        for b in self._retired_batchers:
            if b.q.empty():
                b.close()
            else:
                still_draining.append(b)
        self._retired_batchers = still_draining
        self.dags[dag.name] = dag
        if plan is not None:
            self.plans[dag.name] = plan

    def register_plan(self, plan, name: str) -> RuntimeDag:
        """Lower a ``PhysicalPlan`` and register it in one step."""
        dag = RuntimeDag.from_plan(plan, name)
        self.register_dag(dag, plan=plan)
        return dag

    # -- scheduling -------------------------------------------------------------
    def pick_executor(self, node: RuntimeNode,
                      locality_key: Optional[str] = None):
        cands = self.pool.candidates(node.name, node.resource_class)
        if not cands:
            raise RuntimeError(
                f"no executors for class {node.resource_class!r}")
        if locality_key is not None:
            cached = self.kvs.cached_where(locality_key)
            local = [e for e in cands if e.id in cached]
            if local:
                return min(local, key=lambda e: e.load)
        lo = min(e.load for e in cands)
        best = [e for e in cands if e.load == lo]
        return self._rng.choice(best)

    def dispatch(self, node: RuntimeNode, tables: List[Table],
                 produced_on: List[Optional[str]], callback,
                 locality_key: Optional[str] = None):
        if node.batching:
            self._dispatch_batched(node, tables, produced_on, callback,
                                   locality_key)
            return
        # a device-resident input lives in its producer's accelerator
        # memory: the consumer MUST run there — shipping the batch to
        # another executor would be exactly the host round-trip (or
        # cross-device copy) the residency analysis eliminated, and would
        # invalidate buffer donation
        ex = None
        for t, src in zip(tables, produced_on):
            if isinstance(t, DeviceTable) and src is not None:
                ex = self.pool.by_id(src)
                break
        if ex is None:
            ex = self.pick_executor(node, locality_key)
        ex.submit(WorkItem(fn=node.fn, tables=tables,
                           produced_on=produced_on, callback=callback))

    #: per-series retention: enough history for any rate/percentile window
    #: the controller uses, while keeping snapshot cost and memory constant
    #: under long-running traffic (series are trimmed amortized, at 2x)
    METRIC_SERIES_CAP = 4096

    def record_metric(self, key: str, value: float):
        with self._metrics_lock:
            series = self.metrics.setdefault(key, [])
            series.append(value)
            if len(series) >= 2 * self.METRIC_SERIES_CAP:
                del series[:-self.METRIC_SERIES_CAP]

    def metrics_snapshot(self) -> Dict[str, List[float]]:
        """A consistent copy of every metric series (the controller reads
        this while executor callbacks keep appending)."""
        with self._metrics_lock:
            return {k: list(v) for k, v in self.metrics.items()}

    # -- online reconfiguration (SLO controller hot-apply) --------------------
    def configure_batching(self, node_name: str, *,
                           max_batch: Optional[int] = None,
                           batch_wait_ms: Optional[float] = None) -> bool:
        """Set a node's batching knobs — applied to its LIVE batcher (the
        batch loop reads them per iteration) and remembered for batchers
        created later.  Pure control plane: no re-registration, no
        executable re-trace.  Returns True if anything changed."""
        cfg = self._node_batch_cfg.setdefault(node_name, {})
        changed = False
        if max_batch is not None and cfg.get("max_batch") != int(max_batch):
            cfg["max_batch"] = int(max_batch)
            changed = True
        if batch_wait_ms is not None and \
                cfg.get("batch_wait_ms") != float(batch_wait_ms):
            cfg["batch_wait_ms"] = float(batch_wait_ms)
            changed = True
        with self._batchers_lock:
            b = self._batchers.get(node_name)
        if b is not None and changed:
            b.reconfigure(max_batch=cfg.get("max_batch"),
                          max_wait_ms=cfg.get("batch_wait_ms"))
        return changed

    def set_node_buckets(self, dag_name: str, node_name: str,
                         buckets) -> None:
        """Retune a deployed node's batch padding buckets in place (the
        ChainProfile-driven bucket auto-tuning): updates the runtime
        node's annotation and the lowered op's ``bucket_sizes``.  Already
        compiled bucket shapes keep hitting the executable cache; a new
        bucket compiles lazily on first use."""
        dag = self.dags[dag_name]
        node = dag.nodes[node_name]
        node.batch_buckets = tuple(buckets)
        plan = self.plans.get(dag_name)
        if plan is not None and node.plan_op_id is not None:
            op = plan.op(node.plan_op_id).op
            if hasattr(op, "bucket_sizes"):
                op.bucket_sizes = tuple(buckets)

    def _dispatch_batched(self, node: RuntimeNode, tables, produced_on,
                          callback, locality_key: Optional[str] = None):
        """Queue one request into the node's batcher.  The batch function
        issues ONE executor submission per batch — a single vmapped XLA
        dispatch when the node lowered to a ``BatchedJittedFuse``
        (``node.batched_fn``) — and demultiplexes results back to each
        request's callback from the executor callback (no per-request
        waiter threads)."""
        with self._batchers_lock:
            # creation must be atomic: two concurrent first-dispatches used
            # to each build a Batcher, and the loser's requests ran outside
            # the shared queue (phantom batches, skewed histograms)
            b = self._batchers.get(node.name)
            if b is None:
                cfg = self._node_batch_cfg.get(node.name, {})
                b = Batcher(self._make_batch_fn(node),
                            max_batch=int(cfg.get("max_batch",
                                                  self.max_batch)),
                            max_wait_ms=float(cfg.get("batch_wait_ms",
                                                      self.batch_wait_ms)))
                self._batchers[node.name] = b
        try:
            b.submit((tables, produced_on, callback, locality_key))
        except RuntimeError as e:       # closed under our feet (stop())
            callback(None, e, None)

    def _make_batch_fn(self, node: RuntimeNode):
        def batched(arg_list):
            # merge all request tables into one invocation (paper §4)
            live = []
            for entry in arg_list:
                ts, po, cb, lk = entry
                if not ts:
                    # a request with no input tables can't join the merge;
                    # fail it alone instead of crashing the whole batch
                    cb(None, ValueError(
                        f"{node.name}: batched dispatch needs >=1 table"),
                        None)
                else:
                    live.append(entry)
            if not live:
                return [None] * len(arg_list)
            try:
                # template carries schema/grouping; zero total rows is fine
                # — the fn sees an empty table, returns an empty result
                template = live[0][0][0]
                big = template.with_rows(
                    [r for ts, _, _, _ in live for t in ts for r in t.rows])
                # locality: any request's resolved ref steers the whole
                # batch (members share the node, hence typically the ref)
                lk = next((k for _, _, _, k in live if k is not None), None)
                ex = self.pick_executor(node, lk)
            except BaseException as e:
                # nobody waits on the Batcher items — errors must reach the
                # per-request callbacks, not die in the batch thread
                for _, _, cb, _ in live:
                    try:
                        cb(None, e, None)
                    except BaseException:
                        pass
                return [None] * len(arg_list)
            fn = node.batched_fn or node.fn
            t_submit = time.perf_counter()
            item = WorkItem(fn=fn, tables=[big], produced_on=[None],
                            callback=None)

            def demux(result, error, exec_id):
                lat = time.perf_counter() - t_submit
                self.record_metric(f"batch/{node.name}/size", len(big.rows))
                self.record_metric(f"batch/{node.name}/latency_s", lat)
                if item.exec_s is not None:
                    self.record_metric(f"batch/{node.name}/exec_s",
                                       item.exec_s)
                if error is not None:
                    for _, _, cb, _ in live:
                        cb(None, error, exec_id)
                    return
                if isinstance(result, DeviceTable):
                    # device-resident demux: the batch stays on the
                    # accelerator — each request gets a device-side slice
                    # (row positions are preserved through the vmapped
                    # chain; fused filters only flip mask bits), re-padded
                    # to its bucket so downstream executables keep hitting
                    # cached shapes.  No host copy happens here.
                    buckets = node.batch_buckets or DEFAULT_BUCKETS
                    pos = 0
                    for ts, _, cb, _ in live:
                        k = sum(len(t.rows) for t in ts)
                        span = range(pos, pos + k)
                        pos += k
                        try:
                            if k == 0:
                                part: Any = Table(result.schema,
                                                  grouping=result.grouping)
                            elif len(live) == 1 and k == result.nrows:
                                # single request spanning the whole batch
                                # (the sparse-traffic norm): nothing to
                                # slice — forward the result as-is
                                part = result
                            else:
                                part = result.take(
                                    span, pad_to=bucket_rows(k, buckets))
                            if isinstance(part, DeviceTable):
                                # the part inherits the producer's
                                # consumer-count analysis: with fan-out
                                # downstream, the same part reaches every
                                # consumer — donating it would delete
                                # buffers a sibling still needs
                                part.donatable = result.donatable
                            cb(part, None, exec_id)
                        except BaseException as e:
                            try:
                                cb(None, e, exec_id)
                            except BaseException:
                                pass
                    return
                # demultiplex: positionally when the fn preserved row count
                # (maps/jitted chains — exact even when requests share
                # row_ids), else by row id with multiset semantics (each
                # result row consumed once, so duplicate ids are neither
                # duplicated nor dropped; absent ids = filtered rows)
                positional = len(result.rows) == len(big.rows)
                by_id: Dict[Any, List] = {}
                if not positional:
                    for r in result.rows:
                        by_id.setdefault(r.row_id, []).append(r)
                pos = 0
                for ts, _, cb, _ in live:
                    out_rows = []
                    for t in ts:
                        for r0 in t.rows:
                            if positional:
                                out_rows.append(result.rows[pos])
                                pos += 1
                            else:
                                bucket = by_id.get(r0.row_id)
                                if bucket:
                                    out_rows.append(bucket.pop(0))
                    try:
                        cb(result.with_rows(out_rows), None, exec_id)
                    except BaseException as e:
                        # a broken callback must not starve its siblings
                        try:
                            cb(None, e, exec_id)
                        except BaseException:
                            pass

            item.callback = demux
            ex.submit(item)
            return [None] * len(arg_list)

        return batched

    # -- execution ----------------------------------------------------------------
    def call_dag(self, name: str, table: Table) -> Future:
        dag = self.dags[name]
        fut: Future = Future()
        # arrival + end-to-end latency series: what the SLO controller's
        # rate estimate and the benchmark's measured p99 read back
        t0 = time.perf_counter()
        self.record_metric(f"dag/{name}/request_t", t0)

        def _record(f: Future):
            try:
                if f.exception() is None:
                    self.record_metric(f"dag/{name}/latency_s",
                                       time.perf_counter() - t0)
            except BaseException:
                pass
        fut.add_done_callback(_record)
        _DagExecution(self, dag, table, fut).start()
        return fut

    def stop(self):
        self.pool.stop()
        with self._batchers_lock:
            batchers = list(self._batchers.values()) + self._retired_batchers
        for b in batchers:
            b.close()


class _DagExecution:
    def __init__(self, rt: Runtime, dag: RuntimeDag, table: Table,
                 fut: Future):
        self.rt = rt
        self.dag = dag
        self.input = table
        self.fut = fut
        self.lock = threading.Lock()
        self.results: Dict[str, Table] = {}
        self.produced_on: Dict[str, Optional[str]] = {}
        self.dispatched: set = set()
        self.t0 = time.perf_counter()

    def start(self):
        self._advance()

    def _ready(self, node: RuntimeNode) -> Optional[List[str]]:
        """deps to consume, or None if not ready."""
        if node.wait_any:
            done = [d for d in node.deps if d in self.results]
            return [done[0]] if done else None
        if all(d in self.results for d in node.deps):
            return list(node.deps)
        return None

    def _advance(self):
        with self.lock:
            to_run = []
            for node in self.dag.nodes.values():
                if node.name in self.dispatched or node.name in self.results:
                    continue
                deps = self._ready(node)
                if deps is None:
                    continue
                self.dispatched.add(node.name)
                tables = ([self.input] if not node.deps else
                          [self.results[d] for d in deps])
                srcs = ([None] if not node.deps else
                        [self.produced_on.get(d) for d in deps])
                to_run.append((node, tables, srcs))
        for node, tables, srcs in to_run:
            locality_key = node.locality_const
            if node.locality_ref_column is not None and tables \
                    and isinstance(tables[0], Table):
                # dynamic dispatch: resolved ref from the upstream's output
                # (device-resident upstreams keep values on the accelerator
                # — reading a ref back would defeat the residency, and
                # device chains never carry lookup refs anyway)
                t = tables[0]
                try:
                    idx = t.column_index(node.locality_ref_column)
                    if t.rows:
                        locality_key = t.rows[0].values[idx]
                except KeyError:
                    pass
            self.rt.dispatch(node, tables, srcs,
                             self._make_callback(node), locality_key)

    def _make_callback(self, node: RuntimeNode):
        def cb(result, error, exec_id):
            if error is not None:
                if not self.fut.done():
                    self.fut.set_exception(error)
                return
            finish = False
            with self.lock:
                if node.name in self.results:   # competitive duplicate
                    return
                self.results[node.name] = result
                self.produced_on[node.name] = exec_id
                if node.name == self.dag.output:
                    finish = True
            if finish:
                if not self.fut.done():
                    self.fut.set_result(result)
                return
            self._advance()
        return cb
