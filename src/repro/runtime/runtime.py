"""The runtime facade: scheduler + client (Cloudburst analogue).

Scheduling policy (paper §2.3/§4):
* partition executors by resource class; per-function replica assignment
* locality-aware: prefer an executor whose cache holds the request's ref
  (dynamic dispatch: the ref is resolved by the *to-be-continued* half of a
  split DAG and fed back to the scheduler before the continuation is placed)
* wait-for-any: anyof nodes fire on the first completed upstream
* batching: batch-aware functions are fed buckets via a per-function Batcher
"""
from __future__ import annotations

import dataclasses
import itertools
import random
import threading
import time
from concurrent.futures import Future
from typing import Any, Dict, List, Optional

from repro.core.table import Table
from repro.runtime.dag import RuntimeDag, RuntimeNode
from repro.runtime.executor import ExecutorPool, WorkItem
from repro.runtime.kvs import KVS
from repro.runtime.netmodel import NetModel
from repro.serving.batcher import Batcher

_req_ids = itertools.count()


class Runtime:
    def __init__(self, *, n_cpu: int = 4, n_gpu: int = 0,
                 net: Optional[NetModel] = None,
                 cache_bytes: int = 2 << 30,
                 max_batch: int = 10, batch_wait_ms: float = 2.0,
                 seed: int = 0):
        self.net = net or NetModel()
        self.kvs = KVS(self.net)
        self.pool = ExecutorPool(self.kvs, self.net, n_cpu=n_cpu, n_gpu=n_gpu,
                                 cache_bytes=cache_bytes)
        self.dags: Dict[str, RuntimeDag] = {}
        self.plans: Dict[str, Any] = {}     # dag name -> PhysicalPlan
        self.max_batch = max_batch
        self.batch_wait_ms = batch_wait_ms
        self._batchers: Dict[str, Batcher] = {}
        self._retired_batchers: List[Batcher] = []
        self._rng = random.Random(seed)
        self.metrics: Dict[str, List[float]] = {}

    # -- registration ---------------------------------------------------------
    def register_dag(self, dag: RuntimeDag, plan=None):
        """Register a runtime DAG; ``plan`` (the PhysicalPlan it was lowered
        from) is kept for introspection/debugging.  Re-registering under an
        existing name drops the old deployment's batchers (their closures
        captured the old nodes)."""
        dag.validate()
        old = self.dags.get(dag.name)
        if old is not None:
            # detach the old deployment's batchers: their closures captured
            # the old nodes, but they must still drain in-flight requests
            for node_name in old.nodes:
                b = self._batchers.pop(node_name, None)
                if b is not None:
                    self._retired_batchers.append(b)
        # close retired batchers that have drained (bounds thread leakage
        # across repeated re-registrations)
        still_draining = []
        for b in self._retired_batchers:
            if b.q.empty():
                b.close()
            else:
                still_draining.append(b)
        self._retired_batchers = still_draining
        self.dags[dag.name] = dag
        if plan is not None:
            self.plans[dag.name] = plan

    def register_plan(self, plan, name: str) -> RuntimeDag:
        """Lower a ``PhysicalPlan`` and register it in one step."""
        dag = RuntimeDag.from_plan(plan, name)
        self.register_dag(dag, plan=plan)
        return dag

    # -- scheduling -------------------------------------------------------------
    def pick_executor(self, node: RuntimeNode,
                      locality_key: Optional[str] = None):
        cands = self.pool.candidates(node.name, node.resource_class)
        if not cands:
            raise RuntimeError(
                f"no executors for class {node.resource_class!r}")
        if locality_key is not None:
            cached = self.kvs.cached_where(locality_key)
            local = [e for e in cands if e.id in cached]
            if local:
                return min(local, key=lambda e: e.load)
        lo = min(e.load for e in cands)
        best = [e for e in cands if e.load == lo]
        return self._rng.choice(best)

    def dispatch(self, node: RuntimeNode, tables: List[Table],
                 produced_on: List[Optional[str]], callback,
                 locality_key: Optional[str] = None):
        if node.batching:
            self._dispatch_batched(node, tables, produced_on, callback)
            return
        ex = self.pick_executor(node, locality_key)
        ex.submit(WorkItem(fn=node.fn, tables=tables,
                           produced_on=produced_on, callback=callback))

    def _dispatch_batched(self, node: RuntimeNode, tables, produced_on,
                          callback):
        b = self._batchers.get(node.name)
        if b is None:
            def batched(arg_list):
                # merge all request tables into one invocation (paper §4)
                merged: List[Table] = [t for (ts, _) in arg_list
                                       for t in ts]
                ex = self.pick_executor(node)
                done = threading.Event()
                holder: Dict[str, Any] = {}

                def cb(result, error, exec_id):
                    holder["r"], holder["e"] = result, error
                    done.set()

                big = merged[0].with_rows(
                    [r for t in merged for r in t.rows])
                ex.submit(WorkItem(fn=node.fn, tables=[big],
                                   produced_on=[None], callback=cb))
                done.wait()
                if holder.get("e"):
                    raise holder["e"]
                result: Table = holder["r"]
                # demultiplex by row id
                outs = []
                for ts, _ in arg_list:
                    ids = {r.row_id for t in ts for r in t.rows}
                    outs.append(result.with_rows(
                        [r for r in result.rows if r.row_id in ids]))
                return outs

            b = Batcher(batched, max_batch=self.max_batch,
                        max_wait_ms=self.batch_wait_ms)
            self._batchers[node.name] = b

        def waiter():
            try:
                r = b.call((tables, produced_on))
                callback(r, None, None)
            except BaseException as e:
                callback(None, e, None)

        threading.Thread(target=waiter, daemon=True).start()

    # -- execution ----------------------------------------------------------------
    def call_dag(self, name: str, table: Table) -> Future:
        dag = self.dags[name]
        fut: Future = Future()
        _DagExecution(self, dag, table, fut).start()
        return fut

    def stop(self):
        self.pool.stop()
        for b in list(self._batchers.values()) + self._retired_batchers:
            b.close()


class _DagExecution:
    def __init__(self, rt: Runtime, dag: RuntimeDag, table: Table,
                 fut: Future):
        self.rt = rt
        self.dag = dag
        self.input = table
        self.fut = fut
        self.lock = threading.Lock()
        self.results: Dict[str, Table] = {}
        self.produced_on: Dict[str, Optional[str]] = {}
        self.dispatched: set = set()
        self.t0 = time.perf_counter()

    def start(self):
        self._advance()

    def _ready(self, node: RuntimeNode) -> Optional[List[str]]:
        """deps to consume, or None if not ready."""
        if node.wait_any:
            done = [d for d in node.deps if d in self.results]
            return [done[0]] if done else None
        if all(d in self.results for d in node.deps):
            return list(node.deps)
        return None

    def _advance(self):
        with self.lock:
            to_run = []
            for node in self.dag.nodes.values():
                if node.name in self.dispatched or node.name in self.results:
                    continue
                deps = self._ready(node)
                if deps is None:
                    continue
                self.dispatched.add(node.name)
                tables = ([self.input] if not node.deps else
                          [self.results[d] for d in deps])
                srcs = ([None] if not node.deps else
                        [self.produced_on.get(d) for d in deps])
                to_run.append((node, tables, srcs))
        for node, tables, srcs in to_run:
            locality_key = node.locality_const
            if node.locality_ref_column is not None and tables:
                # dynamic dispatch: resolved ref from the upstream's output
                t = tables[0]
                try:
                    idx = t.column_index(node.locality_ref_column)
                    if t.rows:
                        locality_key = t.rows[0].values[idx]
                except KeyError:
                    pass
            self.rt.dispatch(node, tables, srcs,
                             self._make_callback(node), locality_key)

    def _make_callback(self, node: RuntimeNode):
        def cb(result, error, exec_id):
            if error is not None:
                if not self.fut.done():
                    self.fut.set_exception(error)
                return
            finish = False
            with self.lock:
                if node.name in self.results:   # competitive duplicate
                    return
                self.results[node.name] = result
                self.produced_on[node.name] = exec_id
                if node.name == self.dag.output:
                    finish = True
            if finish:
                if not self.fut.done():
                    self.fut.set_result(result)
                return
            self._advance()
        return cb
