"""The runtime facade: scheduler + client (Cloudburst analogue).

Scheduling policy (paper §2.3/§4):
* partition executors by resource class; per-function replica assignment
* locality-aware: prefer an executor whose cache holds the request's ref
  (dynamic dispatch: the ref is resolved by the *to-be-continued* half of a
  split DAG and fed back to the scheduler before the continuation is placed)
* wait-for-any: anyof nodes fire on the first completed upstream
* batching: batch-aware functions are fed buckets via a per-function Batcher
"""
from __future__ import annotations

import dataclasses
import itertools
import random
import threading
import time
from concurrent.futures import Future
from typing import Any, Dict, List, Optional, Tuple

from repro.core.lowering import DEFAULT_BUCKETS, DegradePolicy, bucket_rows
from repro.core.table import DeviceTable, Table
from repro.obs import keys as okeys
from repro.obs.clock import now as _mono
from repro.obs.metrics import Histogram, HistogramSnapshot, WindowedCounter
from repro.obs.trace import Trace, Tracer
from repro.runtime.dag import RuntimeDag, RuntimeNode
from repro.runtime.executor import ExecutorPool, WorkItem
from repro.runtime.kvs import KVS
from repro.runtime.netmodel import NetModel
from repro.serving.admission import (AdmissionController, DeadlineExceeded,
                                     Overloaded)
from repro.serving.batcher import Batcher
from repro.serving.faults import FaultInjector, FaultPlan
from repro.serving.retry import CompletionToken, ExecutorLost, RetryPolicy

_req_ids = itertools.count()


def _attempt_attrs(log) -> Dict[str, Any]:
    """Summarize a WorkItem's shared attempt log (executor-side start /
    cancelled / requeue / done entries, shared across retry and hedge
    clones) into exec-span attributes."""
    attrs: Dict[str, Any] = {
        "attempts": sum(1 for e in log if e[0] == "start"),
        "cancelled": sum(1 for e in log if e[0] == "cancelled"),
        "requeues": sum(1 for e in log if e[0] == "requeue"),
    }
    return attrs


def _trace_exec_events(tr: Trace, node_name: str, log) -> None:
    """Replay loser/requeue entries from an attempt log onto the trace as
    zero-duration spans at their ORIGINAL timestamps (the callback fires
    once, after the winner — these happened earlier)."""
    for e in log:
        if e[0] == "cancelled":
            tr.span(f"cancelled@{node_name}", e[2], e[2], executor=e[1])
        elif e[0] == "requeue":
            tr.span(f"requeue@{node_name}", e[2], e[2], executor=e[1])
            tr.retried = True


def _exec_span_cb(tr: Trace, node_name: str, item, cb,
                  t_enq: float, link: Optional[int] = None):
    """Wrap a dispatch callback to close an ``exec@node`` span when the
    result (or error) is delivered: covers executor queue wait + service
    time + any retry/hedge overhead, with the measured split in attrs."""
    def wrapped(result, error, exec_id):
        t1 = _mono()
        log = list(item.attempt_log)
        attrs = _attempt_attrs(log)
        attrs["executor"] = exec_id
        done = None
        for e in log:
            if e[0] == "done" and e[1] == exec_id:
                done = e
        if done is not None:
            attrs["queue_s"] = done[3]
            attrs["exec_s"] = done[4]
            if done[5]:
                attrs["copies"] = done[5]
        if error is not None:
            attrs["error"] = type(error).__name__
        _trace_exec_events(tr, node_name, log)
        tr.span(f"exec@{node_name}", t_enq, t1, link=link, **attrs)
        cb(result, error, exec_id)
    return wrapped


@dataclasses.dataclass
class RequestContext:
    """Per-request overload-protection state, carried from ``call_dag``
    through node dispatch, batching, and executor queues."""
    klass: str = "interactive"
    deadline_t: Optional[float] = None    # absolute perf_counter deadline
    deadline_s: Optional[float] = None    # the caller's relative budget
    degrade: Optional[DegradePolicy] = None   # set when admitted degraded
    # idempotence: per-request id, part of every dispatched item's
    # ``dispatch_key`` so at-least-once redispatch can't double-apply
    req_id: Optional[int] = None
    # the request's live trace (None when tracing is disabled or the
    # request is synthetic); instrumentation sites gate on it
    trace: Optional[Trace] = None


class Runtime:
    def __init__(self, *, n_cpu: int = 4, n_gpu: int = 0,
                 net: Optional[NetModel] = None,
                 cache_bytes: int = 2 << 30,
                 max_batch: int = 10, batch_wait_ms: float = 2.0,
                 seed: int = 0,
                 reserved_cpu: int = 0, reserved_gpu: int = 0,
                 fault_plan: Optional[FaultPlan] = None,
                 hang_timeout_s: float = 5.0,
                 detector_interval_s: float = 0.05,
                 auto_replace: bool = True,
                 retry_policies: Optional[Dict[str, RetryPolicy]] = None,
                 tracer: Optional[Tracer] = None):
        self.net = net or NetModel()
        # tracing defaults to tail-keep only (sample_rate=0): nothing is
        # retained unless a request sheds/errors/misses/retries.  Pass
        # Tracer(enabled=False) to strip even the per-request span
        # recording, or a higher sample_rate to also keep healthy traces.
        self.tracer = tracer if tracer is not None else Tracer(
            enabled=True, sample_rate=0.0)
        self.kvs = KVS(self.net)
        injector = FaultInjector(fault_plan) if fault_plan is not None \
            else None
        self.pool = ExecutorPool(self.kvs, self.net, n_cpu=n_cpu, n_gpu=n_gpu,
                                 cache_bytes=cache_bytes,
                                 reserved_cpu=reserved_cpu,
                                 reserved_gpu=reserved_gpu,
                                 fault_injector=injector,
                                 hang_timeout_s=hang_timeout_s,
                                 auto_replace=auto_replace,
                                 on_fault=self._on_fault)
        # heartbeat failure detector: always on — a crashed or wedged
        # executor must never strand in-flight items, fault plan or not
        self.detector_interval_s = detector_interval_s
        self.pool.start_failure_detector(interval_s=detector_interval_s)
        # per-class transient-retry policies ("default" backs all classes
        # without an explicit entry); deadline-budget-aware backoff
        self._retry_policies: Dict[str, RetryPolicy] = \
            dict(retry_policies) if retry_policies else {}
        self._retry_policies.setdefault("default", RetryPolicy())
        self._retry_rng = random.Random(seed ^ 0x5EED)
        # straggler hedging: (dag name, node name) -> hedge delay seconds
        # (profile-derived via serving.faults.install_hedging, or set
        # directly with configure_hedging); absent = hedging off
        self._hedge_delays: Dict[Tuple[str, str], float] = {}
        # per-dag admission gates (set_admission); None = accept everything
        self._admission: Dict[str, AdmissionController] = {}
        self.dags: Dict[str, RuntimeDag] = {}
        self.plans: Dict[str, Any] = {}     # dag name -> PhysicalPlan
        self.max_batch = max_batch
        self.batch_wait_ms = batch_wait_ms
        # deployment state is keyed per GENERATION: two registered DAGs
        # sharing a node name (or the blue and green generation of one
        # DAG mid-swap) must never share a Batcher — its batch fn is a
        # closure over one generation's nodes, so a shared entry would run
        # the other deployment's captured code
        self._batchers: Dict[Tuple[str, int, str], Batcher] = {}
        self._batchers_lock = threading.Lock()
        self._retired_batchers: List[Batcher] = []
        self._rng = random.Random(seed)
        # metrics are appended from executor callback threads and read by
        # the SLO controller: every access goes through _metrics_lock so
        # snapshots are consistent (do not mutate self.metrics directly —
        # use record_metric / metrics_snapshot)
        self.metrics: Dict[str, List[float]] = {}
        self._metrics_lock = threading.Lock()
        # bounded parallel stores fed by record_metric: rate-valued *_t
        # series (values ARE event timestamps) into windowed counters,
        # everything else into log-bucketed mergeable histograms —
        # constant-memory, O(1)-record views the controller can read
        # without copying raw series
        self._hists: Dict[str, Histogram] = {}
        self._counters: Dict[str, WindowedCounter] = {}
        # per-node batching overrides (SLO optimizer PlanConfig), keyed
        # (dag name, node name) — LOGICAL, not per generation: a replanned
        # green generation inherits the hot-applied knobs of matching
        # nodes.  Consulted at batcher creation, hot-applied to the live
        # generation's batchers
        self._node_batch_cfg: Dict[Tuple[str, str], Dict[str, float]] = {}
        # generation lifecycle: in-flight request counts per
        # (dag name, generation); a superseded generation drains — its
        # in-flight executions finish on their own nodes/batchers — and
        # its batchers are retired only once the count hits zero
        self._gen_counter = itertools.count(1)
        self._inflight: Dict[Tuple[str, int], int] = {}
        self._draining: set = set()
        # generations whose batchers were already retired: a straggler
        # execution that creates a fresh batcher under a retired key gets
        # it re-retired on completion.  A PREPARED (never-registered)
        # generation is in neither set — its batchers persist, warm,
        # until the swap makes them the live ones.
        self._retired_gens: set = set()
        self._lifecycle_lock = threading.Lock()

    # -- registration / generation lifecycle ----------------------------------
    def prepare_dag(self, dag: RuntimeDag) -> RuntimeDag:
        """Validate ``dag`` and assign it a deployment generation WITHOUT
        routing any traffic to it.  A prepared dag can be driven directly
        via :meth:`call_dag_object` (warm-up, canary verification) and
        owns generation-keyed runtime state (batchers) from the start —
        the blue/green replanner's pre-swap phase."""
        dag.validate()
        if dag.generation == 0:
            dag.generation = next(self._gen_counter)
        return dag

    def register_dag(self, dag: RuntimeDag, plan=None):
        """Register (or atomically swap in) a runtime DAG; ``plan`` (the
        PhysicalPlan it was lowered from) is kept for introspection and
        bucket retuning.  Re-registering under an existing name is a
        blue/green generation swap: new ``call_dag`` requests route to the
        new generation immediately, in-flight executions finish on the old
        generation's nodes and batchers, and the old generation's batchers
        are retired once its last in-flight request completes — then
        closed when they are quiescent (no queued items, no active
        flush)."""
        self.prepare_dag(dag)
        old = self.dags.get(dag.name)
        with self._lifecycle_lock:
            # re-activating a previously swapped-out generation
            # (swap-back/rollback) must clear BOTH lifecycle marks: left
            # in _retired_gens its fresh batchers would be re-retired
            # after every request; left in _draining, the drain-to-zero
            # of its pre-swap in-flight requests would retire the now
            # LIVE generation's batchers out from under traffic.
            # Cleared BEFORE the registry write — a request completing
            # between publish and clear would re-retire the live
            # generation through the stale marks.
            self._retired_gens.discard((dag.name, dag.generation))
            self._draining.discard((dag.name, dag.generation))
        # the swap: a single dict assignment — call_dag reads the mapping
        # once per request, so every request runs entirely on one
        # generation (the GIL makes the read/replace atomic)
        self.dags[dag.name] = dag
        if plan is not None:
            self.plans[dag.name] = plan
        if old is not None and old is not dag:
            key = (old.name, old.generation)
            with self._lifecycle_lock:
                busy = self._inflight.get(key, 0) > 0
                if busy:
                    self._draining.add(key)
            if not busy:
                self._retire_generation(*key)
        self.sweep_retired()

    def register_plan(self, plan, name: str) -> RuntimeDag:
        """Lower a ``PhysicalPlan`` and register it in one step."""
        dag = RuntimeDag.from_plan(plan, name)
        self.register_dag(dag, plan=plan)
        return dag

    def _retire_generation(self, dag_name: str, generation: int) -> None:
        """Move a superseded generation's batchers out of the live table;
        they drain whatever they still hold and are closed by the sweep."""
        with self._lifecycle_lock:
            self._retired_gens.add((dag_name, generation))
        with self._batchers_lock:
            keys = [k for k in self._batchers
                    if k[0] == dag_name and k[1] == generation]
            for k in keys:
                self._retired_batchers.append(self._batchers.pop(k))

    def discard_dag(self, dag: RuntimeDag) -> None:
        """Discard a PREPARED generation that will never serve (an
        aborted blue/green replan): retire its batchers — created by
        warm-up/canary traffic — so their threads are closed by the sweep
        instead of leaking, and mark the generation retired so any
        straggler execution re-retires what it creates.  A registered
        generation must be superseded via ``register_dag``, not
        discarded."""
        if self.dags.get(dag.name) is dag:
            raise ValueError(f"{dag.name} gen {dag.generation} is live; "
                             "swap it out via register_dag instead")
        self._retire_generation(dag.name, dag.generation)
        self.sweep_retired()

    def sweep_retired(self) -> int:
        """Close retired batchers that have fully drained — queue empty
        AND no flush in progress (``Batcher.quiescent``; ``q.empty()``
        alone races with an active flush whose popped items are still
        live).  Returns how many are still draining.  Bounds thread
        leakage across repeated re-registrations."""
        with self._batchers_lock:
            still, done = [], []
            for b in self._retired_batchers:
                (done if b.quiescent() else still).append(b)
            self._retired_batchers = still
        for b in done:
            b.close()
        return len(still)

    def _track_execution(self, dag: RuntimeDag, fut: Future) -> None:
        """Count an execution against its generation; when a DRAINING (or
        already-superseded) generation's count reaches zero, retire its
        batchers."""
        key = (dag.name, dag.generation)
        with self._lifecycle_lock:
            self._inflight[key] = self._inflight.get(key, 0) + 1

        def _done(_f: Future):
            retire = False
            with self._lifecycle_lock:
                n = self._inflight.get(key, 1) - 1
                if n <= 0:
                    self._inflight.pop(key, None)
                    # superseded generation fully drained — or a batcher
                    # created by a straggler execution AFTER its
                    # generation was retired.  (A PREPARED, never-swapped
                    # generation is in neither set: its warm batchers
                    # survive until the swap makes them live.)
                    if key in self._draining or key in self._retired_gens:
                        self._draining.discard(key)
                        retire = True
                else:
                    self._inflight[key] = n
            if retire:
                self._retire_generation(*key)
                self.sweep_retired()
        fut.add_done_callback(_done)

    # -- scheduling -------------------------------------------------------------
    def pick_executor(self, node: RuntimeNode,
                      locality_key: Optional[str] = None,
                      prefer_reserved: bool = False):
        if prefer_reserved:
            # warm-up/canary work for a not-yet-live generation: the
            # reserved pool (when provisioned) keeps it off the serving
            # workers, so a saturated serving pool can't starve a canary
            rsvd = self.pool.by_class(node.resource_class, reserved=True)
            if rsvd:
                return min(rsvd, key=lambda e: e.load)
        cands = self.pool.candidates(node.name, node.resource_class)
        if not cands:
            raise RuntimeError(
                f"no executors for class {node.resource_class!r}")
        if locality_key is not None:
            cached = self.kvs.cached_where(locality_key)
            local = [e for e in cands if e.id in cached]
            if local:
                return min(local, key=lambda e: e.load)
        lo = min(e.load for e in cands)
        best = [e for e in cands if e.load == lo]
        return self._rng.choice(best)

    def _is_prepared(self, dag: Optional[RuntimeDag]) -> bool:
        """True for a generation that is NOT the live one for its name —
        i.e. warm-up/canary traffic (pre-swap green).  Checked at dispatch
        time, not batcher creation: the same batcher keeps serving after
        the swap makes its generation live."""
        return dag is not None and self.dags.get(dag.name) is not dag

    def dispatch(self, node: RuntimeNode, tables: List[Table],
                 produced_on: List[Optional[str]], callback,
                 locality_key: Optional[str] = None,
                 dag: Optional[RuntimeDag] = None,
                 ctx: Optional[RequestContext] = None):
        if node.batching and (ctx is None or ctx.degrade is None):
            self._dispatch_batched(node, tables, produced_on, callback,
                                   locality_key, dag, ctx)
            return
        # degraded requests bypass the batcher entirely: merging them
        # would degrade their batch-mates, and the per-row executable the
        # DegradePolicy routes to needs no coalescing anyway
        # a device-resident input lives in its producer's accelerator
        # memory: the consumer MUST run there — shipping the batch to
        # another executor would be exactly the host round-trip (or
        # cross-device copy) the residency analysis eliminated, and would
        # invalidate buffer donation
        ex = None
        pinned = False
        for t, src in zip(tables, produced_on):
            if isinstance(t, DeviceTable) and src is not None:
                ex = self.pool.by_id(src)
                pinned = ex is not None
                break
        if ex is None:
            ex = self.pick_executor(node, locality_key,
                                    prefer_reserved=self._is_prepared(dag))
        key = None
        if ctx is not None and ctx.req_id is not None:
            key = (ctx.req_id, node.name)
        item = WorkItem(fn=node.fn, tables=tables,
                        produced_on=produced_on, callback=callback,
                        deadline_t=ctx.deadline_t if ctx else None,
                        degrade=ctx.degrade if ctx else None,
                        dispatch_key=key)
        tr = ctx.trace if ctx is not None else None
        if tr is not None:
            item.callback = _exec_span_cb(tr, node.name, item, callback,
                                          _mono())
        if pinned:
            # pinned to the producer's device: redispatching elsewhere
            # would lose the resident buffers, so no retry/hedge — the
            # failure detector still recovers the item if the pinned
            # worker dies (the requeued run re-materializes on host)
            try:
                ex.submit(item)
            except RuntimeError as e:
                item.deliver(None, ExecutorLost(str(e)), None)
            return
        self._submit_resilient(node, ex, item, ctx,
                               dag_name=dag.name if dag is not None else "")

    #: per-series retention: enough history for any rate/percentile window
    #: the controller uses, while keeping snapshot cost and memory constant
    #: under long-running traffic (series are trimmed amortized, at 2x)
    METRIC_SERIES_CAP = 4096

    def record_metric(self, key: str, value: float):
        with self._metrics_lock:
            series = self.metrics.setdefault(key, [])
            series.append(value)
            if len(series) >= 2 * self.METRIC_SERIES_CAP:
                del series[:-self.METRIC_SERIES_CAP]
            # bounded dual store: *_t series are event-timestamp streams
            # (rate-valued) -> windowed counter binned by the stamp;
            # everything else is value-distributed -> histogram
            if key.endswith("_t"):
                c = self._counters.get(key)
                if c is None:
                    c = self._counters[key] = WindowedCounter()
                c.note(value)
            else:
                h = self._hists.get(key)
                if h is None:
                    h = self._hists[key] = Histogram()
                h.record(value)

    def metrics_snapshot(self, prefix=None) -> Dict[str, List[float]]:
        """A consistent copy of metric series (the controller reads this
        while executor callbacks keep appending).  ``prefix`` — a string
        or tuple of strings — restricts the copy to matching keys, which
        keeps the lock hold (and the stall writers see) proportional to
        what the reader actually consumes instead of every series ever
        recorded."""
        with self._metrics_lock:
            if prefix is None:
                return {k: list(v) for k, v in self.metrics.items()}
            return {k: list(v) for k, v in self.metrics.items()
                    if k.startswith(prefix)}

    def metric_histogram(self, key: str) -> Optional[HistogramSnapshot]:
        """Mergeable snapshot of a value-distributed series' histogram
        (None if the key was never recorded)."""
        with self._metrics_lock:
            h = self._hists.get(key)
            return h.snapshot() if h is not None else None

    def metric_rate(self, key: str, window_s: float,
                    now: Optional[float] = None) -> float:
        """Events/sec for a ``*_t`` series over the trailing window, read
        from the windowed counter (no series scan, no copy)."""
        with self._metrics_lock:
            c = self._counters.get(key)
            if c is None:
                return 0.0
            return c.rate(window_s, now if now is not None else _mono())

    # -- fault tolerance ------------------------------------------------------
    def _on_fault(self, kind: str, executor_id: str, n_requeued: int):
        """Failure-detector hook: surface crash/wedge events and requeue
        volume as metric series (timestamps, like every *_t series) the
        SLO controller folds into ``fault_rate`` — kept SEPARATE from
        ``error_t``: a recovered fault is not a request failure."""
        now = _mono()
        self.record_metric(okeys.fault(kind), now)
        for _ in range(n_requeued):
            self.record_metric(okeys.FAULT_REQUEUED, now)

    def set_fault_plan(self, plan: Optional[FaultPlan]) -> \
            Optional[FaultInjector]:
        """Install (or clear, with None) a fault-injection plan on every
        executor — the chaos benchmark sweeps rates this way.  Returns
        the live injector so callers can read its counts."""
        injector = FaultInjector(plan) if plan is not None else None
        self.pool.set_injector(injector)
        return injector

    def configure_hedging(self, dag_name: str, node_name: str,
                          delay_s: Optional[float]) -> None:
        """Set (or clear, with None) a node's straggler-hedge delay: once
        a dispatch has been out this long with no result, a backup copy
        is raced on another replica, first-result-wins.  Derive delays
        from measured curves with ``serving.faults.install_hedging``."""
        if delay_s is None:
            self._hedge_delays.pop((dag_name, node_name), None)
        else:
            self._hedge_delays[(dag_name, node_name)] = float(delay_s)

    def _submit_resilient(self, node: RuntimeNode, target, item: WorkItem,
                          ctx: Optional[RequestContext],
                          dag_name: str = "",
                          traces: Optional[List[Trace]] = None) -> None:
        """Submit with the fault-tolerance wrapper:

        * **completion token** — every attempt (original, crash requeue,
          hedge, retry) of the logical item delivers at most once;
        * **transient retries** — a typed transient failure redispatches
          to another replica with capped jittered backoff, never past the
          request's deadline budget;
        * **straggler hedging** — if a hedge delay is configured for this
          node (profile-derived p99), a backup dispatch races the primary
          after that delay; the loser is cancelled by the token.  Hedges
          are announced to the admission gate as offered load and are
          suppressed when the gate sees no headroom, so hedging cannot
          amplify an overload.  Nodes in a competitive group are never
          hedged — competitive execution already races replicas.
        """
        klass = ctx.klass if ctx is not None else "interactive"
        deadline_s = ctx.deadline_s if ctx is not None else None
        if traces is None:
            traces = [ctx.trace] if ctx is not None \
                and ctx.trace is not None else []
        policy = self._retry_policies.get(
            klass, self._retry_policies["default"])
        hedge_delay = self._hedge_delays.get((dag_name, node.name))
        if node.competitive_group is not None:
            hedge_delay = None
        final_cb = item.callback

        def attempt_submit(work: WorkItem, ex) -> None:
            timers: List[threading.Timer] = []

            def guard(result, error, exec_id):
                for t in timers:
                    t.cancel()
                if error is not None:
                    delay = policy.next_delay(
                        work.attempt, error, _mono(),
                        deadline_t=work.deadline_t, rng=self._retry_rng)
                    if delay is not None:
                        if dag_name:
                            self.record_metric(
                                okeys.dag(dag_name, "retry_t"), _mono())
                        for tr in traces:
                            tr.event(f"retry@{node.name}",
                                     attempt=work.attempt + 1,
                                     delay_s=delay,
                                     cause=type(error).__name__)
                        nxt = work.clone()
                        nxt.token = CompletionToken()
                        nxt.attempt = work.attempt + 1

                        def fire_retry():
                            try:
                                t2 = self.pick_executor(node)
                                attempt_submit(nxt, t2)
                            except BaseException as e:
                                if nxt.token.claim(None):
                                    final_cb(None, e, None)
                        rt_t = threading.Timer(delay, fire_retry)
                        rt_t.daemon = True
                        rt_t.start()
                        return
                final_cb(result, error, exec_id)

            work.callback = guard
            if hedge_delay is not None:
                def fire_hedge():
                    if work.token.claimed:
                        return
                    adm = self._admission.get(dag_name)
                    if adm is not None and not adm.note_hedge(
                            klass, deadline_s=deadline_s):
                        # no headroom: a hedge now would amplify the
                        # overload the gate is defusing
                        return
                    others = [e for e in self.pool.candidates(
                                  node.name, node.resource_class)
                              if e.id != ex.id]
                    if not others:
                        return
                    if dag_name:
                        self.record_metric(
                            okeys.dag(dag_name, "hedge_t"), _mono())
                    for tr in traces:
                        tr.event(f"hedge_launch@{node.name}",
                                 delay_s=hedge_delay)
                    try:
                        # shared token: first result wins, loser cancelled
                        min(others, key=lambda e: e.load).submit(
                            work.clone())
                    except RuntimeError:
                        pass
                hg_t = threading.Timer(hedge_delay, fire_hedge)
                hg_t.daemon = True
                timers.append(hg_t)
                hg_t.start()
            try:
                ex.submit(work)
            except RuntimeError as e:
                # stopped between pick and submit: count it as a
                # transient executor loss so the retry path re-picks
                work.deliver(None, ExecutorLost(str(e)), None)

        attempt_submit(item, target)

    # -- online reconfiguration (SLO controller hot-apply) --------------------
    def batcher_for(self, dag_name: str, node_name: str,
                    generation: Optional[int] = None) -> Optional[Batcher]:
        """The live Batcher serving ``(dag, node)`` — by default the
        currently registered generation's."""
        if generation is None:
            dag = self.dags.get(dag_name)
            if dag is None:
                return None
            generation = dag.generation
        with self._batchers_lock:
            return self._batchers.get((dag_name, generation, node_name))

    def configure_batching(self, dag_name: str, node_name: str, *,
                           max_batch: Optional[int] = None,
                           batch_wait_ms: Optional[float] = None) -> bool:
        """Set a node's batching knobs — applied to its LIVE batcher (the
        batch loop reads them per iteration) and remembered for batchers
        created later.  The config is keyed logically (dag, node), so a
        replanned green generation inherits it where node names match.
        Pure control plane: no re-registration, no executable re-trace.
        Returns True if anything changed."""
        cfg = self._node_batch_cfg.setdefault((dag_name, node_name), {})
        changed = False
        if max_batch is not None and cfg.get("max_batch") != int(max_batch):
            cfg["max_batch"] = int(max_batch)
            changed = True
        if batch_wait_ms is not None and \
                cfg.get("batch_wait_ms") != float(batch_wait_ms):
            cfg["batch_wait_ms"] = float(batch_wait_ms)
            changed = True
        b = self.batcher_for(dag_name, node_name)
        if b is not None and changed:
            b.reconfigure(max_batch=cfg.get("max_batch"),
                          max_wait_ms=cfg.get("batch_wait_ms"))
        return changed

    def set_node_buckets(self, dag_name: str, node_name: str,
                         buckets) -> None:
        """Retune a deployed node's batch padding buckets in place (the
        ChainProfile-driven bucket auto-tuning): updates the runtime
        node's annotation and the lowered op's ``bucket_sizes``.  Already
        compiled bucket shapes keep hitting the executable cache; a new
        bucket compiles lazily on first use."""
        dag = self.dags[dag_name]
        node = dag.nodes[node_name]
        node.batch_buckets = tuple(buckets)
        plan = self.plans.get(dag_name)
        if plan is not None and node.plan_op_id is not None:
            op = plan.op(node.plan_op_id).op
            if hasattr(op, "bucket_sizes"):
                op.bucket_sizes = tuple(buckets)

    def _dispatch_batched(self, node: RuntimeNode, tables, produced_on,
                          callback, locality_key: Optional[str] = None,
                          dag: Optional[RuntimeDag] = None,
                          ctx: Optional[RequestContext] = None):
        """Queue one request into the node's batcher.  The batch function
        issues ONE executor submission per batch — a single vmapped XLA
        dispatch when the node lowered to a ``BatchedJittedFuse``
        (``node.batched_fn``) — and demultiplexes results back to each
        request's callback from the executor callback (no per-request
        waiter threads).  Batchers are keyed ``(dag, generation, node)``:
        two DAGs sharing a node name — or two generations of one DAG mid
        blue/green swap — never share a batcher, whose batch fn captured
        exactly one generation's node closure."""
        dag_name = dag.name if dag is not None else ""
        generation = dag.generation if dag is not None else 0
        key = (dag_name, generation, node.name)
        with self._batchers_lock:
            # creation must be atomic: two concurrent first-dispatches used
            # to each build a Batcher, and the loser's requests ran outside
            # the shared queue (phantom batches, skewed histograms)
            b = self._batchers.get(key)
            if b is None:
                cfg = self._node_batch_cfg.get((dag_name, node.name), {})
                mkey = okeys.batch_prefix(dag_name, node.name)

                def _drop(args, err, _mkey=mkey, _node=node.name):
                    # a submit can slip in between the sweep's quiescence
                    # check and close() — the drained item's request
                    # callback must still fire, or its future would hang
                    # forever (nobody waits on Batcher item events here).
                    # Deadline expiries land here too; count them.
                    if isinstance(err, DeadlineExceeded):
                        self.record_metric(okeys.batch(_mkey, "expired_t"),
                                           _mono())
                    d_ctx = args[4]
                    if d_ctx is not None and d_ctx.trace is not None:
                        # the request died waiting in the batcher: close
                        # the queue span so attribution sees the wait
                        d_ctx.trace.span(f"queue@{_node}", args[5],
                                         dropped=type(err).__name__)
                    args[2](None, err, None)

                b = Batcher(self._make_batch_fn(node, dag_name, dag),
                            max_batch=int(cfg.get("max_batch",
                                                  self.max_batch)),
                            max_wait_ms=float(cfg.get("batch_wait_ms",
                                                      self.batch_wait_ms)),
                            on_drop=_drop)
                self._batchers[key] = b
        try:
            b.submit((tables, produced_on, callback, locality_key, ctx,
                      _mono()),
                     deadline_t=ctx.deadline_t if ctx else None)
        except RuntimeError as e:       # closed under our feet (stop())
            callback(None, e, None)

    def _make_batch_fn(self, node: RuntimeNode, dag_name: str = "",
                       dag: Optional[RuntimeDag] = None):
        def batched(arg_list):
            # merge all request tables into one invocation (paper §4)
            live = []
            for entry in arg_list:
                ts, po, cb, lk, _ctx, _tq = entry
                if not ts:
                    # a request with no input tables can't join the merge;
                    # fail it alone instead of crashing the whole batch
                    cb(None, ValueError(
                        f"{node.name}: batched dispatch needs >=1 table"),
                        None)
                else:
                    live.append(entry)
            if not live:
                return [None] * len(arg_list)
            try:
                # template carries schema/grouping; zero total rows is fine
                # — the fn sees an empty table, returns an empty result
                template = live[0][0][0]
                big = template.with_rows(
                    [r for ts, _, _, _, _, _ in live for t in ts
                     for r in t.rows])
                # locality: any request's resolved ref steers the whole
                # batch (members share the node, hence typically the ref)
                lk = next((k for _, _, _, k, _, _ in live
                           if k is not None), None)
                ex = self.pick_executor(
                    node, lk, prefer_reserved=self._is_prepared(dag))
            except BaseException as e:
                # nobody waits on the Batcher items — errors must reach the
                # per-request callbacks, not die in the batch thread
                for _, _, cb, _, _, _ in live:
                    try:
                        cb(None, e, None)
                    except BaseException:
                        pass
                return [None] * len(arg_list)
            fn = node.batched_fn or node.fn
            t_submit = _mono()
            # one id names the merged dispatch everywhere: the dispatch
            # key, the batch-level span, and the link on every member's
            # exec span
            bid = next(_req_ids)
            # batch formation closes each traced member's batcher-wait
            # queue span; EDF reordering of THIS batch is read off the
            # live batcher (the batch fn runs on its flush thread)
            batcher = self.batcher_for(
                dag_name, node.name,
                generation=dag.generation if dag is not None else 0)
            reordered = bool(batcher is not None
                             and batcher.last_reordered)
            traced = [c.trace for _, _, _, _, c, _ in live
                      if c is not None and c.trace is not None]
            for _, _, _, _, c, tq in live:
                if c is not None and c.trace is not None:
                    c.trace.span(f"queue@{node.name}", tq, t_submit,
                                 batch_size=len(big.rows),
                                 reordered=reordered)
            # the merged batch inherits the LOOSEST member deadline: a
            # batch is only pointless once every member's deadline passed
            # (per-member expiry already happened in the Batcher)
            deadlines = [c.deadline_t if c is not None else None
                         for _, _, _, _, c, _ in live]
            batch_deadline = (max(deadlines)
                              if deadlines and None not in deadlines
                              else None)
            # the merged batch is one logical item: its dispatch_key makes
            # KVS writes idempotent and its token makes demux exactly-once
            # across crash requeues / hedges of the whole batch
            item = WorkItem(fn=fn, tables=[big], produced_on=[None],
                            callback=None, deadline_t=batch_deadline,
                            dispatch_key=(dag_name, node.name, bid))

            # metric series are keyed by (dag, node) so two DAGs sharing a
            # node name don't interleave their histograms (generations of
            # one DAG intentionally share a series — the controller reads
            # one continuous signal across a blue/green swap)
            mkey = okeys.batch_prefix(dag_name, node.name)

            def demux(result, error, exec_id):
                t_done = _mono()
                lat = t_done - t_submit
                self.record_metric(okeys.batch(mkey, "size"), len(big.rows))
                self.record_metric(okeys.batch(mkey, "latency_s"), lat)
                if item.exec_s is not None:
                    self.record_metric(okeys.batch(mkey, "exec_s"),
                                       item.exec_s)
                if traced:
                    # ONE batch-level span held by the tracer; every
                    # member's exec span links to it via `bid`
                    log = list(item.attempt_log)
                    base = _attempt_attrs(log)
                    done_e = None
                    for e in log:
                        if e[0] == "done" and e[1] == exec_id:
                            done_e = e
                    if done_e is not None:
                        base["queue_s"] = done_e[3]
                        base["exec_s"] = done_e[4]
                        if done_e[5]:
                            base["copies"] = done_e[5]
                    if error is not None:
                        base["error"] = type(error).__name__
                    for trc in traced:
                        _trace_exec_events(trc, node.name, log)
                        trc.span(f"exec@{node.name}", t_submit, t_done,
                                 link=bid, executor=exec_id,
                                 batch=len(big.rows), **base)
                    buckets = node.batch_buckets or DEFAULT_BUCKETS
                    self.tracer.record_batch(
                        node.name, t_submit, t_done, bid,
                        dag=dag_name, size=len(big.rows),
                        n_requests=len(live),
                        bucket=bucket_rows(len(big.rows), buckets),
                        reordered=reordered, executor=exec_id)
                if error is not None:
                    for _, _, cb, _, _, _ in live:
                        cb(None, error, exec_id)
                    return
                if isinstance(result, DeviceTable):
                    # device-resident demux: the batch stays on the
                    # accelerator — each request gets a device-side slice
                    # (row positions are preserved through the vmapped
                    # chain; fused filters only flip mask bits), re-padded
                    # to its bucket so downstream executables keep hitting
                    # cached shapes.  No host copy happens here.
                    buckets = node.batch_buckets or DEFAULT_BUCKETS
                    pos = 0
                    for ts, _, cb, _, c, _ in live:
                        k = sum(len(t.rows) for t in ts)
                        span = range(pos, pos + k)
                        pos += k
                        t_d0 = _mono()
                        try:
                            if k == 0:
                                part: Any = Table(result.schema,
                                                  grouping=result.grouping)
                            elif len(live) == 1 and k == result.nrows:
                                # single request spanning the whole batch
                                # (the sparse-traffic norm): nothing to
                                # slice — forward the result as-is
                                part = result
                            else:
                                part = result.take(
                                    span, pad_to=bucket_rows(k, buckets))
                            if isinstance(part, DeviceTable):
                                # the part inherits the producer's
                                # consumer-count analysis: with fan-out
                                # downstream, the same part reaches every
                                # consumer — donating it would delete
                                # buffers a sibling still needs
                                part.donatable = result.donatable
                            if c is not None and c.trace is not None:
                                c.trace.span(f"demux@{node.name}", t_d0,
                                             _mono(), rows=k, device=True)
                            cb(part, None, exec_id)
                        except BaseException as e:
                            try:
                                cb(None, e, exec_id)
                            except BaseException:
                                pass
                    return
                # demultiplex: positionally when the fn preserved row count
                # (maps/jitted chains — exact even when requests share
                # row_ids), else by row id with multiset semantics (each
                # result row consumed once, so duplicate ids are neither
                # duplicated nor dropped; absent ids = filtered rows)
                positional = len(result.rows) == len(big.rows)
                by_id: Dict[Any, List] = {}
                if not positional:
                    for r in result.rows:
                        by_id.setdefault(r.row_id, []).append(r)
                pos = 0
                for ts, _, cb, _, c, _ in live:
                    t_d0 = _mono()
                    out_rows = []
                    for t in ts:
                        for r0 in t.rows:
                            if positional:
                                out_rows.append(result.rows[pos])
                                pos += 1
                            else:
                                bucket = by_id.get(r0.row_id)
                                if bucket:
                                    out_rows.append(bucket.pop(0))
                    if c is not None and c.trace is not None:
                        c.trace.span(f"demux@{node.name}", t_d0, _mono(),
                                     rows=len(out_rows),
                                     positional=positional)
                    try:
                        cb(result.with_rows(out_rows), None, exec_id)
                    except BaseException as e:
                        # a broken callback must not starve its siblings
                        try:
                            cb(None, e, exec_id)
                        except BaseException:
                            pass

            item.callback = demux
            # retry/hedge budget from any member context (members of a
            # merged batch share the node's class and similar deadlines)
            ctx0 = next((c for _, _, _, _, c, _ in live if c is not None),
                        None)
            self._submit_resilient(node, ex, item, ctx0,
                                   dag_name=dag_name, traces=traced)
            return [None] * len(arg_list)

        return batched

    # -- admission control ----------------------------------------------------
    def set_admission(self, dag_name: str,
                      admission: Optional[AdmissionController]) -> None:
        """Install (or clear, with None) the overload-protection gate for
        a DAG's front door.  Without a gate, ``call_dag`` still honors
        explicit ``deadline_s`` (expiry in batcher/executor queues) but
        never sheds."""
        if admission is None:
            self._admission.pop(dag_name, None)
        else:
            if admission.queue_depth_fn is None:
                # leading overload indicator: executor backlog moves ahead
                # of the arrival-rate estimate during a burst or after a
                # replica failure shrinks effective capacity
                admission.queue_depth_fn = \
                    lambda: self.pool.total_depth()
            self._admission[dag_name] = admission

    def admission_for(self, dag_name: str) -> Optional[AdmissionController]:
        return self._admission.get(dag_name)

    # -- execution ----------------------------------------------------------------
    def call_dag(self, name: str, table: Table, *,
                 deadline_s: Optional[float] = None,
                 klass: Optional[str] = None) -> Future:
        # ONE registry read per request: the whole execution runs on the
        # generation that was live at arrival, even if a blue/green swap
        # lands mid-flight
        dag = self.dags[name]
        t0 = _mono()
        # the trace exists BEFORE the admission decision so a shed
        # request still has a (kept) trace saying why it never ran
        tr = self.tracer.start(name, klass or "interactive", t0)
        ctx: Optional[RequestContext] = None
        adm = self._admission.get(name)
        if adm is not None:
            d = adm.admit(klass, deadline_s)
            kname = d.klass
            if tr is not None:
                tr.klass = kname
                tr.span("admission", t0, _mono(), action=d.action,
                        reason=d.reason, klass=kname,
                        estimate_s=d.estimate_s)
            if deadline_s is None:
                deadline_s = d.deadline_s
            if not d.admitted:
                # typed fast-fail: the caller learns in microseconds —
                # not after a blown deadline — that the deployment is
                # protecting itself.  Sheds get their OWN series (NOT
                # error_t): the controller must distinguish "overloaded
                # and shedding by design" from "failing".
                now = _mono()
                self.record_metric(okeys.dag(name, "shed_t"), now)
                self.record_metric(okeys.admission(name, kname, "shed_t"),
                                   now)
                if tr is not None:
                    tr.finish(shed=True, shed_reason=d.reason)
                fut = Future()
                fut.set_exception(Overloaded(
                    f"{name}: {kname} request shed ({d.reason})",
                    klass=kname, reason=d.reason,
                    estimate_s=d.estimate_s, deadline_s=deadline_s))
                return fut
            if d.action == "degrade":
                self.record_metric(
                    okeys.admission(name, kname, "degraded_t"), _mono())
            ctx = RequestContext(klass=kname, degrade=d.degrade)
        elif tr is not None:
            # no gate installed: a zero-cost marker so every exported
            # trace starts with its admission decision
            tr.span("admission", t0, t0, action="admit", reason="no_gate")
        if ctx is None and (deadline_s is not None or klass is not None
                            or tr is not None):
            ctx = RequestContext(klass=klass or "interactive")
        if ctx is not None and deadline_s is not None:
            ctx.deadline_s = deadline_s
            ctx.deadline_t = t0 + deadline_s
        if ctx is not None and tr is not None:
            ctx.trace = tr
            tr.deadline_s = deadline_s
        return self.call_dag_object(dag, table, record=True, ctx=ctx)

    def call_dag_object(self, dag: RuntimeDag, table: Table, *,
                        record: bool = False,
                        ctx: Optional[RequestContext] = None) -> Future:
        """Execute a DAG *object* directly, registered or not — the
        blue/green replanner drives warm-up and canary requests through a
        prepared (not yet traffic-visible) green generation this way.
        ``record=False`` keeps synthetic requests out of the
        ``dag/<name>/…`` series the SLO controller measures."""
        fut: Future = Future()
        t0 = _mono()
        # every request gets a context with a unique id: (req_id, node)
        # is the dispatch key that makes redispatched KVS writes
        # idempotent and completions exactly-once
        if ctx is None:
            ctx = RequestContext()
        ctx.req_id = next(_req_ids)
        tr = ctx.trace
        if record:
            name = dag.name
            # arrival + end-to-end latency series: what the SLO
            # controller's rate estimate and the benchmark's measured p99
            # read back
            self.record_metric(okeys.dag(name, "request_t"), t0)

            def _record(f: Future):
                lat = _mono() - t0
                try:
                    exc = f.exception()
                except BaseException as e:
                    exc = e
                if exc is None:
                    self.record_metric(okeys.dag(name, "latency_s"), lat)
                elif isinstance(exc, DeadlineExceeded):
                    # admitted but its deadline passed in a queue: an
                    # EXPIRY, not an error — the request failed fast by
                    # design, in a fraction of its budget
                    self.record_metric(okeys.dag(name, "expired_t"),
                                       _mono())
                    self.record_metric(okeys.dag(name, "shed_latency_s"),
                                       lat)
                elif isinstance(exc, Overloaded):
                    self.record_metric(okeys.dag(name, "shed_t"), _mono())
                    self.record_metric(okeys.dag(name, "shed_latency_s"),
                                       lat)
                else:
                    # error-path latency goes to its OWN series plus an
                    # error counter whose values are completion
                    # timestamps (len = count, values = the window the
                    # controller rates errors over).  Folding failures
                    # into latency_s — or dropping them, as we used to —
                    # makes the measured p99 improve exactly when the
                    # system degrades.
                    self.record_metric(okeys.dag(name, "error_latency_s"),
                                       lat)
                    self.record_metric(okeys.dag(name, "error_t"), _mono())
                if tr is not None:
                    # tail-based keep decision happens here, with the
                    # request's true outcome in hand
                    if exc is None:
                        miss = (tr.deadline_s is not None
                                and lat > tr.deadline_s)
                        tr.finish(slo_miss=miss)
                    elif isinstance(exc, DeadlineExceeded):
                        tr.finish(slo_miss=True, shed=True,
                                  shed_reason="expired")
                    elif isinstance(exc, Overloaded):
                        tr.finish(shed=True,
                                  shed_reason=getattr(exc, "reason", None))
                    else:
                        tr.finish(error=exc)
            fut.add_done_callback(_record)
        self._track_execution(dag, fut)
        _DagExecution(self, dag, table, fut, ctx).start()
        return fut

    def stop(self):
        self.pool.stop()
        with self._batchers_lock:
            batchers = list(self._batchers.values()) + self._retired_batchers
        for b in batchers:
            b.close()


class _DagExecution:
    def __init__(self, rt: Runtime, dag: RuntimeDag, table: Table,
                 fut: Future, ctx: Optional[RequestContext] = None):
        self.rt = rt
        self.dag = dag
        self.input = table
        self.fut = fut
        self.ctx = ctx
        self.lock = threading.Lock()
        self.results: Dict[str, Table] = {}
        self.produced_on: Dict[str, Optional[str]] = {}
        self.dispatched: set = set()
        # competitive groups already dispatched for a degraded request
        # (one replica each instead of racing all of them)
        self._groups_fired: set = set()
        self.t0 = _mono()

    def start(self):
        self._advance()

    def _expired(self) -> bool:
        """Fail the whole execution fast once the request's deadline has
        passed — downstream nodes are never dispatched, so an expired
        request stops consuming capacity at the next DAG edge."""
        ctx = self.ctx
        if ctx is None or ctx.deadline_t is None:
            return False
        if ctx.deadline_t > _mono():
            return False
        if not self.fut.done():
            self.fut.set_exception(DeadlineExceeded(
                f"{self.dag.name}: deadline passed mid-execution",
                klass=ctx.klass, deadline_s=ctx.deadline_s))
        return True

    def _ready(self, node: RuntimeNode) -> Optional[List[str]]:
        """deps to consume, or None if not ready."""
        if node.wait_any:
            done = [d for d in node.deps if d in self.results]
            return [done[0]] if done else None
        if all(d in self.results for d in node.deps):
            return list(node.deps)
        return None

    def _advance(self):
        if self._expired():
            return
        degraded_serial = (self.ctx is not None
                           and self.ctx.degrade is not None
                           and not self.ctx.degrade.competitive)
        with self.lock:
            to_run = []
            for node in self.dag.nodes.values():
                if node.name in self.dispatched or node.name in self.results:
                    continue
                deps = self._ready(node)
                if deps is None:
                    continue
                if degraded_serial and node.competitive_group is not None:
                    # degraded request: dispatch ONE replica per
                    # competitive group — racing k copies for tail
                    # suppression is capacity a best-effort request does
                    # not get under overload (wait-any fires on the one)
                    if node.competitive_group in self._groups_fired:
                        self.dispatched.add(node.name)
                        continue
                    self._groups_fired.add(node.competitive_group)
                self.dispatched.add(node.name)
                tables = ([self.input] if not node.deps else
                          [self.results[d] for d in deps])
                srcs = ([None] if not node.deps else
                        [self.produced_on.get(d) for d in deps])
                to_run.append((node, tables, srcs))
        for node, tables, srcs in to_run:
            locality_key = node.locality_const
            if node.locality_ref_column is not None and tables \
                    and isinstance(tables[0], Table):
                # dynamic dispatch: resolved ref from the upstream's output
                # (device-resident upstreams keep values on the accelerator
                # — reading a ref back would defeat the residency, and
                # device chains never carry lookup refs anyway)
                t = tables[0]
                try:
                    idx = t.column_index(node.locality_ref_column)
                    if t.rows:
                        locality_key = t.rows[0].values[idx]
                except KeyError:
                    pass
            try:
                self.rt.dispatch(node, tables, srcs,
                                 self._make_callback(node), locality_key,
                                 dag=self.dag, ctx=self.ctx)
            except BaseException as e:
                # a dispatch that cannot even start (e.g. every replica of
                # the class unhealthy) must still resolve the caller —
                # a hung Future is the one outcome fault tolerance forbids
                if not self.fut.done():
                    self.fut.set_exception(e)
                return

    def _make_callback(self, node: RuntimeNode):
        def cb(result, error, exec_id):
            if error is not None:
                if not self.fut.done():
                    self.fut.set_exception(error)
                return
            finish = False
            with self.lock:
                if node.name in self.results:   # competitive duplicate
                    return
                self.results[node.name] = result
                self.produced_on[node.name] = exec_id
                if node.name == self.dag.output:
                    finish = True
            if finish:
                if not self.fut.done():
                    self.fut.set_result(result)
                return
            self._advance()
        return cb
