"""Executor pool: the FaaS workers (Cloudburst executor analogue).

Each ``Executor`` is one worker (thread) with a local cache; it executes
function invocations serially (one vCPU-ish).  ``resource_class`` partitions
the pool (paper §4: hardware-aware placement — "gpu" executors model
accelerator-attached workers).  Batch-aware functions are fed whole buckets
dequeued from the function queue.

Fault tolerance: workers can crash (thread dies mid-item), wedge
(straggle indefinitely), or throw transient errors — injected via a
:class:`~repro.serving.faults.FaultInjector`, or for real.  Every
completion is gated by the item's
:class:`~repro.serving.retry.CompletionToken`, so at-least-once
redispatch (crash recovery, straggler hedging, retries) delivers each
logical result exactly once.  The pool runs a heartbeat-based failure
detector: a dead or wedged executor is marked unhealthy, excluded from
``candidates()``, its queued + in-flight items are requeued onto healthy
replicas (items already past deadline expire through the normal
pre-dispatch path), and the replica is replaced — by the pool directly
(``auto_replace``) or by the autoscaler converging on the dropped
replica count.
"""
from __future__ import annotations

import dataclasses
import itertools
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.lowering import DegradePolicy, degraded_execution
from repro.core.table import copy_capture_end, copy_capture_start
from repro.runtime.kvs import KVS, CacheClient
from repro.runtime.netmodel import NetModel, nbytes
from repro.serving.admission import DeadlineExceeded
from repro.serving.faults import FaultCrash, FaultInjector
from repro.serving.retry import CompletionToken, ExecutorLost

_exec_ids = itertools.count()


@dataclasses.dataclass
class WorkItem:
    fn: Callable
    tables: List[Any]
    produced_on: List[Optional[str]]     # executor id per input (for net cost)
    callback: Callable                   # callback(result|None, error|None, executor_id)
    enqueue_t: float = dataclasses.field(default_factory=time.perf_counter)
    # filled in by the executor before the callback fires — the per-stage
    # profile hook (queueing delay vs pure execution time) a batch-aware
    # planner needs (InferLine-style batch latency profiles)
    queue_s: Optional[float] = None
    exec_s: Optional[float] = None
    # overload protection: absolute perf_counter deadline — the worker
    # fails the item fast (DeadlineExceeded) if it dequeues it too late —
    # and the degrade variant the admission gate picked, applied around
    # the fn so the exec-path router sees it on the worker thread
    deadline_t: Optional[float] = None
    degrade: Optional[DegradePolicy] = None
    # at-least-once execution: every dispatch attempt of this logical item
    # (original, crash requeue, straggler hedge) shares one token; exactly
    # one completion claims it and fires the callback
    token: CompletionToken = dataclasses.field(
        default_factory=CompletionToken)
    # idempotence key for side effects: (request id, node, row ids) —
    # ``ExecutionContext.kvs_put`` routes writes through ``KVS.put_once``
    # when set, so a double-executed item cannot double-apply a write
    dispatch_key: Optional[Tuple] = None
    # which dispatch attempt this is (0 = original); the retry policy
    # reads it to cap redispatches and size backoff
    attempt: int = 0
    # observability: every attempt of the logical item (original, crash
    # requeue, hedge, retry clone) appends events to ONE shared log —
    # ("start"|"cancelled"|"requeue", executor_id, t) and
    # ("done", executor_id, t, queue_s, exec_s, copies) — so the single
    # winning callback can reconstruct the full attempt history
    attempt_log: List[Tuple] = dataclasses.field(default_factory=list)
    # host<->device copy counts captured around THIS item's execution
    copies: Optional[Dict[str, int]] = None

    def clone(self) -> "WorkItem":
        """A redispatchable copy sharing this item's completion token and
        dispatch key: whichever attempt finishes first wins the claim,
        the rest fall silent."""
        return WorkItem(fn=self.fn, tables=self.tables,
                        produced_on=self.produced_on,
                        callback=self.callback,
                        deadline_t=self.deadline_t, degrade=self.degrade,
                        token=self.token, dispatch_key=self.dispatch_key,
                        attempt=self.attempt,
                        attempt_log=self.attempt_log)

    def deliver(self, result, error, executor_id: Optional[str]) -> bool:
        """Claim the completion and fire the callback; False if another
        attempt already delivered."""
        if not self.token.claim(executor_id):
            return False
        self.callback(result, error, executor_id)
        return True


class ExecutionContext:
    """Passed to operators: KVS access via the executor's cache."""

    def __init__(self, executor: "Executor",
                 item: Optional[WorkItem] = None):
        self.executor = executor
        self.kvs = executor.cache.kvs
        self.dispatch_key = item.dispatch_key if item is not None else None

    def kvs_get(self, key: str):
        return self.executor.cache.get(key)

    def kvs_put(self, key: str, value):
        if self.dispatch_key is not None:
            # at-least-once execution: a redispatched/hedged item re-runs
            # the operator, but its writes apply exactly once
            if not self.kvs.put_once((self.dispatch_key, key), key, value):
                return
            self.executor.cache.observe(key, value)
            return
        self.executor.cache.put(key, value)


class Executor:
    def __init__(self, kvs: KVS, net: NetModel, resource_class: str = "cpu",
                 cache_bytes: int = 2 << 30, reserved: bool = False,
                 injector: Optional[FaultInjector] = None):
        tag = f"{resource_class}-rsvd" if reserved else resource_class
        self.id = f"{tag}-exec-{next(_exec_ids)}"
        self.resource_class = resource_class
        # reserved workers serve ONLY warm-up/canary traffic: a saturated
        # serving pool cannot starve the canary and abort a good swap
        self.reserved = reserved
        self.net = net
        self.cache = CacheClient(kvs, self.id, cache_bytes)
        self.q: "queue.Queue[WorkItem]" = queue.Queue()
        self._stop = False
        self._injector = injector
        self.busy = False
        self.completed = 0
        # failure-detection state: the worker beats on every loop
        # iteration; ``busy_since``/``current`` expose what it is chewing
        # on so a wedged worker's in-flight item can be recovered
        self.healthy = True
        self.crashed = False
        self.heartbeat_t = time.perf_counter()
        self.busy_since: Optional[float] = None
        self.current: Optional[WorkItem] = None
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=self.id)
        self._thread.start()

    @property
    def load(self) -> int:
        return self.q.qsize() + (1 if self.busy else 0)

    @property
    def alive(self) -> bool:
        return self._thread.is_alive()

    def submit(self, item: WorkItem):
        if self._stop:
            raise RuntimeError(f"{self.id} is stopped")
        self.q.put(item)

    def _run(self):
        try:
            self._loop()
        except FaultCrash:
            # the injected crash: the thread dies here, busy/current left
            # set for the failure detector — swallowed only to keep the
            # default threading excepthook from spamming stderr
            pass

    def _loop(self):
        while not self._stop:
            self.heartbeat_t = time.perf_counter()
            try:
                item = self.q.get(timeout=0.05)
            except queue.Empty:
                continue
            self.busy = True
            t_start = time.perf_counter()
            self.busy_since = t_start
            self.current = item
            if item.token.claimed:
                # another attempt (hedge winner, crash requeue) already
                # delivered: loser cancellation — skip without executing
                item.attempt_log.append(("cancelled", self.id, t_start))
                self.current = None
                self.busy = False
                self.completed += 1
                continue
            item.queue_s = t_start - item.enqueue_t
            if item.deadline_t is not None and item.deadline_t <= t_start:
                # the deadline passed while the item sat in this worker's
                # queue: fail fast instead of burning the worker on a
                # result nobody can use
                item.exec_s = 0.0
                try:
                    item.deliver(None, DeadlineExceeded(
                        "deadline passed in executor queue",
                        deadline_s=item.deadline_t), self.id)
                finally:
                    self.current = None
                    self.busy = False
                    self.completed += 1
                continue
            # the attempt starts HERE (worker claimed the item and went
            # busy) — logged before fault injection so a crashed or hung
            # attempt still counts in the winning span's attempt history
            item.attempt_log.append(("start", self.id, t_start))
            fault = None
            if self._injector is not None:
                fault = self._injector.draw(self.id, self.resource_class)
            if fault is not None and fault.kind == "crash":
                # the injected process crash: the raise propagates out of
                # _loop and kills this thread.  busy/current deliberately
                # stay set — the failure detector recovers the in-flight
                # item from them.
                self.crashed = True
                raise FaultCrash(f"injected crash on {self.id}")
            if fault is not None and fault.kind == "hang":
                # straggle: sleep while "busy" — the hedger and the wedge
                # detector race us; if either wins, skip the execution
                time.sleep(fault.hang_s)
                if item.token.claimed:
                    item.attempt_log.append(
                        ("cancelled", self.id, time.perf_counter()))
                    self.current = None
                    self.busy = False
                    self.completed += 1
                    continue
            try:
                if fault is not None and fault.kind == "transient":
                    raise self._injector.transient_error(self.id)
                self.net.charge_invoke()   # FaaS invocation overhead
                # charge network for inputs shipped from other executors
                for t, src in zip(item.tables, item.produced_on):
                    if src is not None and src != self.id:
                        self.net.charge(nbytes(t))
                ctx = ExecutionContext(self, item)
                copy_capture_start()
                try:
                    if item.degrade is not None:
                        with degraded_execution(item.degrade):
                            result = item.fn(item.tables, ctx)
                    else:
                        result = item.fn(item.tables, ctx)
                finally:
                    item.copies = copy_capture_end()
                t_end = time.perf_counter()
                item.exec_s = t_end - t_start
                item.attempt_log.append(("done", self.id, t_end,
                                         item.queue_s, item.exec_s,
                                         item.copies))
                item.deliver(result, None, self.id)
            except BaseException as e:
                t_end = time.perf_counter()
                item.exec_s = t_end - t_start
                item.attempt_log.append(("done", self.id, t_end,
                                         item.queue_s, item.exec_s,
                                         item.copies))
                item.deliver(None, e, self.id)
            finally:
                self.current = None
                self.busy = False
                self.completed += 1

    def drain(self) -> List[WorkItem]:
        """Pop everything still queued (items the worker has not started).
        The caller owns requeueing or failing them."""
        items: List[WorkItem] = []
        while True:
            try:
                items.append(self.q.get_nowait())
            except queue.Empty:
                return items

    def stop(self) -> List[WorkItem]:
        """Stop the worker and return its undispatched queue.  Callers
        MUST route the returned items somewhere (requeue or fail) — the
        pre-fault-tolerance ``stop()`` dropped them silently, hanging
        every caller whose callback never fired."""
        self._stop = True
        return self.drain()


class ExecutorPool:
    """All executors, partitioned by resource class, plus per-function
    replica assignment (the autoscaler mutates assignments) and the
    heartbeat failure detector."""

    def __init__(self, kvs: KVS, net: NetModel,
                 n_cpu: int = 4, n_gpu: int = 0,
                 cache_bytes: int = 2 << 30,
                 reserved_cpu: int = 0, reserved_gpu: int = 0,
                 fault_injector: Optional[FaultInjector] = None,
                 hang_timeout_s: float = 5.0,
                 auto_replace: bool = True,
                 on_fault: Optional[Callable[[str, str, int], None]] = None):
        self.kvs = kvs
        self.net = net
        self.cache_bytes = cache_bytes
        self.injector = fault_injector
        #: busy longer than this = wedged (conservatively above any
        #: legitimate whole-batch service time)
        self.hang_timeout_s = hang_timeout_s
        #: replace a failed executor with a fresh one of the same class
        #: immediately; with False, replacement is the autoscaler's job
        #: (it converges on the dropped replica count)
        self.auto_replace = auto_replace
        #: hook(kind, executor_id, n_requeued) for "crash"/"wedge"
        #: events — the runtime records fault metric series through it
        self.on_fault = on_fault
        self.fault_counts: Dict[str, int] = {"crash": 0, "wedge": 0,
                                             "requeued": 0, "replaced": 0,
                                             "lost": 0}
        self.executors: Dict[str, Executor] = {}
        self._lock = threading.Lock()
        self._detector: Optional[threading.Thread] = None
        self._detector_stop = False
        for _ in range(n_cpu):
            self.add_executor("cpu")
        for _ in range(n_gpu):
            self.add_executor("gpu")
        for _ in range(reserved_cpu):
            self.add_executor("cpu", reserved=True)
        for _ in range(reserved_gpu):
            self.add_executor("gpu", reserved=True)
        # function name -> executor ids allowed to run it (None = any in class)
        self.assignment: Dict[str, List[str]] = {}

    def add_executor(self, resource_class: str, *,
                     reserved: bool = False) -> Executor:
        ex = Executor(self.kvs, self.net, resource_class, self.cache_bytes,
                      reserved=reserved, injector=self.injector)
        with self._lock:
            self.executors[ex.id] = ex
        return ex

    def set_injector(self, injector: Optional[FaultInjector]) -> None:
        """Swap the fault plan at runtime (the chaos benchmark sweeps
        rates without rebuilding the pool)."""
        with self._lock:
            self.injector = injector
            for e in self.executors.values():
                e._injector = injector

    def by_class(self, resource_class: str, *,
                 reserved: bool = False) -> List[Executor]:
        """HEALTHY serving workers of a class; ``reserved=True`` returns
        the warm-up/canary pool instead.  The two never mix: serving
        traffic cannot spill onto reserved workers, and reserved work
        does not queue behind a saturated serving pool.  Unhealthy
        (crashed/wedged) workers are excluded everywhere."""
        with self._lock:
            return [e for e in self.executors.values()
                    if e.resource_class == resource_class
                    and e.reserved == reserved
                    and e.healthy and not e._stop]

    def by_id(self, executor_id: str) -> Optional[Executor]:
        with self._lock:
            return self.executors.get(executor_id)

    def candidates(self, fname: str, resource_class: str) -> List[Executor]:
        with self._lock:
            ids = self.assignment.get(fname)
            if ids:
                got = [self.executors[i] for i in ids
                       if i in self.executors
                       and self.executors[i].healthy
                       and not self.executors[i]._stop]
                if got:
                    return got
        return self.by_class(resource_class)

    # -- failure detection ---------------------------------------------------
    def start_failure_detector(self, interval_s: float = 0.05) -> None:
        """Start the heartbeat monitor: crashed (thread dead) and wedged
        (busy past ``hang_timeout_s``) executors are failed over.  Idempotent."""
        if self._detector is not None:
            return
        self._detector_stop = False

        def _watch():
            while not self._detector_stop:
                try:
                    self.check_health()
                except Exception:       # the detector must never die
                    pass
                time.sleep(interval_s)

        self._detector = threading.Thread(target=_watch, daemon=True,
                                          name="failure-detector")
        self._detector.start()

    def check_health(self, now: Optional[float] = None) -> List[str]:
        """One detection pass (tests drive this directly for determinism).
        Returns the ids of executors failed over in this pass."""
        now = now if now is not None else time.perf_counter()
        with self._lock:
            suspects = []
            for e in self.executors.values():
                if not e.healthy or e._stop:
                    continue
                if not e.alive:
                    suspects.append((e, "crash"))
                elif e.busy and e.busy_since is not None \
                        and now - e.busy_since > self.hang_timeout_s:
                    suspects.append((e, "wedge"))
        failed = []
        for e, kind in suspects:
            self._handle_failure(e, kind)
            failed.append(e.id)
        return failed

    def _handle_failure(self, ex: Executor, kind: str) -> None:
        """Fail over one executor: mark it unhealthy, requeue its queued
        + in-flight items onto healthy replicas, prune it from replica
        assignments (the autoscaler sees the dropped count), and replace
        it when ``auto_replace``."""
        with self._lock:
            if not ex.healthy:          # another pass got here first
                return
            ex.healthy = False
            # prune from assignments so replica_count drops — the signal
            # the autoscaler converges on
            lost_fnames = []
            for fname, ids in self.assignment.items():
                if ex.id in ids:
                    ids.remove(ex.id)
                    lost_fnames.append(fname)
            self.fault_counts[kind] += 1
        # a wedged worker is still alive: stop it so it exits after the
        # current item instead of chewing new work, and drain its queue
        # before it can wake up and reach it.  (A crashed worker's thread
        # is already gone; drain is uncontended.)
        ex._stop = True
        orphans = ex.drain()
        if ex.current is not None:
            # in-flight recovery: a clone shares the completion token, so
            # if the wedged original eventually finishes, exactly one of
            # the two attempts delivers
            orphans.append(ex.current.clone())
        replacement = None
        if self.auto_replace:
            replacement = self.add_executor(ex.resource_class,
                                            reserved=ex.reserved)
            with self._lock:
                for fname in lost_fnames:
                    self.assignment.setdefault(fname, []).append(
                        replacement.id)
                self.fault_counts["replaced"] += 1
        n = self.requeue(orphans, ex.resource_class,
                         exclude={ex.id}, reserved=ex.reserved)
        if self.on_fault is not None:
            try:
                self.on_fault(kind, ex.id, n)
            except Exception:
                pass

    def requeue(self, items: List[WorkItem], resource_class: str, *,
                exclude: Optional[set] = None,
                reserved: bool = False) -> int:
        """Redispatch orphaned items onto the least-loaded healthy
        replicas of a class.  Items whose completion was already claimed
        are dropped (their result was delivered elsewhere); with no
        healthy replica left, items fail typed (``ExecutorLost``) so
        callers never hang.  Returns how many items were requeued."""
        exclude = exclude or set()
        n = 0
        for item in items:
            if item.token.claimed:
                continue
            targets = [e for e in self.by_class(resource_class,
                                                reserved=reserved)
                       if e.id not in exclude]
            if not targets:
                with self._lock:
                    self.fault_counts["lost"] += 1
                try:
                    item.deliver(None, ExecutorLost(
                        f"no healthy {resource_class} replica to requeue "
                        "onto"), None)
                except Exception:
                    pass
                continue
            target = min(targets, key=lambda e: e.load)
            try:
                target.submit(item)
                item.attempt_log.append(
                    ("requeue", target.id, time.perf_counter()))
                n += 1
            except RuntimeError:        # stopped under our feet: next pass
                try:
                    item.deliver(None, ExecutorLost(
                        f"{target.id} stopped during requeue"), None)
                except Exception:
                    pass
        if n:
            with self._lock:
                self.fault_counts["requeued"] += n
        return n

    # -- autoscaler hooks ----------------------------------------------------
    def assign(self, fname: str, executor_ids: List[str]):
        with self._lock:
            self.assignment[fname] = list(executor_ids)

    def add_replica(self, fname: str, resource_class: str) -> str:
        ex = self.add_executor(resource_class)
        with self._lock:
            self.assignment.setdefault(fname, []).append(ex.id)
        return ex.id

    def remove_replica(self, fname: str) -> Optional[str]:
        with self._lock:
            ids = self.assignment.get(fname) or []
            if len(ids) <= 1:
                return None
            # prefer trimming an unhealthy replica: it serves nothing
            eid = next((i for i in ids
                        if i in self.executors
                        and not self.executors[i].healthy), ids[-1])
            ids.remove(eid)
            ex = self.executors.pop(eid, None)
        if ex:
            # lost-work fix: the removed replica's queued items used to be
            # dropped with their callbacks never fired — route them
            # through the requeue path instead
            orphans = ex.stop()
            if orphans:
                self.requeue(orphans, ex.resource_class,
                             exclude={eid}, reserved=ex.reserved)
        return eid

    def replica_count(self, fname: str) -> int:
        """Healthy replicas assigned to ``fname`` — a crashed replica no
        longer counts, which is exactly the deficit the autoscaler's
        target mode closes."""
        with self._lock:
            ids = self.assignment.get(fname)
            if not ids:
                return 0
            return sum(1 for i in ids
                       if i in self.executors
                       and self.executors[i].healthy)

    def queue_depth(self, fname: str, resource_class: str = "cpu") -> int:
        return sum(e.load for e in self.candidates(fname, resource_class))

    def total_depth(self, *, reserved: bool = False) -> int:
        """Queued + in-flight items across every healthy serving
        executor: the leading-indicator load signal the admission gate
        blends into its deadline-risk estimate."""
        with self._lock:
            return sum(e.load for e in self.executors.values()
                       if e.reserved == reserved
                       and e.healthy and not e._stop)

    def stop(self):
        self._detector_stop = True
        with self._lock:
            executors = list(self.executors.values())
        for e in executors:
            for item in e.stop():
                # fail leftovers typed instead of stranding their callers
                try:
                    item.deliver(None, RuntimeError(
                        "executor pool stopped"), None)
                except Exception:
                    pass
