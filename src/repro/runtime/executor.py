"""Executor pool: the FaaS workers (Cloudburst executor analogue).

Each ``Executor`` is one worker (thread) with a local cache; it executes
function invocations serially (one vCPU-ish).  ``resource_class`` partitions
the pool (paper §4: hardware-aware placement — "gpu" executors model
accelerator-attached workers).  Batch-aware functions are fed whole buckets
dequeued from the function queue.
"""
from __future__ import annotations

import dataclasses
import itertools
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from repro.core.lowering import DegradePolicy, degraded_execution
from repro.runtime.kvs import KVS, CacheClient
from repro.runtime.netmodel import NetModel, nbytes
from repro.serving.admission import DeadlineExceeded

_exec_ids = itertools.count()


@dataclasses.dataclass
class WorkItem:
    fn: Callable
    tables: List[Any]
    produced_on: List[Optional[str]]     # executor id per input (for net cost)
    callback: Callable                   # callback(result|None, error|None, executor_id)
    enqueue_t: float = dataclasses.field(default_factory=time.perf_counter)
    # filled in by the executor before the callback fires — the per-stage
    # profile hook (queueing delay vs pure execution time) a batch-aware
    # planner needs (InferLine-style batch latency profiles)
    queue_s: Optional[float] = None
    exec_s: Optional[float] = None
    # overload protection: absolute perf_counter deadline — the worker
    # fails the item fast (DeadlineExceeded) if it dequeues it too late —
    # and the degrade variant the admission gate picked, applied around
    # the fn so the exec-path router sees it on the worker thread
    deadline_t: Optional[float] = None
    degrade: Optional[DegradePolicy] = None


class ExecutionContext:
    """Passed to operators: KVS access via the executor's cache."""

    def __init__(self, executor: "Executor"):
        self.executor = executor
        self.kvs = executor.cache.kvs

    def kvs_get(self, key: str):
        return self.executor.cache.get(key)

    def kvs_put(self, key: str, value):
        self.executor.cache.put(key, value)


class Executor:
    def __init__(self, kvs: KVS, net: NetModel, resource_class: str = "cpu",
                 cache_bytes: int = 2 << 30, reserved: bool = False):
        tag = f"{resource_class}-rsvd" if reserved else resource_class
        self.id = f"{tag}-exec-{next(_exec_ids)}"
        self.resource_class = resource_class
        # reserved workers serve ONLY warm-up/canary traffic: a saturated
        # serving pool cannot starve the canary and abort a good swap
        self.reserved = reserved
        self.net = net
        self.cache = CacheClient(kvs, self.id, cache_bytes)
        self.q: "queue.Queue[WorkItem]" = queue.Queue()
        self._stop = False
        self.busy = False
        self.completed = 0
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=self.id)
        self._thread.start()

    @property
    def load(self) -> int:
        return self.q.qsize() + (1 if self.busy else 0)

    def submit(self, item: WorkItem):
        self.q.put(item)

    def _loop(self):
        while not self._stop:
            try:
                item = self.q.get(timeout=0.05)
            except queue.Empty:
                continue
            self.busy = True
            t_start = time.perf_counter()
            item.queue_s = t_start - item.enqueue_t
            if item.deadline_t is not None and item.deadline_t <= t_start:
                # the deadline passed while the item sat in this worker's
                # queue: fail fast instead of burning the worker on a
                # result nobody can use
                item.exec_s = 0.0
                try:
                    item.callback(None, DeadlineExceeded(
                        "deadline passed in executor queue",
                        deadline_s=item.deadline_t), self.id)
                finally:
                    self.busy = False
                    self.completed += 1
                continue
            try:
                self.net.charge_invoke()   # FaaS invocation overhead
                # charge network for inputs shipped from other executors
                for t, src in zip(item.tables, item.produced_on):
                    if src is not None and src != self.id:
                        self.net.charge(nbytes(t))
                ctx = ExecutionContext(self)
                if item.degrade is not None:
                    with degraded_execution(item.degrade):
                        result = item.fn(item.tables, ctx)
                else:
                    result = item.fn(item.tables, ctx)
                item.exec_s = time.perf_counter() - t_start
                item.callback(result, None, self.id)
            except BaseException as e:
                item.exec_s = time.perf_counter() - t_start
                item.callback(None, e, self.id)
            finally:
                self.busy = False
                self.completed += 1

    def stop(self):
        self._stop = True


class ExecutorPool:
    """All executors, partitioned by resource class, plus per-function
    replica assignment (the autoscaler mutates assignments)."""

    def __init__(self, kvs: KVS, net: NetModel,
                 n_cpu: int = 4, n_gpu: int = 0,
                 cache_bytes: int = 2 << 30,
                 reserved_cpu: int = 0, reserved_gpu: int = 0):
        self.kvs = kvs
        self.net = net
        self.cache_bytes = cache_bytes
        self.executors: Dict[str, Executor] = {}
        self._lock = threading.Lock()
        for _ in range(n_cpu):
            self.add_executor("cpu")
        for _ in range(n_gpu):
            self.add_executor("gpu")
        for _ in range(reserved_cpu):
            self.add_executor("cpu", reserved=True)
        for _ in range(reserved_gpu):
            self.add_executor("gpu", reserved=True)
        # function name -> executor ids allowed to run it (None = any in class)
        self.assignment: Dict[str, List[str]] = {}

    def add_executor(self, resource_class: str, *,
                     reserved: bool = False) -> Executor:
        ex = Executor(self.kvs, self.net, resource_class, self.cache_bytes,
                      reserved=reserved)
        with self._lock:
            self.executors[ex.id] = ex
        return ex

    def by_class(self, resource_class: str, *,
                 reserved: bool = False) -> List[Executor]:
        """Serving workers of a class; ``reserved=True`` returns the
        warm-up/canary pool instead.  The two never mix: serving traffic
        cannot spill onto reserved workers, and reserved work does not
        queue behind a saturated serving pool."""
        with self._lock:
            return [e for e in self.executors.values()
                    if e.resource_class == resource_class
                    and e.reserved == reserved]

    def by_id(self, executor_id: str) -> Optional[Executor]:
        with self._lock:
            return self.executors.get(executor_id)

    def candidates(self, fname: str, resource_class: str) -> List[Executor]:
        with self._lock:
            ids = self.assignment.get(fname)
            if ids:
                got = [self.executors[i] for i in ids
                       if i in self.executors]
                if got:
                    return got
        return self.by_class(resource_class)

    # -- autoscaler hooks ----------------------------------------------------
    def assign(self, fname: str, executor_ids: List[str]):
        with self._lock:
            self.assignment[fname] = list(executor_ids)

    def add_replica(self, fname: str, resource_class: str) -> str:
        ex = self.add_executor(resource_class)
        with self._lock:
            self.assignment.setdefault(fname, []).append(ex.id)
        return ex.id

    def remove_replica(self, fname: str) -> Optional[str]:
        with self._lock:
            ids = self.assignment.get(fname) or []
            if len(ids) <= 1:
                return None
            eid = ids.pop()
            ex = self.executors.pop(eid, None)
        if ex:
            ex.stop()
        return eid

    def replica_count(self, fname: str) -> int:
        with self._lock:
            ids = self.assignment.get(fname)
            return len(ids) if ids else 0

    def queue_depth(self, fname: str, resource_class: str = "cpu") -> int:
        return sum(e.load for e in self.candidates(fname, resource_class))

    def stop(self):
        with self._lock:
            for e in self.executors.values():
                e.stop()
