"""Executor pool: the FaaS workers (Cloudburst executor analogue).

Each ``Executor`` is one worker (thread) with a local cache; it executes
function invocations serially (one vCPU-ish).  ``resource_class`` partitions
the pool (paper §4: hardware-aware placement — "gpu" executors model
accelerator-attached workers).  Batch-aware functions are fed whole buckets
dequeued from the function queue.
"""
from __future__ import annotations

import dataclasses
import itertools
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from repro.runtime.kvs import KVS, CacheClient
from repro.runtime.netmodel import NetModel, nbytes

_exec_ids = itertools.count()


@dataclasses.dataclass
class WorkItem:
    fn: Callable
    tables: List[Any]
    produced_on: List[Optional[str]]     # executor id per input (for net cost)
    callback: Callable                   # callback(result|None, error|None, executor_id)
    enqueue_t: float = dataclasses.field(default_factory=time.perf_counter)
    # filled in by the executor before the callback fires — the per-stage
    # profile hook (queueing delay vs pure execution time) a batch-aware
    # planner needs (InferLine-style batch latency profiles)
    queue_s: Optional[float] = None
    exec_s: Optional[float] = None


class ExecutionContext:
    """Passed to operators: KVS access via the executor's cache."""

    def __init__(self, executor: "Executor"):
        self.executor = executor
        self.kvs = executor.cache.kvs

    def kvs_get(self, key: str):
        return self.executor.cache.get(key)

    def kvs_put(self, key: str, value):
        self.executor.cache.put(key, value)


class Executor:
    def __init__(self, kvs: KVS, net: NetModel, resource_class: str = "cpu",
                 cache_bytes: int = 2 << 30):
        self.id = f"{resource_class}-exec-{next(_exec_ids)}"
        self.resource_class = resource_class
        self.net = net
        self.cache = CacheClient(kvs, self.id, cache_bytes)
        self.q: "queue.Queue[WorkItem]" = queue.Queue()
        self._stop = False
        self.busy = False
        self.completed = 0
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=self.id)
        self._thread.start()

    @property
    def load(self) -> int:
        return self.q.qsize() + (1 if self.busy else 0)

    def submit(self, item: WorkItem):
        self.q.put(item)

    def _loop(self):
        while not self._stop:
            try:
                item = self.q.get(timeout=0.05)
            except queue.Empty:
                continue
            self.busy = True
            t_start = time.perf_counter()
            item.queue_s = t_start - item.enqueue_t
            try:
                self.net.charge_invoke()   # FaaS invocation overhead
                # charge network for inputs shipped from other executors
                for t, src in zip(item.tables, item.produced_on):
                    if src is not None and src != self.id:
                        self.net.charge(nbytes(t))
                ctx = ExecutionContext(self)
                result = item.fn(item.tables, ctx)
                item.exec_s = time.perf_counter() - t_start
                item.callback(result, None, self.id)
            except BaseException as e:
                item.exec_s = time.perf_counter() - t_start
                item.callback(None, e, self.id)
            finally:
                self.busy = False
                self.completed += 1

    def stop(self):
        self._stop = True


class ExecutorPool:
    """All executors, partitioned by resource class, plus per-function
    replica assignment (the autoscaler mutates assignments)."""

    def __init__(self, kvs: KVS, net: NetModel,
                 n_cpu: int = 4, n_gpu: int = 0,
                 cache_bytes: int = 2 << 30):
        self.kvs = kvs
        self.net = net
        self.cache_bytes = cache_bytes
        self.executors: Dict[str, Executor] = {}
        self._lock = threading.Lock()
        for _ in range(n_cpu):
            self.add_executor("cpu")
        for _ in range(n_gpu):
            self.add_executor("gpu")
        # function name -> executor ids allowed to run it (None = any in class)
        self.assignment: Dict[str, List[str]] = {}

    def add_executor(self, resource_class: str) -> Executor:
        ex = Executor(self.kvs, self.net, resource_class, self.cache_bytes)
        with self._lock:
            self.executors[ex.id] = ex
        return ex

    def by_class(self, resource_class: str) -> List[Executor]:
        with self._lock:
            return [e for e in self.executors.values()
                    if e.resource_class == resource_class]

    def by_id(self, executor_id: str) -> Optional[Executor]:
        with self._lock:
            return self.executors.get(executor_id)

    def candidates(self, fname: str, resource_class: str) -> List[Executor]:
        with self._lock:
            ids = self.assignment.get(fname)
            if ids:
                got = [self.executors[i] for i in ids
                       if i in self.executors]
                if got:
                    return got
        return self.by_class(resource_class)

    # -- autoscaler hooks ----------------------------------------------------
    def assign(self, fname: str, executor_ids: List[str]):
        with self._lock:
            self.assignment[fname] = list(executor_ids)

    def add_replica(self, fname: str, resource_class: str) -> str:
        ex = self.add_executor(resource_class)
        with self._lock:
            self.assignment.setdefault(fname, []).append(ex.id)
        return ex.id

    def remove_replica(self, fname: str) -> Optional[str]:
        with self._lock:
            ids = self.assignment.get(fname) or []
            if len(ids) <= 1:
                return None
            eid = ids.pop()
            ex = self.executors.pop(eid, None)
        if ex:
            ex.stop()
        return eid

    def replica_count(self, fname: str) -> int:
        with self._lock:
            ids = self.assignment.get(fname)
            return len(ids) if ids else 0

    def queue_depth(self, fname: str, resource_class: str = "cpu") -> int:
        return sum(e.load for e in self.candidates(fname, resource_class))

    def stop(self):
        with self._lock:
            for e in self.executors.values():
                e.stop()
