"""Queue-depth autoscaler (paper §5.1.3 / Fig 6).

Monitors per-function pending work; adds replicas for saturated functions
and trims idle over-provisioned ones, leaving slack (the paper's observed
behavior: a couple of spare replicas after a spike settles).

Two control modes per function:

* **target mode** — an optimizer-suggested replica count set via
  ``set_target`` (the SLO controller's M/M/c ``c`` for the measured
  arrival rate): scale up toward the target immediately, trim (with
  hysteresis) anything beyond ``target + slack``.
* **depth heuristic** — the original queue-depth rule, used for
  functions with no target.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional

from repro.runtime.executor import ExecutorPool


@dataclasses.dataclass
class AutoscalerConfig:
    interval_s: float = 0.25
    scale_up_depth: float = 2.0      # queued per replica before scaling up
    scale_up_count: int = 4          # replicas added per tick when saturated
    scale_down_idle: float = 0.2     # avg depth per replica to scale down
    min_replicas: int = 1
    max_replicas: int = 64
    slack: int = 2                   # keep this many spares


class Autoscaler:
    def __init__(self, pool: ExecutorPool, functions: Dict[str, str],
                 cfg: Optional[AutoscalerConfig] = None, *, tracer=None):
        """functions: fname -> resource_class to manage.  ``tracer`` (a
        ``repro.obs.trace.Tracer``) receives a control-plane event per
        replica add/remove/replace, so scaling actions line up against
        request latency in trace exports."""
        self.pool = pool
        self.functions = functions
        self.tracer = tracer
        self.cfg = cfg or AutoscalerConfig()
        self._stop = False
        self.history: List[Dict[str, int]] = []
        self._idle_ticks: Dict[str, int] = {f: 0 for f in functions}
        self._targets: Dict[str, int] = {}
        self._targets_lock = threading.Lock()
        self._thread = threading.Thread(target=self._loop, daemon=True)

    def start(self):
        self._thread.start()
        return self

    def stop(self):
        self._stop = True

    # -- optimizer-suggested targets (SLO controller hook) --------------------
    def set_target(self, fname: str, replicas: int) -> None:
        """Pin ``fname``'s replica count to an optimizer-suggested target
        (clamped to the configured bounds).  Overrides the queue-depth
        heuristic until ``clear_target``."""
        with self._targets_lock:
            self._targets[fname] = max(self.cfg.min_replicas,
                                       min(int(replicas),
                                           self.cfg.max_replicas))

    def clear_target(self, fname: str) -> None:
        with self._targets_lock:
            self._targets.pop(fname, None)

    def _event(self, action: str, fname: str, **attrs) -> None:
        if self.tracer is not None:
            self.tracer.control_event(f"scale@{fname}", action=action,
                                      **attrs)

    def target(self, fname: str) -> Optional[int]:
        with self._targets_lock:
            return self._targets.get(fname)

    def _tick_target(self, fname: str, rclass: str, n: int,
                     target: int) -> None:
        """Converge toward the target: scale up fast (bounded per tick),
        trim anything beyond ``target + slack`` slowly (hysteresis), so a
        spike's replicas settle with the paper's observed slack."""
        c = self.cfg
        if n < target:
            added = min(c.scale_up_count, target - n)
            for _ in range(added):
                self.pool.add_replica(fname, rclass)
            self._event("replica_add", fname, count=added, reason="target",
                        replicas=n + added, target=target)
            self._idle_ticks[fname] = 0
        elif n > target + c.slack:
            self._idle_ticks[fname] += 1
            if self._idle_ticks[fname] >= 4:      # hysteresis
                self.pool.remove_replica(fname)
                self._event("replica_remove", fname, count=1,
                            reason="target", replicas=n - 1, target=target)
                self._idle_ticks[fname] = 0
        else:
            self._idle_ticks[fname] = 0

    def _tick_depth(self, fname: str, rclass: str, n: int) -> None:
        """The original queue-depth heuristic (no target set)."""
        c = self.cfg
        depth = self.pool.queue_depth(fname, rclass)
        per = depth / n
        if per > c.scale_up_depth and n < c.max_replicas:
            added = min(c.scale_up_count, c.max_replicas - n)
            for _ in range(added):
                self.pool.add_replica(fname, rclass)
            self._event("replica_add", fname, count=added, reason="depth",
                        replicas=n + added, depth=depth)
            self._idle_ticks[fname] = 0
        elif per < c.scale_down_idle and n > c.min_replicas + c.slack:
            self._idle_ticks[fname] += 1
            if self._idle_ticks[fname] >= 8:       # hysteresis
                self.pool.remove_replica(fname)
                self._event("replica_remove", fname, count=1,
                            reason="idle", replicas=n - 1, depth=depth)
                self._idle_ticks[fname] = 0
        else:
            self._idle_ticks[fname] = 0

    def _loop(self):
        while not self._stop:
            snapshot = {}
            for fname, rclass in self.functions.items():
                # failed-replica floor: replica_count counts HEALTHY
                # executors, so a crashed/wedged worker shows up here as a
                # shortfall — replace it even when the queue is empty (a
                # dead replica with no backlog would otherwise never
                # trigger the depth heuristic, and the next burst would
                # land on a short fleet).  Only for functions that HAVE an
                # assignment: creating a first one would narrow
                # candidates() away from the pool-wide default executors.
                if fname in self.pool.assignment:
                    n0 = self.pool.replica_count(fname)
                    replaced = 0
                    while n0 < self.cfg.min_replicas:
                        self.pool.add_replica(fname, rclass)
                        n0 += 1
                        replaced += 1
                    if replaced:
                        self._event("replica_replace", fname,
                                    count=replaced, reason="failed_floor",
                                    replicas=n0)
                n = max(1, self.pool.replica_count(fname))
                target = self.target(fname)
                if target is not None:
                    self._tick_target(fname, rclass, n, target)
                else:
                    self._tick_depth(fname, rclass, n)
                snapshot[fname] = self.pool.replica_count(fname)
            self.history.append(snapshot)
            time.sleep(self.cfg.interval_s)
