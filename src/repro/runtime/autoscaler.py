"""Queue-depth autoscaler (paper §5.1.3 / Fig 6).

Monitors per-function pending work; adds replicas for saturated functions
and trims idle over-provisioned ones, leaving slack (the paper's observed
behavior: a couple of spare replicas after a spike settles).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional

from repro.runtime.executor import ExecutorPool


@dataclasses.dataclass
class AutoscalerConfig:
    interval_s: float = 0.25
    scale_up_depth: float = 2.0      # queued per replica before scaling up
    scale_up_count: int = 4          # replicas added per tick when saturated
    scale_down_idle: float = 0.2     # avg depth per replica to scale down
    min_replicas: int = 1
    max_replicas: int = 64
    slack: int = 2                   # keep this many spares


class Autoscaler:
    def __init__(self, pool: ExecutorPool, functions: Dict[str, str],
                 cfg: Optional[AutoscalerConfig] = None):
        """functions: fname -> resource_class to manage."""
        self.pool = pool
        self.functions = functions
        self.cfg = cfg or AutoscalerConfig()
        self._stop = False
        self.history: List[Dict[str, int]] = []
        self._idle_ticks: Dict[str, int] = {f: 0 for f in functions}
        self._thread = threading.Thread(target=self._loop, daemon=True)

    def start(self):
        self._thread.start()
        return self

    def stop(self):
        self._stop = True

    def _loop(self):
        c = self.cfg
        while not self._stop:
            snapshot = {}
            for fname, rclass in self.functions.items():
                n = max(1, self.pool.replica_count(fname))
                depth = self.pool.queue_depth(fname, rclass)
                per = depth / n
                if per > c.scale_up_depth and n < c.max_replicas:
                    for _ in range(min(c.scale_up_count,
                                       c.max_replicas - n)):
                        self.pool.add_replica(fname, rclass)
                    self._idle_ticks[fname] = 0
                elif per < c.scale_down_idle and n > c.min_replicas + c.slack:
                    self._idle_ticks[fname] += 1
                    if self._idle_ticks[fname] >= 8:   # hysteresis
                        self.pool.remove_replica(fname)
                        self._idle_ticks[fname] = 0
                else:
                    self._idle_ticks[fname] = 0
                snapshot[fname] = self.pool.replica_count(fname)
            self.history.append(snapshot)
            time.sleep(c.interval_s)
