"""Runtime DAG: what the Cloudflow compiler emits (Cloudburst-DAG analogue).

Each node is a named function over Tables with scheduling annotations:
``resource_class`` (cpu/gpu executor pools), ``batching`` (batch-aware fn),
``wait_any`` (wait-for-any semantics for anyof), and ``tbc`` — the
*to-be-continued* annotation for dynamic dispatch: the node's result carries
a resolved KVS ref and the scheduler places the continuation DAG on a
machine likely caching that ref (paper §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

from repro.core.table import Table


@dataclasses.dataclass
class RuntimeNode:
    name: str
    fn: Callable[[List[Table], Any], Table]     # (tables, ctx) -> Table
    deps: List[str]
    resource_class: str = "cpu"
    batching: bool = False
    wait_any: bool = False
    # dynamic dispatch: column holding the resolved KVS ref (or a constant)
    locality_ref_column: Optional[str] = None
    locality_const: Optional[str] = None


@dataclasses.dataclass
class RuntimeDag:
    name: str
    nodes: Dict[str, RuntimeNode]
    output: str

    def topo(self) -> List[RuntimeNode]:
        order, seen = [], set()

        def visit(n: str):
            if n in seen:
                return
            seen.add(n)
            for d in self.nodes[n].deps:
                visit(d)
            order.append(self.nodes[n])

        visit(self.output)
        return order

    def validate(self):
        for n in self.nodes.values():
            for d in n.deps:
                if d not in self.nodes:
                    raise ValueError(f"{n.name} depends on unknown {d}")
        self.topo()
