"""Runtime DAG: what the compilation pipeline emits (Cloudburst-DAG
analogue).

Each node is a named function over Tables with scheduling annotations:
``resource_class`` (cpu/gpu executor pools), ``batching`` (batch-aware fn),
``wait_any`` (wait-for-any semantics for anyof), ``jitted`` (the node's fn
is a single XLA-compiled callable), the device-residency flags, and the
locality refs — the *to-be-continued* annotation for dynamic dispatch: the
node's result carries a resolved KVS ref and the scheduler places the
continuation DAG on a machine likely caching that ref (paper §4).

``RuntimeDag.from_plan`` is the lowering from the physical-plan IR: one
``RuntimeNode`` per ``PhysicalOp``, annotations copied verbatim — plus the
device-edge analysis: a device-resident op whose consumers are ALL
device-resident (single-input, not wait-any, not request-batching) *emits*
a ``DeviceTable`` instead of gathering back to the host, so a chain of
adjacent accelerator nodes pays one host->device stack at entry and one
gather at the demux boundary.  When such an op has exactly one consumer its
output buffers are marked donatable — the consumer's executable hands them
to XLA (``donate_argnums``) and the next batch reuses the allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

from repro.core.table import Table


@dataclasses.dataclass
class RuntimeNode:
    name: str
    fn: Callable[[List[Table], Any], Table]     # (tables, ctx) -> Table
    deps: List[str]
    resource_class: str = "cpu"
    batching: bool = False
    wait_any: bool = False
    jitted: bool = False
    # batched execution: (merged_table_list, ctx) -> Table, ONE vmapped XLA
    # dispatch per batch (set when the op lowered to a BatchedJittedFuse)
    batched_fn: Optional[Callable[[List[Table], Any], Table]] = None
    batch_buckets: tuple = ()
    # device residency: the op consumes/produces DeviceTables; emits_device
    # means its output actually stays on the device (every consumer is a
    # device-resident op), skipping the host gather at this edge
    device_resident: bool = False
    emits_device: bool = False
    # dynamic dispatch: column holding the resolved KVS ref (or a constant)
    locality_ref_column: Optional[str] = None
    locality_const: Optional[str] = None
    plan_op_id: Optional[int] = None            # provenance into the IR
    # competitive replication: nodes feeding the same wait-any consumer
    # share a group id — under degraded serving only ONE member of each
    # group is dispatched (no tail-suppression racing for best-effort
    # traffic during overload)
    competitive_group: Optional[str] = None


@dataclasses.dataclass
class RuntimeDag:
    name: str
    nodes: Dict[str, RuntimeNode]
    output: str
    #: deployment generation, assigned by ``Runtime.prepare_dag``: two
    #: generations of the same logical DAG (blue/green replanning) must
    #: never share mutable runtime state — batchers capture node closures,
    #: so a generation owns its batchers exclusively.  0 = unregistered.
    generation: int = 0

    @classmethod
    def from_plan(cls, plan, dag_name: str, *,
                  device_resident: bool = True) -> "RuntimeDag":
        """Lower a ``repro.core.ir.PhysicalPlan`` to a runtime DAG.
        ``device_resident=False`` disables the device-edge analysis (every
        node gathers back to the host — the pre-device-pipeline behavior,
        kept for benchmarking the difference)."""
        from repro.core.lowering import BatchedJittedFuse, JittedFuse

        consumers: Dict[int, List] = {}
        for o in plan.ops:
            for i in o.inputs:
                consumers.setdefault(i, []).append(o)

        def wrap(op):
            def fn(tables, ctx):
                return op.apply(tables, ctx)
            return fn

        def wrap_device(op, emits, donate):
            def fn(tables, ctx):
                return op.apply_batched(tables, ctx, emit_device=emits,
                                        donate_out=donate)
            return fn

        nodes: Dict[str, RuntimeNode] = {}
        names: Dict[int, str] = {}
        out_name = None
        for o in plan.ops:
            nm = f"{dag_name}/{o.op_id}:{o.op.name}"[:120]
            names[o.op_id] = nm
            batched = isinstance(o.op, BatchedJittedFuse)
            dev = batched and bool(getattr(o, "device_resident", False))
            cons = consumers.get(o.op_id, [])
            # emit a DeviceTable only when every consumer can take it
            # straight off the device: a device-resident single-input op
            # that neither races (wait-any) nor merges requests on the
            # host (batching); the plan output always gathers
            emits = (device_resident and dev and bool(cons)
                     and o.op_id != plan.output_id
                     and all(getattr(c, "device_resident", False)
                             and not c.wait_any and not c.batching
                             and len(c.inputs) == 1 for c in cons))
            # sole consumer -> nobody else holds the buffers: donate them.
            # An explicit IR annotation overrides the derived default —
            # donate=False pins buffers (debugging/aliasing-hostile
            # backends); donate=True forces donation and is audited by
            # the static verifier (CF201: donating a shared edge deletes
            # buffers a sibling consumer still needs).  Donation is only
            # meaningful on an emitting device edge either way.
            explicit = getattr(o, "donate", None)
            donate = (emits and bool(explicit)) if explicit is not None \
                else (emits and len(cons) == 1)
            fn = wrap_device(o.op, emits, donate) if batched else wrap(o.op)
            nodes[nm] = RuntimeNode(
                name=nm, fn=fn,
                deps=[names[i] for i in o.inputs if i in names],
                resource_class=o.placement,
                batching=o.batching,
                wait_any=o.wait_any,
                jitted=isinstance(o.op, JittedFuse),
                batched_fn=fn if batched else None,
                batch_buckets=tuple(o.batch_buckets),
                device_resident=dev,
                emits_device=emits,
                locality_ref_column=o.locality_ref_column,
                locality_const=o.locality_const,
                plan_op_id=o.op_id,
            )
            out_name = nm
        # annotate competitive groups: the inputs of a wait-any consumer
        # with >=2 deps are racing replicas of the same computation
        for nm, node in nodes.items():
            if node.wait_any and len(node.deps) >= 2:
                for d in node.deps:
                    nodes[d].competitive_group = nm
        dag = cls(dag_name, nodes, names.get(plan.output_id, out_name))
        dag.validate()
        return dag

    def topo(self) -> List[RuntimeNode]:
        order, seen = [], set()

        def visit(n: str):
            if n in seen:
                return
            seen.add(n)
            for d in self.nodes[n].deps:
                visit(d)
            order.append(self.nodes[n])

        visit(self.output)
        return order

    def validate(self):
        for n in self.nodes.values():
            for d in n.deps:
                if d not in self.nodes:
                    raise ValueError(f"{n.name} depends on unknown {d}")
        self.topo()
