"""Runtime DAG: what the compilation pipeline emits (Cloudburst-DAG
analogue).

Each node is a named function over Tables with scheduling annotations:
``resource_class`` (cpu/gpu executor pools), ``batching`` (batch-aware fn),
``wait_any`` (wait-for-any semantics for anyof), ``jitted`` (the node's fn
is a single XLA-compiled callable), and the locality refs — the
*to-be-continued* annotation for dynamic dispatch: the node's result carries
a resolved KVS ref and the scheduler places the continuation DAG on a
machine likely caching that ref (paper §4).

``RuntimeDag.from_plan`` is the lowering from the physical-plan IR: one
``RuntimeNode`` per ``PhysicalOp``, annotations copied verbatim.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

from repro.core.table import Table


@dataclasses.dataclass
class RuntimeNode:
    name: str
    fn: Callable[[List[Table], Any], Table]     # (tables, ctx) -> Table
    deps: List[str]
    resource_class: str = "cpu"
    batching: bool = False
    wait_any: bool = False
    jitted: bool = False
    # batched execution: (merged_table_list, ctx) -> Table, ONE vmapped XLA
    # dispatch per batch (set when the op lowered to a BatchedJittedFuse)
    batched_fn: Optional[Callable[[List[Table], Any], Table]] = None
    batch_buckets: tuple = ()
    # dynamic dispatch: column holding the resolved KVS ref (or a constant)
    locality_ref_column: Optional[str] = None
    locality_const: Optional[str] = None
    plan_op_id: Optional[int] = None            # provenance into the IR


@dataclasses.dataclass
class RuntimeDag:
    name: str
    nodes: Dict[str, RuntimeNode]
    output: str

    @classmethod
    def from_plan(cls, plan, dag_name: str) -> "RuntimeDag":
        """Lower a ``repro.core.ir.PhysicalPlan`` to a runtime DAG."""
        from repro.core.lowering import BatchedJittedFuse, JittedFuse

        def wrap(op):
            def fn(tables, ctx):
                return op.apply(tables, ctx)
            return fn

        def wrap_batched(op):
            def fn(tables, ctx):
                return op.apply_batched(tables, ctx)
            return fn

        nodes: Dict[str, RuntimeNode] = {}
        names: Dict[int, str] = {}
        out_name = None
        for o in plan.ops:
            nm = f"{dag_name}/{o.op_id}:{o.op.name}"[:120]
            names[o.op_id] = nm
            batched = isinstance(o.op, BatchedJittedFuse)
            nodes[nm] = RuntimeNode(
                name=nm, fn=wrap(o.op),
                deps=[names[i] for i in o.inputs if i in names],
                resource_class=o.placement,
                batching=o.batching,
                wait_any=o.wait_any,
                jitted=isinstance(o.op, JittedFuse),
                batched_fn=wrap_batched(o.op) if batched else None,
                batch_buckets=tuple(o.batch_buckets),
                locality_ref_column=o.locality_ref_column,
                locality_const=o.locality_const,
                plan_op_id=o.op_id,
            )
            out_name = nm
        dag = cls(dag_name, nodes, names.get(plan.output_id, out_name))
        dag.validate()
        return dag

    def topo(self) -> List[RuntimeNode]:
        order, seen = [], set()

        def visit(n: str):
            if n in seen:
                return
            seen.add(n)
            for d in self.nodes[n].deps:
                visit(d)
            order.append(self.nodes[n])

        visit(self.output)
        return order

    def validate(self):
        for n in self.nodes.values():
            for d in n.deps:
                if d not in self.nodes:
                    raise ValueError(f"{n.name} depends on unknown {d}")
        self.topo()
