from repro.runtime.runtime import Runtime  # noqa: F401
from repro.runtime.netmodel import NetModel, nbytes  # noqa: F401
from repro.runtime.kvs import KVS, CacheClient  # noqa: F401
from repro.runtime.autoscaler import Autoscaler, AutoscalerConfig  # noqa: F401
