"""Anna-like KVS + per-executor caches (paper §2.3).

``KVS`` is the durable store (network cost on every access).  Each executor
owns a ``CacheClient``: reads hit the local cache for free; misses fetch from
the KVS (paying the modeled transfer) and populate the cache with LRU
eviction.  The scheduler asks ``cached_where(key)`` for locality-aware
placement (paper §4: Data Locality via Dynamic Dispatch).
"""
from __future__ import annotations

import collections
import threading
from typing import Any, Dict, List, Optional, Set

from repro.runtime.netmodel import NetModel, nbytes


class KVS:
    #: retention bound for the applied-write journal (put_once keys)
    APPLIED_CAP = 65536

    def __init__(self, net: Optional[NetModel] = None):
        self.net = net or NetModel()
        self._data: Dict[str, Any] = {}
        self._lock = threading.Lock()
        # which executor caches (likely) hold each key — the scheduler's index
        self._cache_index: Dict[str, Set[str]] = collections.defaultdict(set)
        # idempotence journal: tokens of writes already applied, so an
        # at-least-once redispatch (crash requeue, straggler hedge)
        # re-running an operator cannot double-apply its writes
        self._applied: "collections.OrderedDict[Any, None]" = \
            collections.OrderedDict()
        self.stats = collections.Counter()

    def put(self, key: str, value: Any, *, charge: bool = True):
        if charge:
            self.net.charge(nbytes(value))
        with self._lock:
            self._data[key] = value
            self.stats["puts"] += 1

    def put_once(self, token: Any, key: str, value: Any, *,
                 charge: bool = True) -> bool:
        """Apply a write exactly once per ``token`` (the dispatch key of
        the executing work item + the KVS key).  Returns False — and
        applies nothing, charges nothing — when the token was already
        applied by another execution attempt of the same logical item."""
        with self._lock:
            if token in self._applied:
                self.stats["dedup_puts"] += 1
                return False
            self._applied[token] = None
            while len(self._applied) > self.APPLIED_CAP:
                self._applied.popitem(last=False)
        self.put(key, value, charge=charge)
        return True

    def get(self, key: str, *, charge: bool = True) -> Any:
        with self._lock:
            value = self._data[key]
            self.stats["gets"] += 1
        if charge:
            self.net.charge(nbytes(value))
        return value

    def contains(self, key: str) -> bool:
        with self._lock:
            return key in self._data

    # -- locality index ------------------------------------------------------
    def note_cached(self, key: str, executor_id: str):
        with self._lock:
            self._cache_index[key].add(executor_id)

    def note_evicted(self, key: str, executor_id: str):
        with self._lock:
            self._cache_index[key].discard(executor_id)

    def cached_where(self, key: str) -> Set[str]:
        with self._lock:
            return set(self._cache_index.get(key, ()))


class CacheClient:
    """Executor-local cache over the KVS (LRU by bytes)."""

    def __init__(self, kvs: KVS, executor_id: str,
                 capacity_bytes: int = 2 << 30):
        self.kvs = kvs
        self.executor_id = executor_id
        self.capacity = capacity_bytes
        self._cache: "collections.OrderedDict[str, Any]" = \
            collections.OrderedDict()
        self._bytes = 0
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: str) -> Any:
        with self._lock:
            if key in self._cache:
                self._cache.move_to_end(key)
                self.hits += 1
                return self._cache[key]
        value = self.kvs.get(key)          # modeled network cost
        self.misses += 1
        self._insert(key, value)
        return value

    def put(self, key: str, value: Any):
        """Write-through."""
        self.kvs.put(key, value)
        self._insert(key, value)

    def observe(self, key: str, value: Any):
        """Populate the local cache WITHOUT writing through — used after
        an idempotent ``KVS.put_once`` already applied (or deduplicated)
        the durable write, so this executor still serves reads locally."""
        self._insert(key, value)

    def _insert(self, key: str, value: Any):
        size = nbytes(value)
        with self._lock:
            if key in self._cache:
                self._bytes -= nbytes(self._cache[key])
            self._cache[key] = value
            self._cache.move_to_end(key)
            self._bytes += size
            while self._bytes > self.capacity and len(self._cache) > 1:
                k, v = self._cache.popitem(last=False)
                self._bytes -= nbytes(v)
                self.kvs.note_evicted(k, self.executor_id)
        self.kvs.note_cached(key, self.executor_id)

    def holds(self, key: str) -> bool:
        with self._lock:
            return key in self._cache
