"""Network cost model for the single-host runtime simulation.

The container is one machine, so inter-executor and executor<->KVS transfers
are *modeled*: each transfer sleeps latency + nbytes/bandwidth.  Benchmarks
state this explicitly (DESIGN.md §2).  ``scale=0`` disables all simulated
delays (unit tests).
"""
from __future__ import annotations

import dataclasses
import sys
import time
from typing import Any

import numpy as np


@dataclasses.dataclass
class NetModel:
    latency_s: float = 0.5e-3          # per-hop latency (same-AZ RPC)
    bandwidth: float = 1.0e9           # bytes/s (8 Gbit NIC-ish)
    invoke_overhead_s: float = 1.0e-3  # per function invocation (FaaS RPC)
    scale: float = 1.0                 # 0 disables simulation

    def transfer_time(self, nbytes: int) -> float:
        return self.scale * (self.latency_s + nbytes / self.bandwidth)

    def charge(self, nbytes: int) -> float:
        t = self.transfer_time(nbytes)
        if t > 0:
            time.sleep(t)
        return t

    def charge_invoke(self) -> float:
        t = self.scale * self.invoke_overhead_s
        if t > 0:
            time.sleep(t)
        return t


def nbytes(obj: Any) -> int:
    """Estimate payload size of an intermediate result."""
    if obj is None:
        return 0
    if isinstance(obj, np.ndarray):
        return obj.nbytes
    if isinstance(obj, (bytes, bytearray)):
        return len(obj)
    if isinstance(obj, str):
        return len(obj.encode())
    if isinstance(obj, (int, float, bool)):
        return 8
    if isinstance(obj, dict):
        return sum(nbytes(k) + nbytes(v) for k, v in obj.items())
    if isinstance(obj, (list, tuple, set)):
        return sum(nbytes(v) for v in obj)
    if hasattr(obj, "rows") and hasattr(obj, "schema"):   # Table
        return sum(nbytes(r.values) for r in obj.rows) + 64
    if hasattr(obj, "nbytes"):
        return int(obj.nbytes)
    return sys.getsizeof(obj)
