"""Decoder-only transformer covering the dense / moe / vlm families.

Layers are grouped into the smallest repeating *block* (DESIGN.md §4) so the
whole stack is a single ``lax.scan`` over stacked block params:

* dense (yi, glm4, granite):        block = [attn+mlp]            x L
* gemma2:                           block = [local, global]       x L/2
* arctic:                           block = [attn+moe(+dense res)] x L
* llama4-maverick:                  block = [attn+mlp, attn+moe]  x L/2
* llama-3.2-vision:                 block = [plain x4, cross+plain] x L/5

KV caches are stacked per block-layer and threaded through the scan as
``xs``/``ys``; decode writes ring-buffer slots for sliding-window layers.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers, moe as moe_lib
from repro.models.partition import AxisInfo, shard, mp_size, dp_axes, mp_axis


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    window: int = 0            # 0 = full attention
    is_moe: bool = False
    has_cross: bool = False    # gated cross-attention (vlm)
    aux_mlp: bool = False      # dense residual (arctic) / shared expert


def block_layout(cfg: ModelConfig, *, long_context: bool = False
                 ) -> Tuple[List[LayerSpec], int]:
    """Return (specs for one block, n_blocks)."""
    L = cfg.num_layers
    if cfg.family == "vlm" and cfg.cross_attn_period:
        p = cfg.cross_attn_period
        assert L % p == 0
        specs = [LayerSpec() for _ in range(p - 1)] + [LayerSpec(has_cross=True)]
        return specs, L // p
    if cfg.local_global_pattern:  # gemma2: [local, global] pairs
        p = cfg.local_global_pattern
        assert L % p == 0
        w_global = cfg.sliding_window if (
            long_context and cfg.long_context_windowed) else 0
        specs = [LayerSpec(window=cfg.sliding_window)
                 for _ in range(p - 1)] + [LayerSpec(window=w_global)]
        return specs, L // p
    if cfg.num_experts and cfg.moe_layer_period > 1:  # llama4
        p = cfg.moe_layer_period
        assert L % p == 0
        specs = [LayerSpec() for _ in range(p - 1)] + [
            LayerSpec(is_moe=True, aux_mlp=cfg.shared_expert)]
        return specs, L // p
    if cfg.num_experts:  # arctic
        return [LayerSpec(is_moe=True, aux_mlp=cfg.dense_residual)], L
    return [LayerSpec()], L


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _attn_init(key, cfg: ModelConfig, n: int, mp: int, dtype):
    D, hd = cfg.d_model, cfg.head_dim
    Hp = cfg.padded_heads(mp)
    Kp = cfg.replicated_kv_heads(mp)
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": layers.dense_init(kq, (n, D, Hp * hd), dtype, fan_in=D),
        "wk": layers.dense_init(kk, (n, D, Kp * hd), dtype, fan_in=D),
        "wv": layers.dense_init(kv, (n, D, Kp * hd), dtype, fan_in=D),
        "wo": layers.dense_init(ko, (n, Hp * hd, D), dtype, fan_in=Hp * hd),
    }


def _norm_init(key, cfg: ModelConfig, n: int, dtype):
    p = layers.init_norm(key, cfg.d_model, cfg.norm, dtype)
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), p)


def init_params(key, cfg: ModelConfig, ax: Optional[AxisInfo],
                *, long_context: bool = False) -> Dict[str, Any]:
    mp = mp_size(ax)
    dtype = jnp.dtype(cfg.dtype)
    specs, n_blocks = block_layout(cfg, long_context=long_context)
    keys = jax.random.split(key, len(specs) + 2)
    params: Dict[str, Any] = {
        "embed": layers.embed_init(keys[0], cfg.padded_vocab, cfg.d_model,
                                   dtype),
        "final_norm": layers.init_norm(keys[1], cfg.d_model, cfg.norm, dtype),
        "blocks": {},
    }
    for i, spec in enumerate(specs):
        lk = jax.random.split(keys[2 + i], 8)
        lp: Dict[str, Any] = {
            "ln1": _norm_init(lk[0], cfg, n_blocks, dtype),
            "attn": _attn_init(lk[1], cfg, n_blocks, mp, dtype),
            "ln2": _norm_init(lk[2], cfg, n_blocks, dtype),
        }
        if cfg.post_norms:
            lp["post_ln1"] = _norm_init(lk[3], cfg, n_blocks, dtype)
            lp["post_ln2"] = _norm_init(lk[4], cfg, n_blocks, dtype)
        if spec.is_moe:
            lp["moe"] = moe_lib.moe_init(lk[5], cfg, dtype, n_blocks)
            if cfg.expert_quant:
                lp["moe"] = moe_lib.quantize_expert_weights(lp["moe"])
            if spec.aux_mlp:
                lp["aux_mlp"] = jax.tree.map(
                    lambda a: a,
                    _stacked_mlp_init(lk[6], cfg, n_blocks, dtype))
        else:
            lp["mlp"] = _stacked_mlp_init(lk[6], cfg, n_blocks, dtype)
        if spec.has_cross:
            ck = jax.random.split(lk[7], 6)
            D, hd = cfg.d_model, cfg.head_dim
            Hp, Kp = cfg.padded_heads(mp), cfg.replicated_kv_heads(mp)
            lp["cross"] = {
                "ln": _norm_init(ck[0], cfg, n_blocks, dtype),
                "wq": layers.dense_init(ck[1], (n_blocks, D, Hp * hd), dtype,
                                        fan_in=D),
                "wk": layers.dense_init(ck[2], (n_blocks, D, Kp * hd), dtype,
                                        fan_in=D),
                "wv": layers.dense_init(ck[3], (n_blocks, D, Kp * hd), dtype,
                                        fan_in=D),
                "wo": layers.dense_init(ck[4], (n_blocks, Hp * hd, D), dtype,
                                        fan_in=Hp * hd),
                "gate": jnp.zeros((n_blocks,), jnp.float32),
            }
        params["blocks"][str(i)] = lp
    return params


def _stacked_mlp_init(key, cfg: ModelConfig, n: int, dtype):
    D, F = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_up": layers.dense_init(k1, (n, D, F), dtype, fan_in=D),
         "w_down": layers.dense_init(k2, (n, F, D), dtype, fan_in=F)}
    if cfg.gated_mlp:
        p["w_gate"] = layers.dense_init(k3, (n, D, F), dtype, fan_in=D)
    return p


# ---------------------------------------------------------------------------
# forward pieces
# ---------------------------------------------------------------------------
def _attn_scale(cfg: ModelConfig) -> float:
    return 1.0 / math.sqrt(cfg.head_dim)


def _project_qkv(x, ap, cfg: ModelConfig, mp: int):
    B, S, D = x.shape
    hd = cfg.head_dim
    Hp, Kp = cfg.padded_heads(mp), cfg.replicated_kv_heads(mp)
    q = (x @ ap["wq"]).reshape(B, S, Hp, hd)
    k = (x @ ap["wk"]).reshape(B, S, Kp, hd)
    v = (x @ ap["wv"]).reshape(B, S, Kp, hd)
    return q, k, v


def _self_attention_full(x, ap, cfg: ModelConfig, ax, spec: LayerSpec,
                         positions, chunk: int = 1024):
    """Full-sequence (train / prefill) self attention.  Returns (out, k, v)."""
    mp = mp_size(ax)
    q, k, v = _project_qkv(x, ap, cfg, mp)
    q = shard(ax, q, dp_axes(ax), None, mp_axis(ax), None)
    k = shard(ax, k, dp_axes(ax), None, mp_axis(ax), None)
    v = shard(ax, v, dp_axes(ax), None, mp_axis(ax), None)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        out = kops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True, window=spec.window,
            softcap=cfg.attn_logit_softcap, scale=_attn_scale(cfg),
            block_q=min(128, q.shape[1]), block_k=min(128, q.shape[1]),
        ).transpose(0, 2, 1, 3)
    elif cfg.causal_skip and spec.window == 0 and x.shape[1] % min(
            chunk, x.shape[1]) == 0:
        out = layers.chunked_attention_causal_skip(
            q, k, v, q_positions=positions, k_positions=positions,
            softcap=cfg.attn_logit_softcap, chunk=chunk,
            scale=_attn_scale(cfg))
    else:
        out = layers.chunked_attention(
            q, k, v, q_positions=positions, k_positions=positions,
            causal=True, window=spec.window,
            softcap=cfg.attn_logit_softcap,
            chunk_q=chunk, chunk_k=chunk, scale=_attn_scale(cfg))
    out = out.reshape(x.shape[0], x.shape[1], -1) @ ap["wo"]
    return out, k, v


def _self_attention_decode(x, ap, cfg: ModelConfig, ax, spec: LayerSpec,
                           pos, kc, vc, pc, scales=None):
    """One-token decode.  x: [B,1,D]; kc/vc: [B,W,Kp,hd] (int8 when
    cfg.kv_quant, with ``scales``=(ks, vs) f32 [B,W,Kp]); pc: [B,W] slot
    positions (−1=empty).  pos: [B].  Returns (out, kc, vc, pc, scales)."""
    mp = mp_size(ax)
    q, k, v = _project_qkv(x, ap, cfg, mp)
    q = layers.apply_rope(q, pos[:, None], cfg.rope_theta)
    k = layers.apply_rope(k, pos[:, None], cfg.rope_theta)
    W = kc.shape[1]
    slot = (pos % W)                                              # [B]
    b_idx = jnp.arange(x.shape[0])
    if cfg.kv_quant:
        ks, vs = scales
        kq, ksc = layers.kv_quantize(k[:, 0])
        vq, vsc = layers.kv_quantize(v[:, 0])
        kc = kc.at[b_idx, slot].set(kq)
        vc = vc.at[b_idx, slot].set(vq)
        ks = ks.at[b_idx, slot].set(ksc)
        vs = vs.at[b_idx, slot].set(vsc)
        scales = (ks, vs)
        k_read = layers.kv_dequantize(kc, ks, k.dtype)
        v_read = layers.kv_dequantize(vc, vs, v.dtype)
    else:
        kc = kc.at[b_idx, slot].set(k[:, 0])
        vc = vc.at[b_idx, slot].set(v[:, 0])
        k_read, v_read = kc, vc
    pc = pc.at[b_idx, slot].set(pos)
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        out = kops.decode_attention(
            q[:, 0], k_read.transpose(0, 2, 1, 3),
            v_read.transpose(0, 2, 1, 3), pc, pos,
            window=spec.window, softcap=cfg.attn_logit_softcap,
            scale=_attn_scale(cfg),
            block_s=min(512, k_read.shape[1]))[:, None]
    else:
        out = layers.decode_attention(
            q, k_read, v_read, q_position=pos, k_positions=pc,
            window=spec.window, softcap=cfg.attn_logit_softcap,
            scale=_attn_scale(cfg))
    out = out.reshape(x.shape[0], 1, -1) @ ap["wo"]
    return out, kc, vc, pc, scales


def _cross_attention(x, cp, cfg: ModelConfig, ax, media_kv):
    """Gated cross attention.  media_kv = (k [B,M,Kp,hd], v [B,M,Kp,hd])."""
    B, S, D = x.shape
    mp = mp_size(ax)
    hd = cfg.head_dim
    Hp = cfg.padded_heads(mp)
    xq = layers.apply_norm(x, cp["ln"], cfg.norm)
    q = (xq @ cp["wq"]).reshape(B, S, Hp, hd)
    mk, mv = media_kv
    M = mk.shape[1]
    mpos = jnp.arange(M, dtype=jnp.int32)
    out = layers.chunked_attention(
        q, mk, mv, q_positions=jnp.zeros((S,), jnp.int32),
        k_positions=mpos, causal=False, window=0, softcap=0.0,
        chunk_q=min(1024, S), chunk_k=M, scale=_attn_scale(cfg))
    out = out.reshape(B, S, -1) @ cp["wo"]
    return jnp.tanh(cp["gate"]).astype(x.dtype) * out


def media_kv_from_embeddings(media, cp, cfg: ModelConfig, mp: int):
    """Project stub media embeddings [B,M,D] to cross-attn K/V."""
    B, M, D = media.shape
    hd = cfg.head_dim
    Kp = cfg.replicated_kv_heads(mp)
    mk = (media @ cp["wk"]).reshape(B, M, Kp, hd)
    mv = (media @ cp["wv"]).reshape(B, M, Kp, hd)
    return mk, mv


def _layer_ffn(x, lp, spec: LayerSpec, cfg: ModelConfig, ax,
               seq_sharded: bool, moe_dispatch: str):
    """FFN part (mlp or moe + aux). Returns (y, aux_loss)."""
    if spec.is_moe:
        y, aux = moe_lib.moe_apply(x, lp["moe"], cfg, ax,
                                   seq_sharded=seq_sharded,
                                   dispatch=moe_dispatch)
        if spec.aux_mlp:
            y = y + layers.mlp_apply(x, lp["aux_mlp"], gated=cfg.gated_mlp,
                                     act=cfg.act)
        return y, aux
    return layers.mlp_apply(x, lp["mlp"], gated=cfg.gated_mlp,
                            act=cfg.act), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------
def forward(params, tokens, cfg: ModelConfig, ax: Optional[AxisInfo], *,
            media=None, build_cache: bool = False,
            cache_len: Optional[int] = None, long_context: bool = False,
            remat: bool = True, moe_dispatch: str = "all_to_all",
            chunk: int = 1024):
    """tokens: [B, S] -> logits [B, S, V].  If ``build_cache`` also returns
    the decode cache (prefill)."""
    specs, n_blocks = block_layout(cfg, long_context=long_context)
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    x = layers.embed_lookup(params["embed"], tokens,
                            scale_by_dim=cfg.embedding_scale)
    seq_ax = mp_axis(ax) if cfg.seq_shard else None
    x = shard(ax, x, dp_axes(ax), seq_ax, None)
    media_kvs = None
    if media is not None:
        media = shard(ax, media, dp_axes(ax), None, None)

    def _barrier(t):
        # §Perf B: pin the bf16 value at the seq-parallel reshard boundary so
        # XLA cannot hoist the norm's f32 upcast above the all-gather
        # (observed: f32 collectives = 2x bytes without this).
        return jax.lax.optimization_barrier(t) if cfg.bf16_boundary else t

    def block_fn(x, blk_params):
        aux_total = jnp.zeros((), jnp.float32)
        cache_out = {}
        x = shard(ax, x, dp_axes(ax), seq_ax, None)
        for i, spec in enumerate(specs):
            lp = blk_params[str(i)]
            h = _barrier(layers.apply_norm(x, lp["ln1"], cfg.norm))
            attn_out, k, v = _self_attention_full(
                h, lp["attn"], cfg, ax, spec, positions, chunk=chunk)
            if cfg.post_norms:
                attn_out = layers.apply_norm(attn_out, lp["post_ln1"],
                                             cfg.norm)
            if cfg.rs_outputs:
                attn_out = shard(ax, attn_out, dp_axes(ax), seq_ax, None)
            x = x + attn_out
            if spec.has_cross and media is not None:
                mkv = media_kv_from_embeddings(media, lp["cross"], cfg,
                                               mp_size(ax))
                x = x + _cross_attention(x, lp["cross"], cfg, ax, mkv)
                if build_cache:
                    cache_out[f"ck{i}"], cache_out[f"cv{i}"] = mkv
            h = _barrier(layers.apply_norm(x, lp["ln2"], cfg.norm))
            ffn_out, aux = _layer_ffn(h, lp, spec, cfg, ax,
                                      seq_sharded=(ax is not None
                                                   and cfg.seq_shard),
                                      moe_dispatch=moe_dispatch)
            if cfg.post_norms:
                ffn_out = layers.apply_norm(ffn_out, lp["post_ln2"], cfg.norm)
            if cfg.rs_outputs:
                ffn_out = shard(ax, ffn_out, dp_axes(ax), seq_ax, None)
            x = x + ffn_out
            aux_total = aux_total + aux
            if build_cache:
                W = spec.window if spec.window else (cache_len or S)
                W = min(W, cache_len or S)
                if S >= W:
                    ks = jax.lax.dynamic_slice_in_dim(k, S - W, W, axis=1)
                    vs = jax.lax.dynamic_slice_in_dim(v, S - W, W, axis=1)
                    ps = jnp.broadcast_to(positions[S - W:], (B, W))
                else:
                    pad = W - S
                    ks = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    vs = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    ps = jnp.broadcast_to(
                        jnp.concatenate([positions,
                                         jnp.full((pad,), -1, jnp.int32)]),
                        (B, W))
                if cfg.kv_quant:
                    kq, ksc = layers.kv_quantize(ks)
                    vq, vsc = layers.kv_quantize(vs)
                    cache_out[f"k{i}"], cache_out[f"ks{i}"] = kq, ksc
                    cache_out[f"v{i}"], cache_out[f"vs{i}"] = vq, vsc
                else:
                    cache_out[f"k{i}"] = ks
                    cache_out[f"v{i}"] = vs
                cache_out[f"pos{i}"] = ps.astype(jnp.int32)
        return x, (cache_out, aux_total)

    body = block_fn
    if remat:
        body = jax.checkpoint(
            block_fn, policy=_remat_policy(cfg.remat_policy))
    x, (caches, auxes) = jax.lax.scan(
        lambda c, bp: body(c, bp), x, params["blocks"])
    x = layers.apply_norm(x, params["final_norm"], cfg.norm)
    logits = layers.unembed(x, params["embed"],
                            softcap=cfg.final_logit_softcap)
    logits = shard(ax, logits, dp_axes(ax), seq_ax, None)
    aux = jnp.sum(auxes)
    if build_cache:
        return logits, caches, aux
    return logits, aux


def _remat_policy(name: str):
    cp = jax.checkpoint_policies
    if name == "dots":
        return cp.checkpoint_dots_with_no_batch_dims
    if name == "everything":
        return cp.everything_saveable
    return cp.nothing_saveable


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, ax: Optional[AxisInfo], batch: int,
               cache_len: int, *, long_context: bool = False,
               media_tokens: int = 0):
    """Zero-filled decode cache (stacked over blocks)."""
    specs, n_blocks = block_layout(cfg, long_context=long_context)
    mp = mp_size(ax)
    Kp = cfg.replicated_kv_heads(mp)
    hd = cfg.head_dim
    dtype = jnp.dtype(cfg.dtype)
    cache = {}
    kv_dtype = jnp.int8 if cfg.kv_quant else dtype
    for i, spec in enumerate(specs):
        W = min(spec.window, cache_len) if spec.window else cache_len
        cache[f"k{i}"] = jnp.zeros((n_blocks, batch, W, Kp, hd), kv_dtype)
        cache[f"v{i}"] = jnp.zeros((n_blocks, batch, W, Kp, hd), kv_dtype)
        cache[f"pos{i}"] = jnp.full((n_blocks, batch, W), -1, jnp.int32)
        if cfg.kv_quant:
            cache[f"ks{i}"] = jnp.ones((n_blocks, batch, W, Kp), jnp.float32)
            cache[f"vs{i}"] = jnp.ones((n_blocks, batch, W, Kp), jnp.float32)
        if spec.has_cross:
            M = media_tokens or cfg.num_media_tokens
            cache[f"ck{i}"] = jnp.zeros((n_blocks, batch, M, Kp, hd), dtype)
            cache[f"cv{i}"] = jnp.zeros((n_blocks, batch, M, Kp, hd), dtype)
    return cache


def cache_pspecs(cfg: ModelConfig, ax: AxisInfo, *, long_context: bool = False):
    """PartitionSpecs matching init_cache: batch->data, kv-heads->model."""
    from jax.sharding import PartitionSpec as P
    specs, _ = block_layout(cfg, long_context=long_context)
    out = {}
    dp, mp = ax.batch, ax.model
    for i, spec in enumerate(specs):
        out[f"k{i}"] = P(None, dp, None, mp, None)
        out[f"v{i}"] = P(None, dp, None, mp, None)
        out[f"pos{i}"] = P(None, dp, None)
        if cfg.kv_quant:
            out[f"ks{i}"] = P(None, dp, None, mp)
            out[f"vs{i}"] = P(None, dp, None, mp)
        if spec.has_cross:
            out[f"ck{i}"] = P(None, dp, None, mp, None)
            out[f"cv{i}"] = P(None, dp, None, mp, None)
    return out


def decode_step(params, tokens, pos, cache, cfg: ModelConfig,
                ax: Optional[AxisInfo], *, long_context: bool = False,
                moe_dispatch: str = "all_to_all"):
    """tokens: [B, 1]; pos: [B] absolute position of the new token.
    Returns (logits [B, 1, V], new_cache)."""
    specs, n_blocks = block_layout(cfg, long_context=long_context)
    x = layers.embed_lookup(params["embed"], tokens,
                            scale_by_dim=cfg.embedding_scale)
    x = shard(ax, x, dp_axes(ax), None, None)

    def block_fn(carry, blk_params):
        x, cache, bi = carry
        blk_cache = jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, bi, axis=0,
                                                   keepdims=False), cache)
        new_cache = dict(blk_cache)
        x = shard(ax, x, dp_axes(ax), None, None)
        for i, spec in enumerate(specs):
            lp = blk_params[str(i)]
            h = layers.apply_norm(x, lp["ln1"], cfg.norm)
            scales = ((blk_cache[f"ks{i}"], blk_cache[f"vs{i}"])
                      if cfg.kv_quant else None)
            attn_out, kc, vc, pc, scales = _self_attention_decode(
                h, lp["attn"], cfg, ax, spec, pos,
                blk_cache[f"k{i}"], blk_cache[f"v{i}"], blk_cache[f"pos{i}"],
                scales)
            new_cache[f"k{i}"], new_cache[f"v{i}"] = kc, vc
            new_cache[f"pos{i}"] = pc
            if cfg.kv_quant:
                new_cache[f"ks{i}"], new_cache[f"vs{i}"] = scales
            if cfg.post_norms:
                attn_out = layers.apply_norm(attn_out, lp["post_ln1"],
                                             cfg.norm)
            x = x + attn_out
            if spec.has_cross:
                mkv = (blk_cache[f"ck{i}"], blk_cache[f"cv{i}"])
                x = x + _cross_attention(x, lp["cross"], cfg, ax, mkv)
            h = layers.apply_norm(x, lp["ln2"], cfg.norm)
            ffn_out, _ = _layer_ffn(h, lp, spec, cfg, ax, seq_sharded=False,
                                    moe_dispatch=moe_dispatch)
            if cfg.post_norms:
                ffn_out = layers.apply_norm(ffn_out, lp["post_ln2"], cfg.norm)
            x = x + ffn_out
        cache = jax.tree.map(
            lambda c, nc: jax.lax.dynamic_update_index_in_dim(
                c, nc.astype(c.dtype), bi, axis=0), cache, new_cache)
        return (x, cache, bi + 1), None

    (x, new_cache, _), _ = jax.lax.scan(
        block_fn, (x, cache, jnp.zeros((), jnp.int32)), params["blocks"])
    x = layers.apply_norm(x, params["final_norm"], cfg.norm)
    logits = layers.unembed(x, params["embed"],
                            softcap=cfg.final_logit_softcap)
    return logits, new_cache
