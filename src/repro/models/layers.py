"""Shared neural-net layers for the model zoo (pure-jnp, functional).

Everything here is mesh-agnostic; sharding constraints are applied by the
model wrappers via ``repro.models.partition``.  Attention is implemented in a
KV-chunked online-softmax form (``chunked_attention``) so 32k-token prefill
lowers without materializing O(S^2) score tensors; the Pallas flash kernel in
``repro.kernels`` is a drop-in replacement validated against this code.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm(x, scale, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layernorm(x, scale, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dtype)


def apply_norm(x, params, kind: str):
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"])
    return layernorm(x, params["scale"], params["bias"])


def init_norm(key, d: int, kind: str, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def groupnorm_heads(x, scale, bias, num_heads: int, eps: float = 64e-5):
    """GroupNorm over per-head channels (RWKV6 time-mix output norm)."""
    b, t, d = x.shape
    xs = x.astype(jnp.float32).reshape(b, t, num_heads, d // num_heads)
    mu = jnp.mean(xs, axis=-1, keepdims=True)
    var = jnp.var(xs, axis=-1, keepdims=True)
    xs = (xs - mu) * jax.lax.rsqrt(var + eps)
    xs = xs.reshape(b, t, d)
    return (xs * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float):
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # [hd/2]


def apply_rope(x, positions, theta: float):
    """x: [..., S, n_heads, head_dim]; positions: [S] or [B, S] int32."""
    if theta <= 0.0:
        return x
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)
    angles = positions.astype(jnp.float32)[..., None] * freqs   # [(B,)S,hd/2]
    # broadcast over the heads axis
    angles = jnp.expand_dims(angles, axis=-2)                   # [(B,)S,1,hd/2]
    if angles.ndim == x.ndim - 1:                               # positions [S]
        angles = jnp.broadcast_to(angles, x.shape[:-1] + (hd // 2,))
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (chunked online-softmax; GQA; sliding window; logit softcap)
# ---------------------------------------------------------------------------
NEG_INF = -1e30


def _softcap(logits, cap: float):
    if cap and cap > 0.0:
        return cap * jnp.tanh(logits / cap)
    return logits


def _mask_logits(logits, q_pos, k_pos, *, causal: bool, window: int):
    """logits: [..., Q, Kc]; q_pos: [..., Q]; k_pos: [..., Kc] (−1 = invalid)."""
    valid = (k_pos >= 0)[..., None, :]
    if causal:
        valid = valid & (k_pos[..., None, :] <= q_pos[..., :, None])
    if window and window > 0:
        valid = valid & (q_pos[..., :, None] - k_pos[..., None, :] < window)
    return jnp.where(valid, logits, NEG_INF)


def chunked_attention(q, k, v, *, q_positions, k_positions,
                      causal: bool = True, window: int = 0,
                      softcap: float = 0.0, chunk_q: int = 1024,
                      chunk_k: int = 1024, scale: Optional[float] = None):
    """Flash-style attention without O(Sq*Sk) live memory.

    q: [B, Sq, H, hd];  k, v: [B, Sk, K, hd] with H = K*G (GQA).
    q_positions: [Sq] or [B, Sq]; k_positions: [Sk] or [B, Sk] (−1 invalid).
    Returns [B, Sq, H, hd].
    """
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    cq = min(chunk_q, Sq)
    ck = min(chunk_k, Sk)
    assert Sq % cq == 0 and Sk % ck == 0, (Sq, cq, Sk, ck)
    nq, nk = Sq // cq, Sk // ck

    if q_positions.ndim == 1:
        q_positions = jnp.broadcast_to(q_positions[None], (B, Sq))
    if k_positions.ndim == 1:
        k_positions = jnp.broadcast_to(k_positions[None], (B, Sk))

    qc = q.reshape(B, nq, cq, K, G, hd).transpose(1, 0, 3, 4, 2, 5)
    qp = q_positions.reshape(B, nq, cq).transpose(1, 0, 2)       # [nq,B,cq]
    kc = k.reshape(B, nk, ck, K, hd).transpose(1, 0, 3, 2, 4)    # [nk,B,K,ck,hd]
    vc = v.reshape(B, nk, ck, K, hd).transpose(1, 0, 3, 2, 4)
    kp = k_positions.reshape(B, nk, ck).transpose(1, 0, 2)       # [nk,B,ck]

    def q_step(_, qx):
        q_blk, qpos = qx                       # [B,K,G,cq,hd], [B,cq]
        m0 = jnp.full((B, K, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, cq), jnp.float32)
        a0 = jnp.zeros((B, K, G, cq, hd), jnp.float32)

        # flash-style backward: recompute per-chunk probabilities instead of
        # letting AD save the O(S^2) score chunks across both scans
        @jax.checkpoint
        def k_step(carry, kx):
            m, l, acc = carry
            k_blk, v_blk, kpos = kx            # [B,K,ck,hd] x2, [B,ck]
            logits = jnp.einsum("bkgqd,bkcd->bkgqc",
                                q_blk.astype(jnp.float32),
                                k_blk.astype(jnp.float32)) * scale
            logits = _softcap(logits, softcap)
            logits = _mask_logits(
                logits, qpos[:, None, None, :], kpos[:, None, None, :],
                causal=causal, window=window)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bkcd->bkgqd", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(k_step, (m0, l0, a0), (kc, vc, kp))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)       # [B,K,G,cq,hd]

    _, outs = jax.lax.scan(jax.checkpoint(q_step), None, (qc, qp))
    # outs: [nq, B, K, G, cq, hd] -> [B, Sq, H, hd]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, hd)
    return out


def chunked_attention_causal_skip(q, k, v, *, q_positions, k_positions,
                                  softcap: float = 0.0, chunk: int = 1024,
                                  scale: Optional[float] = None):
    """Causal chunked attention that only computes the lower-triangle chunk
    pairs (nq*(nq+1)/2 instead of nq*nk) — §Perf prefill lever.

    Equivalent to ``chunked_attention(causal=True, window=0)``; one scan over
    the static (qi, ki<=qi) pair list with running-softmax state carried per
    q-chunk.  Executed attention FLOPs halve at long S.
    """
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    assert Sq == Sk, "causal-skip path expects self-attention"
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    c = min(chunk, Sq)
    assert Sq % c == 0
    n = Sq // c
    if q_positions.ndim == 1:
        q_positions = jnp.broadcast_to(q_positions[None], (B, Sq))
    k_positions = q_positions if k_positions is None else (
        jnp.broadcast_to(k_positions[None], (B, Sk))
        if k_positions.ndim == 1 else k_positions)

    qc = q.reshape(B, n, c, K, G, hd).transpose(1, 0, 3, 4, 2, 5)
    kc = k.reshape(B, n, c, K, hd).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(B, n, c, K, hd).transpose(1, 0, 3, 2, 4)
    qp = q_positions.reshape(B, n, c).transpose(1, 0, 2)
    kp = k_positions.reshape(B, n, c).transpose(1, 0, 2)

    QI = jnp.asarray([qi for qi in range(n) for _ in range(qi + 1)],
                     jnp.int32)
    KI = jnp.asarray([ki for qi in range(n) for ki in range(qi + 1)],
                     jnp.int32)

    m0 = jnp.full((n, B, K, G, c), NEG_INF, jnp.float32)
    l0 = jnp.zeros((n, B, K, G, c), jnp.float32)
    a0 = jnp.zeros((n, B, K, G, c, hd), jnp.float32)

    def step(carry, idx):
        m, l, acc = carry
        qi, ki = idx
        q_blk = jax.lax.dynamic_index_in_dim(qc, qi, 0, keepdims=False)
        qpos = jax.lax.dynamic_index_in_dim(qp, qi, 0, keepdims=False)
        k_blk = jax.lax.dynamic_index_in_dim(kc, ki, 0, keepdims=False)
        v_blk = jax.lax.dynamic_index_in_dim(vc, ki, 0, keepdims=False)
        kpos = jax.lax.dynamic_index_in_dim(kp, ki, 0, keepdims=False)
        logits = jnp.einsum("bkgqd,bkcd->bkgqc",
                            q_blk.astype(jnp.float32),
                            k_blk.astype(jnp.float32)) * scale
        logits = _softcap(logits, softcap)
        logits = _mask_logits(
            logits, qpos[:, None, None, :], kpos[:, None, None, :],
            causal=True, window=0)
        mq = jax.lax.dynamic_index_in_dim(m, qi, 0, keepdims=False)
        lq = jax.lax.dynamic_index_in_dim(l, qi, 0, keepdims=False)
        aq = jax.lax.dynamic_index_in_dim(acc, qi, 0, keepdims=False)
        m_new = jnp.maximum(mq, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(mq - m_new)
        lq = lq * corr + jnp.sum(p, axis=-1)
        aq = aq * corr[..., None] + jnp.einsum(
            "bkgqc,bkcd->bkgqd", p, v_blk.astype(jnp.float32))
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, lq, qi, 0)
        acc = jax.lax.dynamic_update_index_in_dim(acc, aq, qi, 0)
        return (m, l, acc), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (QI, KI))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, hd).astype(
        q.dtype)


def decode_attention(q, k_cache, v_cache, *, q_position, k_positions,
                     window: int = 0, softcap: float = 0.0,
                     scale: Optional[float] = None):
    """Single-token attention against a (possibly ring-buffer) KV cache.

    q: [B, 1, H, hd]; k_cache/v_cache: [B, S, K, hd];
    q_position: [B] int32; k_positions: [B, S] int32 (−1 = empty slot).
    """
    B, _, H, hd = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qh = q.reshape(B, K, G, hd)
    logits = jnp.einsum("bkgd,bskd->bkgs", qh.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    logits = _softcap(logits, softcap)
    valid = (k_positions >= 0) & (k_positions <= q_position[:, None])
    if window and window > 0:
        valid = valid & (q_position[:, None] - k_positions < window)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# int8 KV-cache quantization (beyond-paper serving optimization, §Perf C)
# ---------------------------------------------------------------------------
def kv_quantize(x):
    """x: [..., hd] -> (int8 values, f32 scale [...]). Per-(slot, head)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1), 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def kv_dequantize(q, scale, dtype=jnp.bfloat16):
    """On TPU this fuses into the attention matmul inside the Pallas decode
    kernel; the pure-jnp path materializes (HBM traffic is still counted as
    int8 in the analytic roofline — the kernel is the deployment path)."""
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def _act(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    return jax.nn.gelu(x, approximate=True)


def mlp_apply(x, params, *, gated: bool, act: str):
    if gated:
        h = _act(x @ params["w_gate"], act) * (x @ params["w_up"])
    else:
        h = _act(x @ params["w_up"], act)
    return h @ params["w_down"]


def mlp_init(key, d: int, f: int, *, gated: bool, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_up": _dense_init(k1, d, f, dtype),
         "w_down": _dense_init(k2, f, d, dtype)}
    if gated:
        p["w_gate"] = _dense_init(k3, d, f, dtype)
    return p


def _dense_init(key, fan_in: int, fan_out: int, dtype, scale: float = 1.0):
    std = scale / math.sqrt(fan_in)
    return (jax.random.normal(key, (fan_in, fan_out), jnp.float32)
            * std).astype(dtype)


def dense_init(key, shape: Tuple[int, ...], dtype, fan_in: Optional[int] = None):
    fan_in = fan_in if fan_in is not None else shape[-2] if len(shape) > 1 else shape[0]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32)
            * (1.0 / math.sqrt(d))).astype(dtype)


def embed_lookup(table, tokens, *, scale_by_dim: bool = False):
    out = jnp.take(table, tokens, axis=0)
    if scale_by_dim:
        out = out * math.sqrt(table.shape[-1])
    return out


def unembed(x, table, *, softcap: float = 0.0):
    logits = jnp.einsum("...d,vd->...v", x, table).astype(jnp.float32)
    return _softcap(logits, softcap)
