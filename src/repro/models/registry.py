"""Uniform model facade over the zoo families.

``build_model(cfg, ax)`` returns a ``Model`` with:

* ``init(key)``                          -> params pytree
* ``logits(params, batch)``              -> [B, S, V] (train forward)
* ``loss(params, batch)``                -> (scalar, metrics)
* ``prefill(params, batch, cache_len)``  -> (logits, cache)
* ``decode_step(params, tokens, pos, cache, media?)`` -> (logits, cache)
* ``init_cache(batch, cache_len)``
* ``input_specs(shape)``                 -> ShapeDtypeStructs for the dry-run

``batch`` is a dict: {"tokens", "labels"?, "media"? (vlm stub patch
embeddings), "frames"? (audio stub frame embeddings)}.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models import transformer, rwkv6, rglru, whisper
from repro.models.partition import AxisInfo, shard, dp_axes, mp_axis

_FAMILY_MODULES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": rwkv6,
    "hybrid": rglru,
    "audio": whisper,
}


def cross_entropy(logits, labels, *, ignore_id: int = -1):
    """logits: [B, S, V] (f32); labels: [B, S] int32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    mask = (labels != ignore_id).astype(jnp.float32)
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    ax: Optional[AxisInfo] = None
    long_context: bool = False
    moe_dispatch: str = "all_to_all"

    @property
    def mod(self):
        return _FAMILY_MODULES[self.cfg.family]

    # -- params ------------------------------------------------------------
    def init(self, key):
        return self.mod.init_params(key, self.cfg, self.ax,
                                    long_context=self.long_context)

    # -- forward / loss ------------------------------------------------------
    def _fwd_kwargs(self, batch, remat):
        kw: Dict[str, Any] = {"remat": remat}
        if self.cfg.family in ("vlm", "moe"):
            kw["moe_dispatch"] = self.moe_dispatch
        if self.cfg.family == "vlm":
            kw["media"] = batch.get("media")
        if self.cfg.family == "audio":
            kw["frames"] = batch.get("frames")
        if self.cfg.family in ("dense", "moe", "vlm"):
            kw["long_context"] = self.long_context
        return kw

    def logits(self, params, batch, *, remat: bool = True):
        out, aux = self.mod.forward(params, batch["tokens"], self.cfg,
                                    self.ax, **self._fwd_kwargs(batch, remat))
        return out, aux

    def loss(self, params, batch, *, remat: bool = True):
        logits, aux = self.logits(params, batch, remat=remat)
        labels = batch.get("labels")
        if labels is None:
            labels = jnp.concatenate(
                [batch["tokens"][:, 1:],
                 jnp.full_like(batch["tokens"][:, :1], -1)], axis=1)
        ce = cross_entropy(logits, labels)
        total = ce + self.cfg.router_aux_loss_coef * aux
        return total, {"ce": ce, "aux": aux}

    # -- serving -------------------------------------------------------------
    def prefill(self, params, batch, cache_len: int):
        out = self.mod.forward(params, batch["tokens"], self.cfg, self.ax,
                               build_cache=True, cache_len=cache_len,
                               **self._fwd_kwargs(batch, remat=False))
        logits, cache, _aux = out
        return logits[:, -1:], cache

    def init_cache(self, batch: int, cache_len: int):
        return self.mod.init_cache(
            self.cfg, self.ax, batch, cache_len,
            long_context=self.long_context)

    def cache_pspecs(self):
        return self.mod.cache_pspecs(self.cfg, self.ax,
                                     long_context=self.long_context)

    def decode_step(self, params, tokens, pos, cache):
        kw = {}
        if self.cfg.family in ("moe",):
            kw["moe_dispatch"] = self.moe_dispatch
        if self.cfg.family in ("dense", "moe", "vlm"):
            kw["long_context"] = self.long_context
        return self.mod.decode_step(params, tokens, pos, cache, self.cfg,
                                    self.ax, **kw)

    # -- dry-run specs ---------------------------------------------------------
    def input_specs(self, shape: InputShape) -> Dict[str, Any]:
        """ShapeDtypeStructs for every model input of the given shape."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        dt = jnp.dtype(cfg.dtype)
        if shape.kind == "train":
            specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                     "labels": jax.ShapeDtypeStruct((B, S), i32)}
            if cfg.family == "vlm":
                specs["media"] = jax.ShapeDtypeStruct(
                    (B, cfg.num_media_tokens, cfg.d_model), dt)
            if cfg.family == "audio":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.encoder_seq, cfg.d_model), dt)
            return specs
        if shape.kind == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
            if cfg.family == "vlm":
                specs["media"] = jax.ShapeDtypeStruct(
                    (B, cfg.num_media_tokens, cfg.d_model), dt)
            if cfg.family == "audio":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.encoder_seq, cfg.d_model), dt)
            return specs
        # decode: one token + cache of length S
        cache = jax.eval_shape(lambda: self.init_cache(B, S))
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
                "pos": jax.ShapeDtypeStruct((B,), i32),
                "cache": cache}


def build_model(cfg: ModelConfig, ax: Optional[AxisInfo] = None, *,
                long_context: bool = False,
                moe_dispatch: str = "all_to_all") -> Model:
    if cfg.family not in _FAMILY_MODULES:
        raise ValueError(f"unknown family {cfg.family}")
    return Model(cfg=cfg, ax=ax, long_context=long_context,
                 moe_dispatch=moe_dispatch)
