"""Uniform model facade over the zoo families.

``build_model(cfg, ax)`` returns a ``Model`` with:

* ``init(key)``                          -> params pytree
* ``logits(params, batch)``              -> [B, S, V] (train forward)
* ``loss(params, batch)``                -> (scalar, metrics)
* ``prefill(params, batch, cache_len)``  -> (logits, cache)
* ``decode_step(params, tokens, pos, cache, media?)`` -> (logits, cache)
* ``init_cache(batch, cache_len)``
* ``input_specs(shape)``                 -> ShapeDtypeStructs for the dry-run

``batch`` is a dict: {"tokens", "labels"?, "media"? (vlm stub patch
embeddings), "frames"? (audio stub frame embeddings)}.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models import transformer, rwkv6, rglru, whisper
from repro.models.partition import AxisInfo, shard, dp_axes, mp_axis

_FAMILY_MODULES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": rwkv6,
    "hybrid": rglru,
    "audio": whisper,
}


def cross_entropy(logits, labels, *, ignore_id: int = -1):
    """logits: [B, S, V] (f32); labels: [B, S] int32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    mask = (labels != ignore_id).astype(jnp.float32)
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    ax: Optional[AxisInfo] = None
    long_context: bool = False
    moe_dispatch: str = "all_to_all"

    @property
    def mod(self):
        return _FAMILY_MODULES[self.cfg.family]

    # -- params ------------------------------------------------------------
    def init(self, key):
        return self.mod.init_params(key, self.cfg, self.ax,
                                    long_context=self.long_context)

    # -- forward / loss ------------------------------------------------------
    def _fwd_kwargs(self, batch, remat):
        kw: Dict[str, Any] = {"remat": remat}
        if self.cfg.family in ("vlm", "moe"):
            kw["moe_dispatch"] = self.moe_dispatch
        if self.cfg.family == "vlm":
            kw["media"] = batch.get("media")
        if self.cfg.family == "audio":
            kw["frames"] = batch.get("frames")
        if self.cfg.family in ("dense", "moe", "vlm"):
            kw["long_context"] = self.long_context
        return kw

    def logits(self, params, batch, *, remat: bool = True):
        out, aux = self.mod.forward(params, batch["tokens"], self.cfg,
                                    self.ax, **self._fwd_kwargs(batch, remat))
        return out, aux

    def loss(self, params, batch, *, remat: bool = True):
        logits, aux = self.logits(params, batch, remat=remat)
        labels = batch.get("labels")
        if labels is None:
            labels = jnp.concatenate(
                [batch["tokens"][:, 1:],
                 jnp.full_like(batch["tokens"][:, :1], -1)], axis=1)
        ce = cross_entropy(logits, labels)
        total = ce + self.cfg.router_aux_loss_coef * aux
        return total, {"ce": ce, "aux": aux}

    # -- serving -------------------------------------------------------------
    def prefill(self, params, batch, cache_len: int):
        out = self.mod.forward(params, batch["tokens"], self.cfg, self.ax,
                               build_cache=True, cache_len=cache_len,
                               **self._fwd_kwargs(batch, remat=False))
        logits, cache, _aux = out
        return logits[:, -1:], cache

    def init_cache(self, batch: int, cache_len: int):
        return self.mod.init_cache(
            self.cfg, self.ax, batch, cache_len,
            long_context=self.long_context)

    def cache_pspecs(self):
        return self.mod.cache_pspecs(self.cfg, self.ax,
                                     long_context=self.long_context)

    def decode_step(self, params, tokens, pos, cache):
        kw = {}
        if self.cfg.family in ("moe",):
            kw["moe_dispatch"] = self.moe_dispatch
        if self.cfg.family in ("dense", "moe", "vlm"):
            kw["long_context"] = self.long_context
        return self.mod.decode_step(params, tokens, pos, cache, self.cfg,
                                    self.ax, **kw)

    # -- dry-run specs ---------------------------------------------------------
    def input_specs(self, shape: InputShape) -> Dict[str, Any]:
        """ShapeDtypeStructs for every model input of the given shape."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        dt = jnp.dtype(cfg.dtype)
        if shape.kind == "train":
            specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                     "labels": jax.ShapeDtypeStruct((B, S), i32)}
            if cfg.family == "vlm":
                specs["media"] = jax.ShapeDtypeStruct(
                    (B, cfg.num_media_tokens, cfg.d_model), dt)
            if cfg.family == "audio":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.encoder_seq, cfg.d_model), dt)
            return specs
        if shape.kind == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
            if cfg.family == "vlm":
                specs["media"] = jax.ShapeDtypeStruct(
                    (B, cfg.num_media_tokens, cfg.d_model), dt)
            if cfg.family == "audio":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.encoder_seq, cfg.d_model), dt)
            return specs
        # decode: one token + cache of length S
        cache = jax.eval_shape(lambda: self.init_cache(B, S))
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
                "pos": jax.ShapeDtypeStruct((B,), i32),
                "cache": cache}


def build_model(cfg: ModelConfig, ax: Optional[AxisInfo] = None, *,
                long_context: bool = False,
                moe_dispatch: str = "all_to_all") -> Model:
    if cfg.family not in _FAMILY_MODULES:
        raise ValueError(f"unknown family {cfg.family}")
    return Model(cfg=cfg, ax=ax, long_context=long_context,
                 moe_dispatch=moe_dispatch)


# ---------------------------------------------------------------------------
# plan-operator glue: model stages as first-class dataflow ops (ModelOp)
# ---------------------------------------------------------------------------
#
# ``model_stage_op(model, params, stage)`` wraps one serving stage of a
# built model as a ``repro.core.operators.ModelOp`` — a map step with
# declared ``jax.Array`` annotations (so it typechecks, fuses, and lowers
# into Jitted/BatchedJittedFuse chains) and *native batch semantics*: the
# step is row-wise for the dataflow, but a ``custom_vmap`` rule maps the
# lowered chain's row axis straight onto the model's leading batch
# dimension, so a whole batch runs through the model in ONE dispatch.
#
# Row-wise column contracts (per table row):
#
# * ``logits``  — tokens [S] i32              -> next-token logits [V]
# * ``prefill`` — tokens [S] i32              -> (tok [] i32, pos [] i32,
#                                                 *cache leaves)
# * ``decode``  — (tok, pos, *cache leaves)   -> same shape: one greedy
#                                                 decode step advances them
#
# The KV cache rides the table as per-row columns (one per pytree leaf),
# so prefill -> decode -> decode chains fuse into a single device-resident
# chain with no host round-trip between steps.

def _stage_fn(fname: str, argnames, inner, ret_arity: int):
    """Explicit-positional-arg wrapper (``fn_signature`` reads
    ``__code__``) with jax.Array annotations, delegating to ``inner``."""
    fname = "".join(c if c.isalnum() or c == "_" else "_" for c in fname)
    if not fname or fname[0].isdigit():
        fname = f"m_{fname}"
    src = (f"def {fname}({', '.join(argnames)}):\n"
           f"    return _inner({', '.join(argnames)})")
    ns: Dict[str, Any] = {"_inner": inner}
    exec(src, ns)                                        # noqa: S102
    f = ns[fname]
    ann: Dict[str, Any] = {a: jax.Array for a in argnames}
    if ret_arity == 1:
        ann["return"] = jax.Array
    else:
        from typing import Tuple
        ann["return"] = Tuple[tuple([jax.Array] * ret_arity)]
    f.__annotations__ = ann
    return f


def _rowwise_native_batch(batched, multi: bool):
    """Row-wise view of a natively-batched stage fn: untransformed calls
    run the stage with B=1; under ``jax.vmap`` (a batched-lowered chain)
    the rule feeds the whole row batch to the stage in one call."""

    @jax.custom_batching.custom_vmap
    def per_row(*cols):
        out = batched(*[c[None] for c in cols])
        return tuple(o[0] for o in out) if multi else out[0]

    @per_row.def_vmap
    def _rule(axis_size, in_batched, *cols):
        cols = [c if b
                else jnp.broadcast_to(c[None], (axis_size,) + c.shape)
                for c, b in zip(cols, in_batched)]
        out = batched(*cols)
        return (out, tuple(True for _ in out)) if multi else (out, True)

    return per_row


def _timing_hook(batched, arg_maker, *, runs: int = 3, warmup: int = 1):
    """Per-bucket cost hook: measure the jitted natively-batched stage at
    batch size ``b``.  Feeds ``profiling.profiler.seed_from_model_ops`` ->
    ``OpLatencyCurve`` buckets."""
    import statistics
    import time

    jitted = jax.jit(batched)

    def hook(b: int) -> Dict[str, Any]:
        args = arg_maker(b)
        out = None
        for _ in range(warmup):
            out = jax.block_until_ready(jitted(*args))
        ts = []
        for _ in range(runs):
            t0 = time.perf_counter()
            out = jax.block_until_ready(jitted(*args))
            ts.append(time.perf_counter() - t0)
        mean = sum(ts) / len(ts)
        cv = (statistics.stdev(ts) / mean) if len(ts) > 1 and mean > 0 \
            else 0.0
        leaves = jax.tree_util.tree_leaves(out)
        ob = int(sum(x.size * x.dtype.itemsize for x in leaves))
        return {"mean_s": mean, "p99_s": max(ts), "cv": cv,
                "runs": len(ts), "out_bytes": ob}

    return hook


def model_stage_op(model: Model, params, stage: str, *,
                   model_name: str = "model", seq_len: int = 32,
                   cache_len: int = 64, measure: bool = True,
                   runs: int = 3):
    """Build a ``ModelOp`` for one serving stage of ``model`` (see module
    comment for the row-wise column contracts).  ``seq_len``/``cache_len``
    fix the token/cache geometry (the cost hook measures at exactly these
    shapes; the op itself serves any row shape the flow feeds it).
    ``measure=False`` skips attaching the timing cost hook."""
    from repro.core import operators as ops

    i32 = jnp.int32
    cache_shape = jax.eval_shape(lambda: model.init_cache(1, cache_len))
    leaves_shape, treedef = jax.tree_util.tree_flatten(cache_shape)
    n_leaves = len(leaves_shape)
    state_names = ["tok", "pos"] + [f"c{i}" for i in range(n_leaves)]

    # Cache leaves are NOT batch-leading in general (a lax.scan over layers
    # stacks the layer axis first), so find each leaf's batch axis by
    # diffing shapes at B=1 vs B=2 and normalize: as table columns, cache
    # leaves are always batch-leading; ``_join``/``_split`` transpose at
    # the model boundary.
    leaves_b2, _ = jax.tree_util.tree_flatten(
        jax.eval_shape(lambda: model.init_cache(2, cache_len)))
    batch_axes = []
    for a, b in zip(leaves_shape, leaves_b2):
        diff = [i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                if x != y]
        if len(diff) != 1:
            raise ValueError(
                f"cannot identify batch axis of cache leaf {a.shape} "
                f"vs {b.shape}")
        batch_axes.append(diff[0])

    def _split(cache):
        """native cache -> batch-leading leaf columns"""
        return [jnp.moveaxis(l, ax, 0) for l, ax in
                zip(jax.tree_util.tree_leaves(cache), batch_axes)]

    def _join(leaves):
        """batch-leading leaf columns -> native cache"""
        return jax.tree_util.tree_unflatten(
            treedef, [jnp.moveaxis(l, 0, ax)
                      for l, ax in zip(leaves, batch_axes)])

    if stage == "logits":
        def batched(tokens):
            out, _ = model.logits(params, {"tokens": tokens}, remat=False)
            return out[:, -1]

        fn = _stage_fn(f"{model_name}_logits", ("tokens",),
                       _rowwise_native_batch(batched, multi=False), 1)
        names = ["logits"]

        def arg_maker(b):
            return (jnp.zeros((b, seq_len), i32),)

    elif stage == "prefill":
        def batched(tokens):
            logits, cache = model.prefill(params, {"tokens": tokens},
                                          cache_len)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(i32)
            pos = jnp.full(tokens.shape[:1], tokens.shape[1], i32)
            return (tok, pos, *_split(cache))

        fn = _stage_fn(f"{model_name}_prefill", ("tokens",),
                       _rowwise_native_batch(batched, multi=True),
                       2 + n_leaves)
        names = list(state_names)

        def arg_maker(b):
            return (jnp.zeros((b, seq_len), i32),)

    elif stage == "decode":
        def batched(tok, pos, *leaves):
            cache = _join(leaves)
            logits, new_cache = model.decode_step(params, tok[:, None],
                                                  pos, cache)
            ntok = jnp.argmax(logits[:, -1], axis=-1).astype(i32)
            return (ntok, pos + 1, *_split(new_cache))

        fn = _stage_fn(f"{model_name}_decode", tuple(state_names),
                       _rowwise_native_batch(batched, multi=True),
                       2 + n_leaves)
        names = list(state_names)

        def arg_maker(b):
            cache = model.init_cache(b, cache_len)
            return (jnp.zeros((b,), i32), jnp.zeros((b,), i32),
                    *_split(cache))

    else:
        raise ValueError(f"unknown stage {stage!r} "
                         "(logits | prefill | decode)")

    hook = _timing_hook(batched, arg_maker, runs=runs) if measure else None
    return ops.ModelOp(fn=fn, names=names, model_name=model_name,
                       stage=stage, cost_hook=hook)


def stage_input_specs(model: Model, stage: str, *, seq_len: int = 32,
                      cache_len: int = 64) -> Dict[str, Any]:
    """Row-level input column specs for one serving stage — the
    ``input_specs`` the static verifier (``repro.analysis``) wants for a
    flow feeding this stage's op, at the same ``seq_len``/``cache_len``
    geometry ``model_stage_op`` was built with.  ``logits``/``prefill``
    consume a token column; ``decode`` consumes the normalized
    (batch-leading) cache-state columns ``tok``/``pos``/``c{i}``."""
    i32 = jnp.int32
    if stage in ("logits", "prefill"):
        return {"tokens": jax.ShapeDtypeStruct((seq_len,), i32)}
    if stage != "decode":
        raise ValueError(f"unknown stage {stage!r} "
                         "(logits | prefill | decode)")
    leaves, _ = jax.tree_util.tree_flatten(
        jax.eval_shape(lambda: model.init_cache(1, cache_len)))
    leaves_b2, _ = jax.tree_util.tree_flatten(
        jax.eval_shape(lambda: model.init_cache(2, cache_len)))
    specs: Dict[str, Any] = {"tok": jax.ShapeDtypeStruct((), i32),
                             "pos": jax.ShapeDtypeStruct((), i32)}
    for i, (a, b) in enumerate(zip(leaves, leaves_b2)):
        diff = [j for j, (x, y) in enumerate(zip(a.shape, b.shape))
                if x != y]
        if len(diff) != 1:
            raise ValueError(
                f"cannot identify batch axis of cache leaf {a.shape} "
                f"vs {b.shape}")
        row = tuple(s for j, s in enumerate(a.shape) if j != diff[0])
        specs[f"c{i}"] = jax.ShapeDtypeStruct(row, a.dtype)
    return specs
