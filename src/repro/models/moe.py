"""Mixture-of-Experts FFN with TPU expert parallelism.

Two execution paths:

* **reference** (``ax is None``): loop over experts with masked combine —
  exact, used by smoke tests and as the oracle for the EP path.
* **expert-parallel** (mesh present): ``shard_map`` over the mesh.  Tokens are
  dispatched into per-expert capacity buckets via a sort-based ranking (no
  O(T*E*C) one-hot einsum — that would dwarf the expert FLOPs), exchanged with
  ``all_to_all`` over the ``model`` axis (experts are sharded E/mp per chip),
  computed with dense per-expert matmuls, and combined on the way back.

This is the TPU-native adaptation of the paper's "operator placement"
optimization applied to the MoE hot-spot (DESIGN.md §2/§6).
"""
from __future__ import annotations

import functools
import inspect
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
try:                                   # jax >= 0.6: top-level name
    from jax import shard_map
except ImportError:                    # jax 0.4.x/0.5.x
    from jax.experimental.shard_map import shard_map

# replication-check kwarg was renamed check_rep -> check_vma across jax
_SHARD_MAP_CHECK_KW = (
    "check_vma" if "check_vma" in inspect.signature(shard_map).parameters
    else "check_rep")

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.partition import AxisInfo


def moe_init(key, cfg: ModelConfig, dtype, n_layers: int):
    """Stacked MoE params for ``n_layers`` MoE layers."""
    D, F, E = cfg.d_model, cfg.expert_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": layers.dense_init(ks[0], (n_layers, D, E), dtype=jnp.float32,
                                    fan_in=D),
        "w_up": layers.dense_init(ks[1], (n_layers, E, D, F), dtype, fan_in=D),
        "w_down": layers.dense_init(ks[2], (n_layers, E, F, D), dtype,
                                    fan_in=F),
    }
    if cfg.gated_mlp:
        p["w_gate"] = layers.dense_init(ks[3], (n_layers, E, D, F), dtype,
                                        fan_in=D)
    return p


def quantize_expert_weights(moe_params):
    """int8-quantize stacked expert weights (serving; §Perf A decode lever).

    Each [n, E, D, F]-like tensor becomes {"q": int8, "s": f32 [n, E, F]}
    (per-(expert, out-feature) scale over the reduction dim).  The FSDP
    all-gather then moves half the bytes; dequant happens post-gather inside
    the shard_map, right before the expert matmul.
    """
    out = dict(moe_params)
    for name in ("w_gate", "w_up", "w_down"):
        if name not in moe_params:
            continue
        w = moe_params[name].astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(w), axis=-2), 1e-8) / 127.0
        q = jnp.clip(jnp.round(w / scale[..., None, :]), -127, 127
                     ).astype(jnp.int8)
        out[name] = {"q": q, "s": scale}
    return out


def _maybe_dequant(w, dtype=jnp.bfloat16):
    if isinstance(w, dict) and "q" in w:
        return (w["q"].astype(jnp.float32) * w["s"][..., None, :]
                ).astype(dtype)
    return w


def _expert_ffn(x, w_gate, w_up, w_down, act: str, gated: bool):
    """x: [..., E, C, D]; weights: [E, D, F] / [E, F, D] (or int8 dicts)."""
    w_gate = _maybe_dequant(w_gate)
    w_up = _maybe_dequant(w_up)
    w_down = _maybe_dequant(w_down)
    up = jnp.einsum("...ecd,edf->...ecf", x, w_up)
    if gated:
        g = jnp.einsum("...ecd,edf->...ecf", x, w_gate)
        h = layers._act(g, act) * up
    else:
        h = layers._act(up, act)
    return jnp.einsum("...ecf,efd->...ecd", h, w_down)


def _router(xf, router_w, k: int):
    """xf: [T, D] -> (weights [T,k], idx [T,k], aux_loss scalar)."""
    logits = (xf.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # [T, E]
    top_w, top_i = jax.lax.top_k(probs, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss
    E = logits.shape[-1]
    me = jnp.mean(probs, axis=0)                                 # router frac
    ce = jnp.mean(
        jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)
    return top_w, top_i, aux


# ---------------------------------------------------------------------------
# Reference path (single device)
# ---------------------------------------------------------------------------
def moe_apply_reference(x, params, cfg: ModelConfig):
    """x: [B, S, D].  Exact masked-combine over all experts."""
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    xf = x.reshape(-1, D)
    top_w, top_i, aux = _router(xf, params["router"], k)
    out = jnp.zeros_like(xf, dtype=jnp.float32)
    sl = lambda w, e: jax.tree.map(lambda t: t[e:e + 1], w)
    for e in range(E):
        w_g = params.get("w_gate")
        h = _expert_ffn(xf[None],
                        sl(params["w_gate"], e) if w_g is not None else None,
                        sl(params["w_up"], e), sl(params["w_down"], e),
                        cfg.act, cfg.gated_mlp)[0]
        gate = jnp.sum(jnp.where(top_i == e, top_w, 0.0), axis=-1)
        out = out + gate[:, None] * h.astype(jnp.float32)
    return out.reshape(B, S, D).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Expert-parallel shard_map path
# ---------------------------------------------------------------------------
def _capacity(tokens: int, k: int, E: int, factor: float) -> int:
    return max(1, int(math.ceil(tokens * k * factor / E)))


def _dispatch_combine_local(xf, router_w, w_gate, w_up, w_down, *,
                            cfg: ModelConfig, mp: int, mp_axis: str,
                            dispatch: str = "all_to_all"):
    """Runs on one chip inside shard_map.  xf: [T, D] local tokens;
    expert weights are the local shard [E_loc, D, F]."""
    T, D = xf.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    E_loc = E // mp
    C = _capacity(T, k, E, cfg.capacity_factor)

    top_w, top_i, aux = _router(xf, router_w, k)
    flat_e = top_i.reshape(-1)                                   # [T*k]
    flat_w = top_w.reshape(-1)
    token_idx = jnp.arange(T * k) // k

    # rank of each (token, expert) slot within its expert, via stable sort
    order = jnp.argsort(flat_e)
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    ranks_sorted = jnp.arange(T * k) - starts[flat_e[order]]
    ranks = jnp.zeros((T * k,), jnp.int32).at[order].set(
        ranks_sorted.astype(jnp.int32))
    keep = ranks < C
    safe_rank = jnp.where(keep, ranks, C - 1)

    # dispatch buffer [E, C, D]
    contrib = jnp.where(keep[:, None], xf[token_idx], 0.0)
    buf = jnp.zeros((E, C, D), xf.dtype).at[flat_e, safe_rank].add(contrib)

    if dispatch == "all_to_all" and mp > 1:
        send = buf.reshape(mp, E_loc, C, D)
        recv = jax.lax.all_to_all(send, mp_axis, split_axis=0,
                                  concat_axis=0, tiled=True)     # [mp,Eloc,C,D]
        h = _expert_ffn(recv, w_gate, w_up, w_down, cfg.act, cfg.gated_mlp)
        back = jax.lax.all_to_all(h, mp_axis, split_axis=0,
                                  concat_axis=0, tiled=True)
        out_buf = back.reshape(E, C, D)
    elif mp > 1:
        # baseline "allgather" dispatch: gather full expert weights per chip
        wg = (jax.lax.all_gather(w_gate, mp_axis, axis=0, tiled=True)
              if w_gate is not None else None)
        wu = jax.lax.all_gather(w_up, mp_axis, axis=0, tiled=True)
        wd = jax.lax.all_gather(w_down, mp_axis, axis=0, tiled=True)
        out_buf = _expert_ffn(buf, wg, wu, wd, cfg.act, cfg.gated_mlp)
    else:
        out_buf = _expert_ffn(buf, w_gate, w_up, w_down, cfg.act,
                              cfg.gated_mlp)

    gathered = out_buf[flat_e, safe_rank] * keep[:, None]
    y = (flat_w[:, None] * gathered.astype(jnp.float32)).reshape(T, k, D)
    return y.sum(axis=1).astype(xf.dtype), aux


def moe_apply_ep(x, params, cfg: ModelConfig, ax: AxisInfo, *,
                 seq_sharded: bool, dispatch: str = "all_to_all"):
    """Expert-parallel MoE.  x: [B, S, D].

    ``seq_sharded``: the residual stream is sharded [B->data, S->model, D]
    (train/prefill).  Otherwise (decode) tokens are [B->data, 1, D] and each
    model-row chip takes a sub-slice of the local batch.
    """
    mp, mp_ax = ax.mp_size, ax.model
    dp = ax.batch
    E = cfg.num_experts
    assert E % mp == 0, (E, mp)

    def fn(x_loc, router_w, w_g, w_u, w_d):
        B_loc, S_loc, D = x_loc.shape
        if seq_sharded:
            xf = x_loc.reshape(-1, D)
            y, aux = _dispatch_combine_local(
                xf, router_w, w_g, w_u, w_d, cfg=cfg, mp=mp, mp_axis=mp_ax,
                dispatch=dispatch)
            out = y.reshape(B_loc, S_loc, D)
        else:
            # split local tokens across the model axis, then all_gather
            T = B_loc * S_loc
            pad = (-T) % mp
            xf = jnp.pad(x_loc.reshape(T, D), ((0, pad), (0, 0)))
            per = (T + pad) // mp
            i = jax.lax.axis_index(mp_ax)
            xs = jax.lax.dynamic_slice_in_dim(xf, i * per, per, axis=0)
            y, aux = _dispatch_combine_local(
                xs, router_w, w_g, w_u, w_d, cfg=cfg, mp=mp, mp_axis=mp_ax,
                dispatch=dispatch)
            yf = jax.lax.all_gather(y, mp_ax, axis=0, tiled=True)
            out = yf[:T].reshape(B_loc, S_loc, D)
        aux = jax.lax.pmean(aux, mp_ax)
        for a in dp:
            aux = jax.lax.pmean(aux, a)
        return out, aux

    seq_spec = mp_ax if seq_sharded else None

    def w_spec(w):
        if isinstance(w, dict):   # int8-quantized {"q": [E,D,F], "s": [E,F]}
            return {"q": P(mp_ax, None, None), "s": P(mp_ax, None)}
        return P(mp_ax, None, None)

    in_specs = (P(dp, seq_spec, None), P(None, None),
                w_spec(params.get("w_gate", params["w_up"])),
                w_spec(params["w_up"]), w_spec(params["w_down"]))
    out_specs = (P(dp, seq_spec, None), P())
    fn_s = shard_map(fn, mesh=ax.mesh, in_specs=in_specs,
                     out_specs=out_specs, **{_SHARD_MAP_CHECK_KW: False})
    w_gate = params.get("w_gate")
    if w_gate is None:
        w_gate = params["w_up"]  # placeholder, unused when not gated
    return fn_s(x, params["router"], w_gate, params["w_up"],
                params["w_down"])


def moe_apply(x, params, cfg: ModelConfig, ax: Optional[AxisInfo], *,
              seq_sharded: bool = True,
              dispatch: str = "all_to_all") -> Tuple[jax.Array, jax.Array]:
    """Dispatch to reference or expert-parallel path.  Returns (y, aux)."""
    if ax is None or ax.mp_size == 1:
        return moe_apply_reference(x, params, cfg)
    return moe_apply_ep(x, params, cfg, ax, seq_sharded=seq_sharded,
                        dispatch=dispatch)
