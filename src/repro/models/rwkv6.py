"""RWKV-6 "Finch" — attention-free time-mix with data-dependent decay.
[arXiv:2404.05892]

State per layer: wkv matrix state S [B, H, hd, hd] + token-shift states.
Training uses a ``lax.scan`` over time (the Pallas chunked kernel in
``repro.kernels.wkv6`` is the TPU fast path, validated against this).
Heads are sharded over the ``model`` axis; the recurrence is elementwise in
the sharded dims so the scan body has no collectives.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.partition import AxisInfo, shard, dp_axes, mp_axis

TM_LORA = 32
DECAY_LORA = 64
MIX = ("w", "k", "v", "r", "g")


def init_params(key, cfg: ModelConfig, ax: Optional[AxisInfo], **_unused):
    D, F, L = cfg.d_model, cfg.d_ff, cfg.num_layers
    H, hd = cfg.num_rwkv_heads, cfg.rwkv_head_dim
    dtype = jnp.dtype(cfg.dtype)
    k = jax.random.split(key, 16)
    uniform = lambda kk, shape, s=0.1: (jax.random.uniform(
        kk, shape, jnp.float32, -s, s)).astype(jnp.float32)

    def stack(fn, kk):
        return fn(kk)  # already created with leading L dim

    blocks = {
        "ln1": _norms(k[0], L, D, dtype),
        "ln2": _norms(k[1], L, D, dtype),
        "mu_x": uniform(k[2], (L, D)),
        "mu_mix": uniform(k[3], (L, 5, D)),
        "tm_w1": layers.dense_init(k[4], (L, D, 5 * TM_LORA), dtype, fan_in=D),
        "tm_w2": layers.dense_init(k[5], (L, 5, TM_LORA, D), dtype,
                                   fan_in=TM_LORA),
        "w0": jnp.zeros((L, D), jnp.float32) - 0.5,
        "dw1": layers.dense_init(k[6], (L, D, DECAY_LORA), dtype, fan_in=D),
        "dw2": layers.dense_init(k[7], (L, DECAY_LORA, D), dtype,
                                 fan_in=DECAY_LORA),
        "u": uniform(k[8], (L, H, hd), 0.5),
        "wr": layers.dense_init(k[9], (L, D, D), dtype, fan_in=D),
        "wk": layers.dense_init(k[10], (L, D, D), dtype, fan_in=D),
        "wv": layers.dense_init(k[11], (L, D, D), dtype, fan_in=D),
        "wg": layers.dense_init(k[12], (L, D, D), dtype, fan_in=D),
        "wo": layers.dense_init(k[13], (L, D, D), dtype, fan_in=D),
        "gn_scale": jnp.ones((L, D), jnp.float32),
        "gn_bias": jnp.zeros((L, D), jnp.float32),
        # channel mix
        "cm_mu_k": uniform(k[14], (L, D)),
        "cm_mu_r": uniform(k[14], (L, D)),
        "cm_wk": layers.dense_init(k[15], (L, D, F), dtype, fan_in=D),
        "cm_wv": layers.dense_init(k[15], (L, F, D), dtype, fan_in=F),
        "cm_wr": layers.dense_init(k[15], (L, D, D), dtype, fan_in=D),
    }
    ke = jax.random.split(key, 2)
    return {
        "embed": layers.embed_init(ke[0], cfg.padded_vocab, D, dtype),
        "final_norm": layers.init_norm(ke[1], D, cfg.norm, dtype),
        "blocks": blocks,
    }


def _norms(key, L, D, dtype):
    return {"scale": jnp.ones((L, D), dtype), "bias": jnp.zeros((L, D), dtype)}


def _ddlerp(x, xprev, lp):
    """Data-dependent lerp producing the 5 mixed inputs (w,k,v,r,g)."""
    dx = xprev - x
    xxx = x + dx * lp["mu_x"]
    B, T, D = x.shape
    low = jnp.tanh(xxx @ lp["tm_w1"]).reshape(B, T, 5, TM_LORA)
    mixes = jnp.einsum("btjl,jld->btjd", low, lp["tm_w2"])
    outs = []
    for j in range(5):
        outs.append(x + dx * (lp["mu_mix"][j] + mixes[:, :, j]))
    return outs  # [x_w, x_k, x_v, x_r, x_g]


def wkv_scan(r, k, v, w, u, state):
    """Reference WKV6 recurrence.

    r,k,v: [B, T, H, hd]; w: [B, T, H, hd] decay in (0,1); u: [H, hd];
    state: [B, H, hd, hd].  Returns (y [B,T,H,hd], new_state).
    """
    def step(S, inp):
        rt, kt, vt, wt = inp                     # [B, H, hd]
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)   # outer over (key, value)
        y = jnp.einsum("bhi,bhij->bhj", rt, S + u[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), state


def _time_mix(x, xprev, S, lp, cfg: ModelConfig, ax, *, need_state=True):
    B, T, D = x.shape
    H, hd = cfg.num_rwkv_heads, cfg.rwkv_head_dim
    xw, xk, xv, xr, xg = _ddlerp(x, xprev, lp)
    f32 = jnp.float32
    r = (xr @ lp["wr"]).astype(f32)
    k = (xk @ lp["wk"]).astype(f32)
    v = (xv @ lp["wv"]).astype(f32)
    g = jax.nn.silu((xg @ lp["wg"]).astype(f32))
    dm = dp_axes(ax)
    r = shard(ax, r, dm, None, mp_axis(ax))
    k = shard(ax, k, dm, None, mp_axis(ax))
    v = shard(ax, v, dm, None, mp_axis(ax))
    decay_low = jnp.tanh(xw @ lp["dw1"]) @ lp["dw2"]
    w = jnp.exp(-jnp.exp((lp["w0"] + decay_low).astype(f32)))  # [B,T,D]
    w = shard(ax, w, dm, None, mp_axis(ax))
    hshape = (B, T, H, hd)
    if cfg.use_pallas and T > 1:
        from repro.kernels import ops as kops
        # kernel covers the zero-state fresh-sequence path (train/prefill);
        # single-token decode (nonzero state) uses the scan below
        y = kops.wkv6(r.reshape(hshape), k.reshape(hshape),
                      v.reshape(hshape), w.reshape(hshape),
                      lp["u"].astype(f32),
                      chunk=min(64, T))
        if need_state:  # prefill: tail state for the decode cache
            _, S = wkv_scan(r.reshape(hshape), k.reshape(hshape),
                            v.reshape(hshape), w.reshape(hshape),
                            lp["u"].astype(f32), S)
    else:
        y, S = wkv_scan(r.reshape(hshape), k.reshape(hshape),
                        v.reshape(hshape), w.reshape(hshape),
                        lp["u"].astype(f32), S)
    y = layers.groupnorm_heads(y.reshape(B, T, D), lp["gn_scale"],
                               lp["gn_bias"], H)
    out = ((y * g).astype(x.dtype)) @ lp["wo"]
    return out, S


def _channel_mix(x, xprev, lp):
    dx = xprev - x
    xk = x + dx * lp["cm_mu_k"]
    xr = x + dx * lp["cm_mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ lp["cm_wk"]))
    return jax.nn.sigmoid(xr @ lp["cm_wr"]) * (kk @ lp["cm_wv"])


def _shift(x, prev):
    """prev: [B, D] last token of previous chunk (zeros at t=0)."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def forward(params, tokens, cfg: ModelConfig, ax: Optional[AxisInfo], *,
            build_cache: bool = False, cache_len=None, remat: bool = True,
            **_unused):
    B, T = tokens.shape
    H, hd = cfg.num_rwkv_heads, cfg.rwkv_head_dim
    x = layers.embed_lookup(params["embed"], tokens)
    x = shard(ax, x, dp_axes(ax), mp_axis(ax), None)

    def block_fn(x, lp):
        x = shard(ax, x, dp_axes(ax), mp_axis(ax), None)
        zeros_shift = jnp.zeros((B, x.shape[-1]), x.dtype)
        S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        h1 = layers.layernorm(x, lp["ln1"]["scale"], lp["ln1"]["bias"])
        tm_out, S = _time_mix(h1, _shift(h1, zeros_shift), S0, lp, cfg,
                              ax, need_state=build_cache)
        x = x + tm_out
        h2 = layers.layernorm(x, lp["ln2"]["scale"], lp["ln2"]["bias"])
        x = (x + _channel_mix(h2, _shift(h2, zeros_shift), lp)).astype(
            jnp.dtype(cfg.dtype))
        cache_out = {}
        if build_cache:
            cache_out = {"S": S, "tm_shift": h1[:, -1], "cm_shift": h2[:, -1]}
        return x, cache_out

    body = jax.checkpoint(block_fn) if remat else block_fn
    x, caches = jax.lax.scan(lambda c, lp: body(c, lp), x, params["blocks"])
    x = layers.apply_norm(x, params["final_norm"], cfg.norm)
    logits = layers.unembed(x, params["embed"])
    logits = shard(ax, logits, dp_axes(ax), mp_axis(ax), None)
    aux = jnp.zeros((), jnp.float32)
    if build_cache:
        return logits, caches, aux
    return logits, aux


def init_cache(cfg: ModelConfig, ax, batch: int, cache_len: int, **_unused):
    L = cfg.num_layers
    D, H, hd = cfg.d_model, cfg.num_rwkv_heads, cfg.rwkv_head_dim
    return {
        "S": jnp.zeros((L, batch, H, hd, hd), jnp.float32),
        "tm_shift": jnp.zeros((L, batch, D), jnp.dtype(cfg.dtype)),
        "cm_shift": jnp.zeros((L, batch, D), jnp.dtype(cfg.dtype)),
    }


def cache_pspecs(cfg: ModelConfig, ax: AxisInfo, **_unused):
    from jax.sharding import PartitionSpec as P
    dp, mp = ax.batch, ax.model
    return {"S": P(None, dp, mp, None, None),
            "tm_shift": P(None, dp, None),
            "cm_shift": P(None, dp, None)}


def decode_step(params, tokens, pos, cache, cfg: ModelConfig,
                ax: Optional[AxisInfo], **_unused):
    """tokens: [B,1].  Cache: {S, tm_shift, cm_shift} stacked over layers."""
    B = tokens.shape[0]
    x = layers.embed_lookup(params["embed"], tokens)
    x = shard(ax, x, dp_axes(ax), None, None)

    def block_fn(carry, lp):
        x, cache, bi = carry
        c = jax.tree.map(
            lambda t: jax.lax.dynamic_index_in_dim(t, bi, axis=0,
                                                   keepdims=False), cache)
        h = layers.layernorm(x, lp["ln1"]["scale"], lp["ln1"]["bias"])
        tm_out, S = _time_mix(h, c["tm_shift"][:, None], c["S"], lp, cfg, ax)
        x = x + tm_out
        h2 = layers.layernorm(x, lp["ln2"]["scale"], lp["ln2"]["bias"])
        x = (x + _channel_mix(h2, c["cm_shift"][:, None], lp)).astype(
            jnp.dtype(cfg.dtype))
        new_c = {"S": S, "tm_shift": h[:, -1].astype(c["tm_shift"].dtype),
                 "cm_shift": h2[:, -1].astype(c["cm_shift"].dtype)}
        cache = jax.tree.map(
            lambda t, nc: jax.lax.dynamic_update_index_in_dim(
                t, nc.astype(t.dtype), bi, axis=0), cache, new_c)
        return (x, cache, bi + 1), None

    (x, new_cache, _), _ = jax.lax.scan(
        block_fn, (x, cache, jnp.zeros((), jnp.int32)), params["blocks"])
    x = layers.apply_norm(x, params["final_norm"], cfg.norm)
    logits = layers.unembed(x, params["embed"])
    return logits, new_cache
