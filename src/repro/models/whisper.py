"""Whisper-medium transformer backbone — encoder-decoder. [arXiv:2212.04356]

The mel-spectrogram + conv frontend is a STUB (allowed carve-out):
``input_specs`` supplies precomputed frame embeddings [B, 1500, D].  Positions
are sinusoidal for both encoder and decoder (the original uses learned decoder
positions capped at 448; we serve the assigned 4k/32k shapes, so we use
sinusoidal throughout — documented deviation, DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.partition import AxisInfo, shard, mp_size, dp_axes, mp_axis


def sinusoidal_positions(length: int, d: int, offset: int = 0):
    pos = jnp.arange(offset, offset + length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None]
    angle = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def _attn_init(key, cfg, n, mp, dtype):
    D, hd = cfg.d_model, cfg.head_dim
    Hp, Kp = cfg.padded_heads(mp), cfg.replicated_kv_heads(mp)
    ks = jax.random.split(key, 4)
    return {"wq": layers.dense_init(ks[0], (n, D, Hp * hd), dtype, fan_in=D),
            "wk": layers.dense_init(ks[1], (n, D, Kp * hd), dtype, fan_in=D),
            "wv": layers.dense_init(ks[2], (n, D, Kp * hd), dtype, fan_in=D),
            "wo": layers.dense_init(ks[3], (n, Hp * hd, D), dtype,
                                    fan_in=Hp * hd)}


def _mlp_init(key, cfg, n, dtype):
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 2)
    return {"w_up": layers.dense_init(ks[0], (n, D, F), dtype, fan_in=D),
            "w_down": layers.dense_init(ks[1], (n, F, D), dtype, fan_in=F)}


def _norm_init(key, cfg, n, dtype):
    p = layers.init_norm(key, cfg.d_model, cfg.norm, dtype)
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), p)


def init_params(key, cfg: ModelConfig, ax: Optional[AxisInfo], **_unused):
    mp = mp_size(ax)
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 12)
    Le, Ld = cfg.encoder_layers, cfg.num_layers
    return {
        "embed": layers.embed_init(ks[0], cfg.padded_vocab, cfg.d_model,
                                   dtype),
        "enc": {"ln1": _norm_init(ks[1], cfg, Le, dtype),
                "attn": _attn_init(ks[2], cfg, Le, mp, dtype),
                "ln2": _norm_init(ks[3], cfg, Le, dtype),
                "mlp": _mlp_init(ks[4], cfg, Le, dtype)},
        "enc_norm": layers.init_norm(ks[5], cfg.d_model, cfg.norm, dtype),
        "dec": {"ln1": _norm_init(ks[6], cfg, Ld, dtype),
                "attn": _attn_init(ks[7], cfg, Ld, mp, dtype),
                "lnx": _norm_init(ks[8], cfg, Ld, dtype),
                "xattn": _attn_init(ks[9], cfg, Ld, mp, dtype),
                "ln2": _norm_init(ks[10], cfg, Ld, dtype),
                "mlp": _mlp_init(ks[11], cfg, Ld, dtype)},
        "final_norm": layers.init_norm(ks[5], cfg.d_model, cfg.norm, dtype),
    }


def _divisor_chunk(s: int, target: int = 1024) -> int:
    """Largest chunk <= target that divides s (whisper's 1500 frames)."""
    for c in range(min(s, target), 0, -1):
        if s % c == 0:
            return c
    return s


def _mha_full(x, ap, cfg, ax, positions, *, kv=None, causal=True):
    """Self (kv=None) or cross attention over full sequences."""
    B, S, D = x.shape
    mp = mp_size(ax)
    hd = cfg.head_dim
    Hp, Kp = cfg.padded_heads(mp), cfg.replicated_kv_heads(mp)
    q = (x @ ap["wq"]).reshape(B, S, Hp, hd)
    if kv is None:
        k = (x @ ap["wk"]).reshape(B, S, Kp, hd)
        v = (x @ ap["wv"]).reshape(B, S, Kp, hd)
        kpos = positions
    else:
        k, v = kv
        kpos = jnp.arange(k.shape[1], dtype=jnp.int32)
    q = shard(ax, q, dp_axes(ax), None, mp_axis(ax), None)
    chunk = _divisor_chunk(S)
    ck = min(1024, k.shape[1])
    # pad kv length to a chunk multiple for the chunked scan
    pad = (-k.shape[1]) % ck
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.concatenate([kpos, jnp.full((pad,), -1, jnp.int32)])
    out = layers.chunked_attention(
        q, k, v, q_positions=positions if causal else jnp.zeros(
            (S,), jnp.int32),
        k_positions=kpos, causal=causal, chunk_q=chunk, chunk_k=ck,
        scale=1.0 / math.sqrt(hd))
    return out.reshape(B, S, -1) @ ap["wo"], (k, v)


def encode(params, frames, cfg: ModelConfig, ax):
    """frames: [B, T_enc, D] stub embeddings -> encoder output."""
    B, T, D = frames.shape
    x = frames + sinusoidal_positions(T, D).astype(frames.dtype)
    x = shard(ax, x, dp_axes(ax), None, None)
    positions = jnp.arange(T, dtype=jnp.int32)

    def layer(x, lp):
        h = layers.apply_norm(x, lp["ln1"], cfg.norm)
        a, _ = _mha_full(h, lp["attn"], cfg, ax, positions, causal=False)
        x = x + a
        h = layers.apply_norm(x, lp["ln2"], cfg.norm)
        x = x + layers.mlp_apply(h, lp["mlp"], gated=cfg.gated_mlp,
                                 act=cfg.act)
        return x, None

    x, _ = jax.lax.scan(layer, x, params["enc"])
    return layers.apply_norm(x, params["enc_norm"], cfg.norm)


def forward(params, tokens, cfg: ModelConfig, ax: Optional[AxisInfo], *,
            frames=None, build_cache: bool = False, cache_len=None,
            remat: bool = True, **_unused):
    """tokens: [B, S] decoder input; frames: [B, T_enc, D] stub embeddings."""
    B, S = tokens.shape
    if frames is None:
        frames = jnp.zeros((B, cfg.encoder_seq, cfg.d_model),
                           jnp.dtype(cfg.dtype))
    enc_out = encode(params, frames, cfg, ax)
    positions = jnp.arange(S, dtype=jnp.int32)
    x = layers.embed_lookup(params["embed"], tokens)
    x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)
    x = shard(ax, x, dp_axes(ax), mp_axis(ax), None)
    mp = mp_size(ax)
    Kp, hd = cfg.replicated_kv_heads(mp), cfg.head_dim

    def layer(x, lp):
        x = shard(ax, x, dp_axes(ax), mp_axis(ax), None)
        h = layers.apply_norm(x, lp["ln1"], cfg.norm)
        a, (k, v) = _mha_full(h, lp["attn"], cfg, ax, positions, causal=True)
        x = x + a
        h = layers.apply_norm(x, lp["lnx"], cfg.norm)
        ek = (enc_out @ lp["xattn"]["wk"]).reshape(B, -1, Kp, hd)
        ev = (enc_out @ lp["xattn"]["wv"]).reshape(B, -1, Kp, hd)
        a, _ = _mha_full(h, lp["xattn"], cfg, ax, positions, kv=(ek, ev),
                         causal=False)
        x = x + a
        h = layers.apply_norm(x, lp["ln2"], cfg.norm)
        x = x + layers.mlp_apply(h, lp["mlp"], gated=cfg.gated_mlp,
                                 act=cfg.act)
        cache = {}
        if build_cache:
            W = cache_len or S
            ks = k[:, :S][:, -W:] if S >= W else jnp.pad(
                k[:, :S], ((0, 0), (0, W - S), (0, 0), (0, 0)))
            vs = v[:, :S][:, -W:] if S >= W else jnp.pad(
                v[:, :S], ((0, 0), (0, W - S), (0, 0), (0, 0)))
            ps = jnp.where(jnp.arange(W) < S,
                           jnp.arange(W), -1).astype(jnp.int32)
            cache = {"k": ks, "v": vs,
                     "pos": jnp.broadcast_to(ps, (B, W)).astype(jnp.int32),
                     "ck": ek, "cv": ev}
        return x, cache

    body = jax.checkpoint(layer) if remat else layer
    x, caches = jax.lax.scan(lambda c, lp: body(c, lp), x, params["dec"])
    x = layers.apply_norm(x, params["final_norm"], cfg.norm)
    logits = layers.unembed(x, params["embed"])
    logits = shard(ax, logits, dp_axes(ax), mp_axis(ax), None)
    aux = jnp.zeros((), jnp.float32)
    if build_cache:
        return logits, caches, aux
    return logits, aux


def init_cache(cfg: ModelConfig, ax, batch: int, cache_len: int, **_unused):
    mp = mp_size(ax)
    Kp, hd = cfg.replicated_kv_heads(mp), cfg.head_dim
    L = cfg.num_layers
    dtype = jnp.dtype(cfg.dtype)
    M = cfg.encoder_seq
    return {"k": jnp.zeros((L, batch, cache_len, Kp, hd), dtype),
            "v": jnp.zeros((L, batch, cache_len, Kp, hd), dtype),
            "pos": jnp.full((L, batch, cache_len), -1, jnp.int32),
            "ck": jnp.zeros((L, batch, M, Kp, hd), dtype),
            "cv": jnp.zeros((L, batch, M, Kp, hd), dtype)}


def cache_pspecs(cfg: ModelConfig, ax: AxisInfo, **_unused):
    from jax.sharding import PartitionSpec as P
    dp, mp = ax.batch, ax.model
    return {"k": P(None, dp, None, mp, None),
            "v": P(None, dp, None, mp, None),
            "pos": P(None, dp, None),
            "ck": P(None, dp, None, mp, None),
            "cv": P(None, dp, None, mp, None)}


def decode_step(params, tokens, pos, cache, cfg: ModelConfig,
                ax: Optional[AxisInfo], **_unused):
    B = tokens.shape[0]
    mp = mp_size(ax)
    Hp, Kp = cfg.padded_heads(mp), cfg.replicated_kv_heads(mp)
    hd = cfg.head_dim
    x = layers.embed_lookup(params["embed"], tokens)
    # sinusoidal at the decode position (per batch element)
    dim = jnp.arange(0, cfg.d_model, 2, dtype=jnp.float32)[None]
    angle = pos.astype(jnp.float32)[:, None] / jnp.power(
        10000.0, dim / cfg.d_model)
    pe = jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)
    x = x + pe[:, None].astype(x.dtype)
    x = shard(ax, x, dp_axes(ax), None, None)

    def layer(carry, lp):
        x, cache, bi = carry
        c = jax.tree.map(
            lambda t: jax.lax.dynamic_index_in_dim(t, bi, axis=0,
                                                   keepdims=False), cache)
        h = layers.apply_norm(x, lp["ln1"], cfg.norm)
        q = (h @ lp["attn"]["wq"]).reshape(B, 1, Hp, hd)
        k = (h @ lp["attn"]["wk"]).reshape(B, 1, Kp, hd)
        v = (h @ lp["attn"]["wv"]).reshape(B, 1, Kp, hd)
        W = c["k"].shape[1]
        slot = pos % W
        b_idx = jnp.arange(B)
        kc = c["k"].at[b_idx, slot].set(k[:, 0])
        vc = c["v"].at[b_idx, slot].set(v[:, 0])
        pc = c["pos"].at[b_idx, slot].set(pos)
        a = layers.decode_attention(q, kc, vc, q_position=pos,
                                    k_positions=pc,
                                    scale=1.0 / math.sqrt(hd))
        x = x + a.reshape(B, 1, -1) @ lp["attn"]["wo"]
        h = layers.apply_norm(x, lp["lnx"], cfg.norm)
        qx = (h @ lp["xattn"]["wq"]).reshape(B, 1, Hp, hd)
        M = c["ck"].shape[1]
        a = layers.decode_attention(
            qx, c["ck"], c["cv"],
            q_position=jnp.full((B,), M, jnp.int32),
            k_positions=jnp.broadcast_to(jnp.arange(M, dtype=jnp.int32),
                                         (B, M)),
            scale=1.0 / math.sqrt(hd))
        x = x + a.reshape(B, 1, -1) @ lp["xattn"]["wo"]
        h = layers.apply_norm(x, lp["ln2"], cfg.norm)
        x = x + layers.mlp_apply(h, lp["mlp"], gated=cfg.gated_mlp,
                                 act=cfg.act)
        new_c = {"k": kc, "v": vc, "pos": pc}
        cache = jax.tree.map(
            lambda t, nc: jax.lax.dynamic_update_index_in_dim(
                t, nc.astype(t.dtype), bi, axis=0),
            {k: cache[k] for k in new_c}, new_c) | {
                "ck": cache["ck"], "cv": cache["cv"]}
        return (x, cache, bi + 1), None

    (x, new_cache, _), _ = jax.lax.scan(
        layer, (x, cache, jnp.zeros((), jnp.int32)), params["dec"])
    x = layers.apply_norm(x, params["final_norm"], cfg.norm)
    logits = layers.unembed(x, params["embed"])
    return logits, new_cache
