from repro.models.registry import Model, build_model, cross_entropy  # noqa: F401
from repro.models.partition import AxisInfo  # noqa: F401
