"""RecurrentGemma / Griffin — RG-LRU recurrent blocks + local attention (2:1).
[arXiv:2402.19427]

Layer pattern: (recurrent, recurrent, local-attn) repeated; each layer is a
temporal block followed by a gated MLP.  The stack is scanned over
(rec, rec, attn) super-blocks with the remainder layers unrolled (26 = 8*3+2).
Training uses ``jax.lax.associative_scan`` for the linear recurrence
(log-depth on TPU); decode keeps a [B, R] hidden state + conv ring.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.partition import AxisInfo, shard, mp_size, dp_axes, mp_axis

C_SCALE = 8.0  # Griffin's fixed recurrence sharpness


def layer_types(cfg: ModelConfig) -> List[str]:
    p = cfg.attn_layer_period
    return ["attn" if (i % p) == p - 1 else "rec"
            for i in range(cfg.num_layers)]


def layout(cfg: ModelConfig) -> Tuple[List[str], int, List[str]]:
    """(block pattern, n_blocks, remainder types)."""
    types = layer_types(cfg)
    p = cfg.attn_layer_period
    n_blocks = cfg.num_layers // p
    return types[:p], n_blocks, types[n_blocks * p:]


# ---------------------------------------------------------------------------
def _rec_init(key, cfg: ModelConfig, n: int, dtype):
    D, R = cfg.d_model, cfg.rnn_dim
    cw = cfg.conv_width
    ks = jax.random.split(key, 8)
    # Lambda init so a = sigmoid(lam)^c in ~(0.9, 0.999)
    u = jax.random.uniform(ks[0], (n, R), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u ** (1.0 / C_SCALE) / (1 - u ** (1.0 / C_SCALE)))
    return {
        "wx": layers.dense_init(ks[1], (n, D, R), dtype, fan_in=D),
        "wgate": layers.dense_init(ks[2], (n, D, R), dtype, fan_in=D),
        "conv_w": layers.dense_init(ks[3], (n, cw, R), dtype, fan_in=cw),
        "conv_b": jnp.zeros((n, R), dtype),
        "lam": lam,
        "wi_a": jnp.ones((n, R), jnp.float32) * 0.0,   # input gate weight
        "wi_b": jnp.zeros((n, R), jnp.float32),
        "wr_a": jnp.ones((n, R), jnp.float32) * 0.0,   # recurrence gate
        "wr_b": jnp.zeros((n, R), jnp.float32),
        "wo": layers.dense_init(ks[4], (n, R, D), dtype, fan_in=R),
    }


def _attn_init(key, cfg: ModelConfig, n: int, mp: int, dtype):
    D, hd = cfg.d_model, cfg.head_dim
    Hp, Kp = cfg.padded_heads(mp), cfg.replicated_kv_heads(mp)
    ks = jax.random.split(key, 4)
    return {"wq": layers.dense_init(ks[0], (n, D, Hp * hd), dtype, fan_in=D),
            "wk": layers.dense_init(ks[1], (n, D, Kp * hd), dtype, fan_in=D),
            "wv": layers.dense_init(ks[2], (n, D, Kp * hd), dtype, fan_in=D),
            "wo": layers.dense_init(ks[3], (n, Hp * hd, D), dtype,
                                    fan_in=Hp * hd)}


def _mlp_init(key, cfg: ModelConfig, n: int, dtype):
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {"w_gate": layers.dense_init(ks[0], (n, D, F), dtype, fan_in=D),
            "w_up": layers.dense_init(ks[1], (n, D, F), dtype, fan_in=D),
            "w_down": layers.dense_init(ks[2], (n, F, D), dtype, fan_in=F)}


def _norm_init(key, cfg, n, dtype):
    p = layers.init_norm(key, cfg.d_model, cfg.norm, dtype)
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), p)


def _layer_init(key, cfg: ModelConfig, kind: str, n: int, mp: int, dtype):
    ks = jax.random.split(key, 4)
    p = {"ln1": _norm_init(ks[0], cfg, n, dtype),
         "ln2": _norm_init(ks[1], cfg, n, dtype),
         "mlp": _mlp_init(ks[2], cfg, n, dtype)}
    if kind == "rec":
        p["rec"] = _rec_init(ks[3], cfg, n, dtype)
    else:
        p["attn"] = _attn_init(ks[3], cfg, n, mp, dtype)
    return p


def init_params(key, cfg: ModelConfig, ax: Optional[AxisInfo], **_unused):
    mp = mp_size(ax)
    dtype = jnp.dtype(cfg.dtype)
    pattern, n_blocks, rest = layout(cfg)
    keys = jax.random.split(key, len(pattern) + len(rest) + 2)
    params: Dict[str, Any] = {
        "embed": layers.embed_init(keys[0], cfg.padded_vocab, cfg.d_model,
                                   dtype),
        "final_norm": layers.init_norm(keys[1], cfg.d_model, cfg.norm, dtype),
        "blocks": {},
        "rest": {},
    }
    for i, kind in enumerate(pattern):
        params["blocks"][str(i)] = _layer_init(keys[2 + i], cfg, kind,
                                               n_blocks, mp, dtype)
    for j, kind in enumerate(rest):
        params["rest"][str(j)] = jax.tree.map(
            lambda a: a[0],
            _layer_init(keys[2 + len(pattern) + j], cfg, kind, 1, mp, dtype))
    return params


# ---------------------------------------------------------------------------
# temporal blocks
# ---------------------------------------------------------------------------
def _conv1d(u, w, b, conv_state=None):
    """Causal depthwise temporal conv.  u: [B, T, R]; w: [cw, R].
    conv_state: [B, cw-1, R] previous inputs (decode)."""
    cw = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
    else:
        pad = conv_state.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)            # [B, T+cw-1, R]
    out = sum(full[:, i:i + u.shape[1]] * w[i] for i in range(cw))
    new_state = full[:, -(cw - 1):]
    return out + b, new_state


def _rglru_gates(u, rp):
    """u: [..., R] conv output -> (a, gated_input) in f32."""
    uf = u.astype(jnp.float32)
    i_gate = jax.nn.sigmoid(rp["wi_a"] * uf + rp["wi_b"])
    r_gate = jax.nn.sigmoid(rp["wr_a"] * uf + rp["wr_b"])
    log_a = -C_SCALE * jax.nn.softplus(rp["lam"]) * r_gate
    a = jnp.exp(log_a)
    x_in = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * (i_gate * uf)
    return a, x_in


def _rec_block_full(x, rp, cfg: ModelConfig, ax, build_cache: bool):
    """x: [B, T, D] -> (out, state_cache)."""
    gate = jax.nn.gelu((x @ rp["wgate"]).astype(jnp.float32))
    u = (x @ rp["wx"])
    u = shard(ax, u, dp_axes(ax), None, mp_axis(ax))
    u, conv_state = _conv1d(u, rp["conv_w"], rp["conv_b"])
    a, x_in = _rglru_gates(u, rp)
    # linear recurrence h_t = a_t h_{t-1} + x_t
    if cfg.use_pallas and x.shape[1] > 1:
        from repro.kernels import ops as kops
        h = kops.rglru_scan(a, x_in, chunk=min(128, x.shape[1]),
                            block_r=min(512, a.shape[-1]))
    else:
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2
        a_s, h = jax.lax.associative_scan(combine, (a, x_in), axis=1)
    y = (h * gate).astype(x.dtype) @ rp["wo"]
    cache = {}
    if build_cache:
        cache = {"h": h[:, -1], "conv": conv_state}
    return y, cache


def _rec_block_step(x, rp, state):
    """x: [B, 1, D]; state: {h: [B,R] f32, conv: [B,cw-1,R]}"""
    gate = jax.nn.gelu((x @ rp["wgate"]).astype(jnp.float32))
    u = x @ rp["wx"]
    u, conv_state = _conv1d(u, rp["conv_w"], rp["conv_b"],
                            conv_state=state["conv"])
    a, x_in = _rglru_gates(u, rp)
    h = a[:, 0] * state["h"] + x_in[:, 0]
    y = (h[:, None] * gate).astype(x.dtype) @ rp["wo"]
    return y, {"h": h, "conv": conv_state.astype(state["conv"].dtype)}


def _attn_full(x, apm, cfg: ModelConfig, ax, positions):
    B, S, D = x.shape
    mp = mp_size(ax)
    hd = cfg.head_dim
    Hp, Kp = cfg.padded_heads(mp), cfg.replicated_kv_heads(mp)
    q = (x @ apm["wq"]).reshape(B, S, Hp, hd)
    k = (x @ apm["wk"]).reshape(B, S, Kp, hd)
    v = (x @ apm["wv"]).reshape(B, S, Kp, hd)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    chunk = min(1024, S)
    out = layers.chunked_attention(
        q, k, v, q_positions=positions, k_positions=positions, causal=True,
        window=cfg.sliding_window, chunk_q=chunk, chunk_k=chunk,
        scale=1.0 / math.sqrt(hd))
    return out.reshape(B, S, -1) @ apm["wo"], k, v


def _attn_step(x, apm, cfg: ModelConfig, ax, pos, kc, vc, pc):
    B = x.shape[0]
    mp = mp_size(ax)
    hd = cfg.head_dim
    Hp, Kp = cfg.padded_heads(mp), cfg.replicated_kv_heads(mp)
    q = (x @ apm["wq"]).reshape(B, 1, Hp, hd)
    k = (x @ apm["wk"]).reshape(B, 1, Kp, hd)
    v = (x @ apm["wv"]).reshape(B, 1, Kp, hd)
    q = layers.apply_rope(q, pos[:, None], cfg.rope_theta)
    k = layers.apply_rope(k, pos[:, None], cfg.rope_theta)
    W = kc.shape[1]
    slot = pos % W
    b_idx = jnp.arange(B)
    kc = kc.at[b_idx, slot].set(k[:, 0])
    vc = vc.at[b_idx, slot].set(v[:, 0])
    pc = pc.at[b_idx, slot].set(pos)
    out = layers.decode_attention(q, kc, vc, q_position=pos, k_positions=pc,
                                  window=cfg.sliding_window,
                                  scale=1.0 / math.sqrt(hd))
    return out.reshape(B, 1, -1) @ apm["wo"], kc, vc, pc


def _mlp(x, mp_params, cfg):
    h = jax.nn.gelu((x @ mp_params["w_gate"]).astype(jnp.float32),
                    approximate=True).astype(x.dtype) * (x @ mp_params["w_up"])
    return h @ mp_params["w_down"]


# ---------------------------------------------------------------------------
def _apply_layer_full(x, lp, kind: str, cfg, ax, positions, build_cache,
                      cache_len=None):
    h = layers.apply_norm(x, lp["ln1"], cfg.norm)
    cache = {}
    if kind == "rec":
        y, cache = _rec_block_full(h, lp["rec"], cfg, ax, build_cache)
    else:
        y, k, v = _attn_full(h, lp["attn"], cfg, ax, positions)
        if build_cache:
            B, S = x.shape[0], x.shape[1]
            # ring capacity must come from cache_len (matching init_cache),
            # NOT the prefill length: with capacity == S the first decode
            # step (slot = S % S = 0) evicts position 0's KV even when the
            # attention window still covers it, skewing decode logits vs
            # the full forward
            cap = cache_len if cache_len else S
            W = min(cfg.sliding_window, cap) if cfg.sliding_window else cap
            keep = min(W, S)
            # scatter kept keys to slot = position % W so decode's ring
            # addressing overwrites the genuinely oldest entries
            kept_pos = positions[S - keep:]
            slots = kept_pos % W
            ks = jnp.zeros((B, W) + k.shape[2:], k.dtype)
            vs = jnp.zeros((B, W) + v.shape[2:], v.dtype)
            ks = ks.at[:, slots].set(k[:, S - keep:])
            vs = vs.at[:, slots].set(v[:, S - keep:])
            ps = jnp.full((B, W), -1, jnp.int32)
            ps = ps.at[:, slots].set(kept_pos.astype(jnp.int32))
            cache = {"k": ks, "v": vs, "pos": ps}
    x = x + y
    h = layers.apply_norm(x, lp["ln2"], cfg.norm)
    x = x + _mlp(h, lp["mlp"], cfg)
    return x, cache


def _apply_layer_step(x, lp, kind: str, cfg, ax, pos, cache):
    h = layers.apply_norm(x, lp["ln1"], cfg.norm)
    if kind == "rec":
        y, new_cache = _rec_block_step(h, lp["rec"], cache)
    else:
        y, kc, vc, pc = _attn_step(h, lp["attn"], cfg, ax, pos,
                                   cache["k"], cache["v"], cache["pos"])
        new_cache = {"k": kc, "v": vc, "pos": pc}
    x = x + y
    h = layers.apply_norm(x, lp["ln2"], cfg.norm)
    x = x + _mlp(h, lp["mlp"], cfg)
    return x, new_cache


def forward(params, tokens, cfg: ModelConfig, ax: Optional[AxisInfo], *,
            build_cache: bool = False, cache_len=None, remat: bool = True,
            **_unused):
    pattern, n_blocks, rest = layout(cfg)
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    x = layers.embed_lookup(params["embed"], tokens,
                            scale_by_dim=cfg.embedding_scale)
    x = shard(ax, x, dp_axes(ax), mp_axis(ax), None)

    def block_fn(x, bp):
        x = shard(ax, x, dp_axes(ax), mp_axis(ax), None)
        caches = {}
        for i, kind in enumerate(pattern):
            x, c = _apply_layer_full(x, bp[str(i)], kind, cfg, ax, positions,
                                     build_cache, cache_len)
            caches[str(i)] = c
        return x, caches

    body = jax.checkpoint(block_fn) if remat else block_fn
    x, caches = jax.lax.scan(lambda c, bp: body(c, bp), x, params["blocks"])
    rest_caches = {}
    for j, kind in enumerate(rest):
        x, c = _apply_layer_full(x, params["rest"][str(j)], kind, cfg, ax,
                                 positions, build_cache, cache_len)
        rest_caches[str(j)] = c
    x = layers.apply_norm(x, params["final_norm"], cfg.norm)
    logits = layers.unembed(x, params["embed"],
                            softcap=cfg.final_logit_softcap)
    logits = shard(ax, logits, dp_axes(ax), mp_axis(ax), None)
    aux = jnp.zeros((), jnp.float32)
    if build_cache:
        return logits, {"blocks": caches, "rest": rest_caches}, aux
    return logits, aux


def _empty_layer_cache(cfg: ModelConfig, ax, kind: str, batch: int,
                       cache_len: int, lead: Tuple[int, ...]):
    dtype = jnp.dtype(cfg.dtype)
    if kind == "rec":
        R, cw = cfg.rnn_dim, cfg.conv_width
        return {"h": jnp.zeros(lead + (batch, R), jnp.float32),
                "conv": jnp.zeros(lead + (batch, cw - 1, R), dtype)}
    mp = mp_size(ax)
    Kp, hd = cfg.replicated_kv_heads(mp), cfg.head_dim
    W = min(cfg.sliding_window, cache_len) if cfg.sliding_window else cache_len
    return {"k": jnp.zeros(lead + (batch, W, Kp, hd), dtype),
            "v": jnp.zeros(lead + (batch, W, Kp, hd), dtype),
            "pos": jnp.full(lead + (batch, W), -1, jnp.int32)}


def init_cache(cfg: ModelConfig, ax, batch: int, cache_len: int, **_unused):
    pattern, n_blocks, rest = layout(cfg)
    return {
        "blocks": {str(i): _empty_layer_cache(cfg, ax, kind, batch, cache_len,
                                              (n_blocks,))
                   for i, kind in enumerate(pattern)},
        "rest": {str(j): _empty_layer_cache(cfg, ax, kind, batch, cache_len,
                                            ())
                 for j, kind in enumerate(rest)},
    }


def cache_pspecs(cfg: ModelConfig, ax: AxisInfo, **_unused):
    from jax.sharding import PartitionSpec as P
    pattern, _, rest = layout(cfg)
    dp, mp = ax.batch, ax.model

    def spec(kind, lead):
        if kind == "rec":
            return {"h": P(*lead, dp, mp),
                    "conv": P(*lead, dp, None, mp)}
        return {"k": P(*lead, dp, None, mp, None),
                "v": P(*lead, dp, None, mp, None),
                "pos": P(*lead, dp, None)}

    return {
        "blocks": {str(i): spec(kind, (None,))
                   for i, kind in enumerate(pattern)},
        "rest": {str(j): spec(kind, ()) for j, kind in enumerate(rest)},
    }


def decode_step(params, tokens, pos, cache, cfg: ModelConfig,
                ax: Optional[AxisInfo], **_unused):
    pattern, n_blocks, rest = layout(cfg)
    x = layers.embed_lookup(params["embed"], tokens,
                            scale_by_dim=cfg.embedding_scale)
    x = shard(ax, x, dp_axes(ax), None, None)

    def block_fn(carry, bp):
        x, bcache, bi = carry
        bc = jax.tree.map(
            lambda t: jax.lax.dynamic_index_in_dim(t, bi, axis=0,
                                                   keepdims=False), bcache)
        new_c = {}
        for i, kind in enumerate(pattern):
            x, c = _apply_layer_step(x, bp[str(i)], kind, cfg, ax, pos,
                                     bc[str(i)])
            new_c[str(i)] = c
        bcache = jax.tree.map(
            lambda t, nc: jax.lax.dynamic_update_index_in_dim(
                t, nc.astype(t.dtype), bi, axis=0), bcache, new_c)
        return (x, bcache, bi + 1), None

    (x, new_blocks, _), _ = jax.lax.scan(
        block_fn, (x, cache["blocks"], jnp.zeros((), jnp.int32)),
        params["blocks"])
    new_rest = {}
    for j, kind in enumerate(rest):
        x, c = _apply_layer_step(x, params["rest"][str(j)], kind, cfg, ax,
                                 pos, cache["rest"][str(j)])
        new_rest[str(j)] = c
    x = layers.apply_norm(x, params["final_norm"], cfg.norm)
    logits = layers.unembed(x, params["embed"],
                            softcap=cfg.final_logit_softcap)
    return logits, {"blocks": new_blocks, "rest": new_rest}
