"""Mesh axis bookkeeping shared by models and the launcher.

``AxisInfo`` describes the logical axes of the active mesh.  Model code calls
``ax.shard(x, ...)`` to attach sharding constraints; with ``ax=None`` (smoke
tests, single device) everything is a no-op, so the model zoo runs unchanged
on one CPU device.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisName = Union[str, Tuple[str, ...], None]


@dataclasses.dataclass(frozen=True)
class AxisInfo:
    """Logical axes: ``data`` (batch/FSDP; may be ('pod','data')), ``model``.

    ``shard_batch=False`` (long_500k: global batch 1) keeps weight sharding
    but leaves activation batch dims replicated.
    """
    mesh: Mesh
    data: Tuple[str, ...] = ("data",)
    model: str = "model"
    shard_batch: bool = True

    @property
    def batch(self) -> Optional[Tuple[str, ...]]:
        """Axes for activation batch dims (None when batch is unshardable)."""
        return self.data if self.shard_batch else None

    @property
    def dp_size(self) -> int:
        return math.prod(self.mesh.shape[a] for a in self.data)

    @property
    def mp_size(self) -> int:
        return self.mesh.shape[self.model]

    def spec(self, *axes: AxisName) -> P:
        return P(*axes)

    def shard(self, x, *axes: AxisName):
        """with_sharding_constraint under the active mesh."""
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*axes)))

    def sharding(self, *axes: AxisName) -> NamedSharding:
        return NamedSharding(self.mesh, P(*axes))


def shard(ax: Optional[AxisInfo], x, *axes: AxisName):
    if ax is None:
        return x
    return ax.shard(x, *axes)


def mp_size(ax: Optional[AxisInfo]) -> int:
    return 1 if ax is None else ax.mp_size


def dp_axes(ax: Optional[AxisInfo]):
    """Batch-dim axes for activations (None if batch unshardable/no mesh)."""
    return None if ax is None else ax.batch


def mp_axis(ax: Optional[AxisInfo]) -> Optional[str]:
    return None if ax is None else ax.model
