"""Seeded, deterministic fault injection for the executor fleet.

Failure is a first-class, *measurable* event: a :class:`FaultPlan` makes
any executor crash (its thread dies mid-item), hang (straggle for a
configured duration), or throw a typed transient error, at configurable
per-kind rates.  The plan is installed via ``Runtime(fault_plan=...)`` /
``ExecutorPool(fault_injector=...)``; with no plan installed the
production code paths run unmodified (a single ``is None`` check per
item).

Determinism: every decision comes from a per-executor ``random.Random``
seeded by ``(plan.seed, executor_id)``, so a given executor sees the same
fault sequence for the same seed regardless of thread interleaving — the
chaos benchmark and the regression tests replay identical fault
schedules.

The module also derives **straggler-hedging delays** from the profiler's
latency curves: :func:`hedge_delays_from_profile` turns a deployed DAG's
per-op p99 into a per-node hedge delay (fire a backup dispatch once the
primary has taken longer than ``factor`` × p99), and
:func:`install_hedging` wires those delays into the runtime.
"""
from __future__ import annotations

import dataclasses
import random
import threading
from typing import Dict, Iterable, List, Optional, Tuple

from repro.serving.retry import TransientFault


class FaultCrash(BaseException):
    """Raised *outside* the executor's error-handling scope to kill the
    worker thread mid-item — the injected analogue of a process crash.
    Derives from BaseException so no user-level handler can swallow it."""


@dataclasses.dataclass
class FaultSpec:
    """One injectable failure mode.

    ``rate`` is the per-item probability; ``limit`` bounds how many times
    the spec fires in total (``limit=1, rate=1.0`` is the deterministic
    "fail the next item" used by regression tests).  ``classes``
    restricts the spec to executor resource classes (None = all).
    """
    kind: str                        # "crash" | "hang" | "transient"
    rate: float = 0.0
    hang_s: float = 0.2              # straggle duration for kind="hang"
    limit: Optional[int] = None      # max firings (None = unbounded)
    classes: Optional[Tuple[str, ...]] = None

    def __post_init__(self):
        if self.kind not in ("crash", "hang", "transient"):
            raise ValueError(f"unknown fault kind {self.kind!r}")


@dataclasses.dataclass
class FaultPlan:
    """A seeded schedule of :class:`FaultSpec`\\ s for the fleet."""
    specs: List[FaultSpec] = dataclasses.field(default_factory=list)
    seed: int = 0

    def crash(self, rate: float = 0.0, *, limit: Optional[int] = None,
              classes: Optional[Iterable[str]] = None) -> "FaultPlan":
        return self._add("crash", rate, limit=limit, classes=classes)

    def hang(self, rate: float = 0.0, *, hang_s: float = 0.2,
             limit: Optional[int] = None,
             classes: Optional[Iterable[str]] = None) -> "FaultPlan":
        return self._add("hang", rate, hang_s=hang_s, limit=limit,
                         classes=classes)

    def transient(self, rate: float = 0.0, *, limit: Optional[int] = None,
                  classes: Optional[Iterable[str]] = None) -> "FaultPlan":
        return self._add("transient", rate, limit=limit, classes=classes)

    def _add(self, kind, rate, *, hang_s=0.2, limit=None, classes=None):
        self.specs.append(FaultSpec(
            kind, rate, hang_s=hang_s, limit=limit,
            classes=tuple(classes) if classes else None))
        return self


class FaultInjector:
    """Draws fault decisions for executors from a :class:`FaultPlan`.

    Thread-safe; shared by every executor in a pool.  ``draw`` is called
    once per dequeued work item and returns the fault to apply (first
    matching spec wins) or None.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._lock = threading.Lock()
        self._rngs: Dict[str, random.Random] = {}
        self._fired: Dict[int, int] = {}         # spec index -> count
        self.counts: Dict[str, int] = {"crash": 0, "hang": 0,
                                       "transient": 0}

    def _rng(self, executor_id: str) -> random.Random:
        rng = self._rngs.get(executor_id)
        if rng is None:
            rng = random.Random(f"{self.plan.seed}/{executor_id}")
            self._rngs[executor_id] = rng
        return rng

    def draw(self, executor_id: str,
             resource_class: str) -> Optional[FaultSpec]:
        """The fault to inject for this item, or None.  One uniform draw
        per (executor, spec) keeps the sequence deterministic per
        executor under any thread interleaving."""
        with self._lock:
            rng = self._rng(executor_id)
            for i, spec in enumerate(self.plan.specs):
                if spec.classes is not None \
                        and resource_class not in spec.classes:
                    continue
                if spec.limit is not None \
                        and self._fired.get(i, 0) >= spec.limit:
                    continue
                if spec.rate <= 0.0 or rng.random() >= spec.rate:
                    continue
                self._fired[i] = self._fired.get(i, 0) + 1
                self.counts[spec.kind] += 1
                return spec
        return None

    def transient_error(self, executor_id: str) -> TransientFault:
        return TransientFault(
            f"injected transient fault on {executor_id}")

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return dict(self.counts)


# -- straggler hedging delays from the profiler's curves ---------------------

def hedge_delays_from_profile(deployed, profile, *, factor: float = 1.0,
                              floor_s: float = 0.001,
                              batch: int = 1) -> Dict[str, float]:
    """Per-node hedge delays for a deployed DAG: fire a backup dispatch
    once the primary has been out for ``factor`` × the op's measured p99
    at ``batch`` rows.  A replica slower than its own p99 is by
    definition a straggler; waiting that long first keeps the hedge rate
    ~1% under healthy operation (the Clipper/Dean tail-at-scale recipe).

    Returns ``{runtime node name: delay_s}`` for every node whose plan op
    has a measured curve."""
    delays: Dict[str, float] = {}
    for name, node in deployed.dag.nodes.items():
        if node.plan_op_id is None:
            continue
        curve = profile.curves.get(node.plan_op_id)
        if curve is None:
            continue
        p99 = curve.p99_s(batch)
        if p99 <= 0.0:
            continue
        delays[name] = max(floor_s, factor * p99)
    return delays


def install_hedging(runtime, deployed, profile, *, factor: float = 1.0,
                    floor_s: float = 0.001) -> Dict[str, float]:
    """Derive hedge delays from ``profile`` and install them on
    ``runtime`` for ``deployed``'s DAG.  Returns what was installed."""
    delays = hedge_delays_from_profile(deployed, profile, factor=factor,
                                       floor_s=floor_s)
    for node_name, delay_s in delays.items():
        runtime.configure_hedging(deployed.dag.name, node_name, delay_s)
    return delays
