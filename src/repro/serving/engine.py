"""Serving engine: jitted prefill/decode with KV-cache management.

This is the "black-box model operator" that Cloudflow dataflows wrap: a
``ServingEngine`` exposes ``generate`` (prefill + N decode steps) and
``step`` primitives.  Batching across requests is handled one level up by
``repro.runtime``'s batching executor (paper §4: Batching) via
``repro.serving.batcher``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.registry import Model, build_model


@dataclasses.dataclass
class ServingEngine:
    model: Model
    cache_len: int = 256

    def __post_init__(self):
        self._prefill = jax.jit(
            functools.partial(self._prefill_impl),
            static_argnames=("cache_len",))
        self._decode = jax.jit(self._decode_impl)

    # --- impl -------------------------------------------------------------
    def _prefill_impl(self, params, batch, *, cache_len: int):
        return self.model.prefill(params, batch, cache_len=cache_len)

    def _decode_impl(self, params, tokens, pos, cache):
        return self.model.decode_step(params, tokens, pos, cache)

    # --- public -----------------------------------------------------------
    def prefill(self, params, batch: Dict[str, Any],
                cache_len: Optional[int] = None):
        return self._prefill(params, batch,
                             cache_len=cache_len or self.cache_len)

    def decode(self, params, tokens, pos, cache):
        return self._decode(params, tokens, pos, cache)

    def generate(self, params, batch: Dict[str, Any], max_new_tokens: int,
                 *, temperature: float = 0.0, key=None) -> np.ndarray:
        """Greedy (or sampled) generation.  Returns [B, max_new_tokens]."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        cache_len = max(self.cache_len, S + max_new_tokens)
        logits, cache = self.prefill(params, batch, cache_len=cache_len)
        out = []
        cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        for i in range(max_new_tokens):
            out.append(np.asarray(cur))
            pos = jnp.full((B,), S + i, jnp.int32)
            logits, cache = self.decode(params, cur, pos, cache)
            if temperature > 0.0 and key is not None:
                key, sub = jax.random.split(key)
                cur = jax.random.categorical(
                    sub, logits[:, -1] / temperature).astype(jnp.int32)[:, None]
            else:
                cur = jnp.argmax(logits[:, -1], axis=-1).astype(
                    jnp.int32)[:, None]
        return np.concatenate(out, axis=1)


def make_engine(cfg: ModelConfig, *, cache_len: int = 256,
                ax=None, long_context: bool = False) -> ServingEngine:
    return ServingEngine(build_model(cfg, ax, long_context=long_context),
                         cache_len=cache_len)
