"""Deadline-aware admission control: the front door's overload valve.

An overloaded deployment that just queues is worse than useless — every
queued request blows its deadline AND inflates the queue for the requests
behind it, so one burst past capacity poisons p99 for *all* traffic
(cascading collapse).  Clipper sheds work against per-query deadlines and
InferLine provisions for bursty arrivals; this module is that idea applied
at the dataflow front door, using the SAME M/M/c critical-path model the
optimizer plans with (``profiling/estimator.py``) so admission and
planning never disagree about what the deployment can sustain.

Mechanics, per offered request:

1. **Token bucket per class** — each :class:`ClassPolicy` may carry a
   rate/burst budget; a class over budget is shed immediately
   (``rate_limit``), before any modeling.  Low-priority classes get small
   buckets, so they are the first traffic to go.
2. **Priority-ordered estimator gate** — the critical-path p99 estimate
   for a class-``k`` request is computed at the arrival rate of all
   traffic with priority **>= k's**: best-effort traffic is modeled
   against the full load (and shed/degraded as soon as the full load
   misses its deadline) while interactive traffic is modeled against only
   its peers — exactly the brownout ordering an operator wants, without a
   separate scheduler.
3. **Degrade instead of shed** — a class whose policy carries a
   :class:`~repro.core.lowering.DegradePolicy` is *degraded* (routed to
   cheap, already-compiled variants: per-row path, capped buckets, no
   competitive racing) rather than fast-failed, as long as its token
   bucket still has room.

Every decision is surfaced as a :class:`Decision` so the runtime can
record ``admission/...`` metrics and the SLO controller can distinguish
"overloaded and protecting itself" from "missing SLO".

The typed errors (:class:`Overloaded`, :class:`DeadlineExceeded`) live
here so `runtime/`, `serving/`, and callers share one vocabulary; they
are deliberately dependency-free.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Deque, Dict, Optional, Tuple

from repro.core.lowering import DegradePolicy
from repro.obs import keys as okeys
from repro.obs.clock import now as _mono


class Overloaded(RuntimeError):
    """Typed fast-fail: the deployment refused this request to protect
    itself (rate limit exceeded, or the critical-path estimate already
    misses the request's deadline)."""

    def __init__(self, msg: str, *, klass: str = "",
                 reason: str = "overload",
                 estimate_s: Optional[float] = None,
                 deadline_s: Optional[float] = None):
        super().__init__(msg)
        self.klass = klass
        self.reason = reason
        self.estimate_s = estimate_s
        self.deadline_s = deadline_s


class DeadlineExceeded(Overloaded):
    """The request's deadline passed while it waited (queue/batch slot):
    it fails fast instead of occupying capacity it can no longer use."""

    def __init__(self, msg: str, *, klass: str = "",
                 deadline_s: Optional[float] = None):
        super().__init__(msg, klass=klass, reason="deadline",
                         deadline_s=deadline_s)


class TokenBucket:
    """Thread-safe token bucket: ``rate`` tokens/s, capacity ``burst``.
    ``rate <= 0`` or ``None`` means unlimited."""

    def __init__(self, rate: Optional[float], burst: Optional[float] = None):
        self.rate = float(rate) if rate else 0.0
        self.burst = float(burst if burst is not None else
                           max(self.rate, 1.0))
        self._tokens = self.burst
        self._t = _mono()
        self._lock = threading.Lock()

    def try_take(self, n: float = 1.0) -> bool:
        if self.rate <= 0:
            return True
        with self._lock:
            now = _mono()
            self._tokens = min(self.burst,
                               self._tokens + (now - self._t) * self.rate)
            self._t = now
            if self._tokens >= n:
                self._tokens -= n
                return True
            return False


@dataclasses.dataclass(frozen=True)
class ClassPolicy:
    """How one request class is treated at the front door."""
    name: str
    priority: int                    # higher = protected longer
    rate: Optional[float] = None     # token-bucket rate (req/s); None = inf
    burst: Optional[float] = None    # token-bucket capacity
    degrade: Optional[DegradePolicy] = None   # degrade instead of shed
    default_deadline_s: Optional[float] = None


#: the canonical three-class split: interactive is protected, batch rides
#: in the middle, best_effort degrades first and sheds first.
def default_classes() -> Dict[str, ClassPolicy]:
    return {
        "interactive": ClassPolicy("interactive", priority=2,
                                   default_deadline_s=None),
        "batch": ClassPolicy("batch", priority=1),
        "best_effort": ClassPolicy("best_effort", priority=0,
                                   degrade=DegradePolicy()),
    }


@dataclasses.dataclass(frozen=True)
class Decision:
    """What the gate decided for one offered request."""
    action: str                      # "admit" | "degrade" | "shed"
    klass: str
    reason: str = "ok"               # "ok"|"rate_limit"|"deadline_risk"
    estimate_s: Optional[float] = None
    deadline_s: Optional[float] = None
    degrade: Optional[DegradePolicy] = None

    @property
    def admitted(self) -> bool:
        return self.action != "shed"


class AdmissionController:
    """The gate ``Runtime.call_dag`` consults before accepting a request.

    Stateless with respect to the runtime: it holds the plan + profile +
    config the deployment currently runs (refreshed via :meth:`update`
    after a replan) and measures per-class arrival rates itself from the
    offered stream.  Estimates are cached for ``reestimate_s`` and
    invalidated when the measured rate moves >10%, so the per-request
    cost is a dict lookup, not a DAG walk.
    """

    def __init__(self, plan=None, profile=None, config=None, *, net=None,
                 classes: Optional[Dict[str, ClassPolicy]] = None,
                 window_s: float = 1.0, reestimate_s: float = 0.25,
                 default_klass: str = "interactive",
                 queue_depth_fn=None, queue_cost_s: float = 0.0):
        self.plan = plan
        self.profile = profile
        self.config = config
        self.net = net
        self.classes = dict(classes) if classes else default_classes()
        self.window_s = float(window_s)
        self.reestimate_s = float(reestimate_s)
        self.default_klass = default_klass
        # leading overload indicator: live executor backlog.  The M/M/c
        # estimate is a steady-state model fed by a windowed arrival rate,
        # so it lags a burst (and a replica failure that shrinks capacity)
        # by up to window_s; the queue it leaves behind is visible NOW.
        # queue_cost_s is the modeled per-queued-item drain cost — when
        # 0 it is derived from the profile's bottleneck service time.
        self.queue_depth_fn = queue_depth_fn
        self.queue_cost_s = float(queue_cost_s)
        self._lock = threading.Lock()
        self._buckets: Dict[str, TokenBucket] = {}
        for name, pol in self.classes.items():
            if pol.rate:
                self._buckets[name] = TokenBucket(pol.rate, pol.burst)
        self._arrivals: Dict[str, Deque[float]] = \
            collections.defaultdict(collections.deque)
        # (lam_used, p99_s, computed_at) per priority level
        self._est_cache: Dict[int, Tuple[float, float, float]] = {}
        self.counters: Dict[str, int] = collections.defaultdict(int)

    # -- live-state refresh --------------------------------------------------
    def update(self, plan=None, profile=None, config=None) -> None:
        """Point the gate at the model of the NOW-live deployment (called
        after hot-applies and blue/green swaps)."""
        with self._lock:
            if plan is not None:
                self.plan = plan
            if profile is not None:
                self.profile = profile
            if config is not None:
                self.config = config
            self._est_cache.clear()
            self._btl_cost = None

    def set_class(self, policy: ClassPolicy) -> None:
        with self._lock:
            self.classes[policy.name] = policy
            if policy.rate:
                self._buckets[policy.name] = TokenBucket(policy.rate,
                                                         policy.burst)
            else:
                self._buckets.pop(policy.name, None)

    def policy(self, klass: Optional[str]) -> ClassPolicy:
        name = klass or self.default_klass
        pol = self.classes.get(name)
        if pol is None:
            # unknown classes ride at the bottom: they get best-effort
            # treatment, not a KeyError on the hot path
            pol = ClassPolicy(name, priority=0, degrade=DegradePolicy())
            self.classes[name] = pol
        return pol

    # -- measured arrival rates ----------------------------------------------
    def _note_arrival(self, name: str, now: float) -> None:
        dq = self._arrivals[name]
        dq.append(now)
        cut = now - self.window_s
        while dq and dq[0] < cut:
            dq.popleft()

    def rate_at_or_above(self, priority: int, now: float) -> float:
        """Measured offered rate (req/s) of all classes with priority >=
        ``priority`` — the load a request of that priority competes with."""
        cut = now - self.window_s
        total = 0
        for name, dq in self._arrivals.items():
            if self.classes.get(name, _BOTTOM).priority < priority:
                continue
            while dq and dq[0] < cut:
                dq.popleft()
            total += len(dq)
        return total / max(self.window_s, 1e-9)

    # -- estimator gate ------------------------------------------------------
    def _p99_at(self, priority: int, lam: float, now: float) -> float:
        cached = self._est_cache.get(priority)
        if cached is not None:
            lam0, p99, t0 = cached
            fresh = now - t0 < self.reestimate_s
            close = abs(lam - lam0) <= 0.1 * max(lam0, 1.0)
            if fresh and close:
                return p99
        p99 = self._estimate_p99(lam)
        self._est_cache[priority] = (lam, p99, now)
        return p99

    def _estimate_p99(self, lam: float) -> float:
        if self.plan is None or self.profile is None:
            return 0.0           # nothing to model against: permissive
        from repro.profiling.estimator import LatencyEstimator, Workload
        est = LatencyEstimator(self.profile, net=self.net)
        cfg = self.config if self.config is not None else _DEFAULT_CONFIG
        return est.estimate(self.plan, cfg,
                            Workload(arrival_rate=max(lam, 1e-6))).p99_s

    def _queue_penalty(self, now: float) -> float:
        """Extra expected wait implied by the backlog already sitting in
        executor queues: depth × per-item drain cost at the bottleneck.
        Computed OUTSIDE the estimate cache — the backlog moves faster
        than ``reestimate_s`` during exactly the events (bursts, replica
        failures) this signal exists to catch."""
        fn = self.queue_depth_fn
        if fn is None:
            return 0.0
        try:
            depth = int(fn())
        except BaseException:
            return 0.0
        if depth <= 0:
            return 0.0
        cost = self.queue_cost_s
        if cost <= 0.0:
            cost = self._bottleneck_cost_s()
        return depth * cost

    def _bottleneck_cost_s(self) -> float:
        """Per-queued-item drain cost: the slowest op's mean service time
        divided by its replica count (the pool drains the backlog at the
        bottleneck's aggregate rate).  Cached — the plan only changes via
        ``update``, which clears it."""
        cached = getattr(self, "_btl_cost", None)
        if cached is not None:
            return cached
        cost = 1e-3              # permissive floor with nothing to model
        if self.plan is not None and self.profile is not None:
            cfg = self.config if self.config is not None \
                else _DEFAULT_CONFIG
            worst = 0.0
            for o in getattr(self.plan, "ops", ()):
                curve = self.profile.curves.get(o.op_id)
                if curve is None:
                    continue
                nc = cfg.node(o.op_id)
                c = max(1, int(getattr(nc, "target_replicas", 1) or 1))
                b = max(1, int(getattr(nc, "max_batch", 1) or 1))
                per_item = curve.service_s(b) / (b * c)
                worst = max(worst, per_item)
            if worst > 0.0:
                cost = worst
        self._btl_cost = cost
        return cost

    # -- the gate ------------------------------------------------------------
    def admit(self, klass: Optional[str] = None,
              deadline_s: Optional[float] = None) -> Decision:
        """Decide one offered request.  Never raises — the caller turns a
        shed Decision into a typed :class:`Overloaded` failure."""
        now = _mono()
        pol = self.policy(klass)
        name = pol.name
        if deadline_s is None:
            deadline_s = pol.default_deadline_s
        with self._lock:
            self.counters[okeys.gate_counter(name, "offered")] += 1
            bucket = self._buckets.get(name)
            if bucket is not None and not bucket.try_take():
                self.counters[okeys.gate_counter(name, "shed")] += 1
                return Decision("shed", name, "rate_limit",
                                deadline_s=deadline_s)
            self._note_arrival(name, now)
            est = None
            if deadline_s is not None:
                lam = self.rate_at_or_above(pol.priority, now)
                penalty = self._queue_penalty(now)
                est = self._p99_at(pol.priority, lam, now) + penalty
                if est > deadline_s:
                    reason = ("queue_depth"
                              if penalty > 0.0
                              and est - penalty <= deadline_s
                              else "deadline_risk")
                    if pol.degrade is not None:
                        self.counters[okeys.gate_counter(name, "degraded")] += 1
                        return Decision("degrade", name, reason,
                                        estimate_s=est,
                                        deadline_s=deadline_s,
                                        degrade=pol.degrade)
                    self.counters[okeys.gate_counter(name, "shed")] += 1
                    return Decision("shed", name, reason,
                                    estimate_s=est, deadline_s=deadline_s)
            self.counters[okeys.gate_counter(name, "admitted")] += 1
            return Decision("admit", name, "ok", estimate_s=est,
                            deadline_s=deadline_s)

    def note_hedge(self, klass: Optional[str] = None,
                   deadline_s: Optional[float] = None) -> bool:
        """A straggler hedge is OFFERED LOAD: it occupies a replica like
        any request.  The runtime announces each would-be hedge here; the
        gate counts it into the class's arrival window and answers
        whether there is headroom for it.  False suppresses the hedge —
        under overload a backup dispatch only amplifies the queue the
        primary is already stuck in."""
        now = _mono()
        pol = self.policy(klass)
        name = pol.name
        with self._lock:
            self.counters[okeys.gate_counter(name, "hedge_offered")] += 1
            self._note_arrival(name, now)
            if deadline_s is None:
                deadline_s = pol.default_deadline_s
            if deadline_s is not None:
                lam = self.rate_at_or_above(pol.priority, now)
                est = self._p99_at(pol.priority, lam, now) \
                    + self._queue_penalty(now)
                if est > deadline_s:
                    self.counters[okeys.gate_counter(name, "hedge_suppressed")] += 1
                    return False
            self.counters[okeys.gate_counter(name, "hedge_admitted")] += 1
            return True

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return dict(self.counters)


_BOTTOM = ClassPolicy("_bottom", priority=-(10 ** 9))


class _DefaultNodeConfig:
    max_batch = 1
    batch_wait_ms = 0.0
    batched_lowering = True
    target_replicas = 1
    competitive_replicas = 0


class _DefaultConfig:
    nodes: Dict[int, object] = {}

    def node(self, op_id: int):
        return _DefaultNodeConfig


_DEFAULT_CONFIG = _DefaultConfig()
