"""Retry policy + error taxonomy for at-least-once dispatch.

A serverless substrate assumes functions can fail mid-request and the
dataflow still answers inside its latency goal (Cloudburst executors are
"unpredictably slow" by design; Clipper's straggler mitigation makes the
same point for ensembles).  That requires a vocabulary the dispatcher can
act on:

* :class:`Transient` — the *attempt* failed, not the request: a worker
  died or was injected with a recoverable fault.  Redispatching the same
  work to another replica is expected to succeed.
* :class:`Permanent` — the *request* failed: user code raised, inputs are
  malformed.  Re-executing would fail identically (or worse, double-apply
  side effects), so permanent errors are delivered immediately.

Everything not typed here is treated as permanent: re-running unknown user
exceptions is how at-least-once systems corrupt state.

:class:`RetryPolicy` is capped exponential backoff with jitter, and it is
**deadline-budget-aware**: a retry whose backoff would land past the
request's ``deadline_t`` is not taken — the caller gets the typed failure
while it can still act on it, instead of a late answer nobody can use.

:class:`CompletionToken` is the idempotence primitive for at-least-once
execution.  Every dispatch attempt of a logical work item (the original,
its crash-recovery requeue, its straggler hedge) shares one token; exactly
one completion *claims* it and delivers the callback.  Losers fall silent:
no double demux, no double-counted metrics, no double future resolution.
KVS writes are made idempotent the same way, keyed by the item's
``dispatch_key`` (request, node, row ids) — see ``KVS.put_once``.
"""
from __future__ import annotations

import dataclasses
import random
import threading
from typing import Optional

from repro.obs.clock import now as _mono


class Transient(RuntimeError):
    """An attempt-scoped failure: redispatch to another replica is
    expected to succeed."""


class Permanent(RuntimeError):
    """A request-scoped failure: re-execution would fail identically (or
    double-apply side effects) — never retried."""


class TransientFault(Transient):
    """A typed transient error raised by fault injection (the chaos
    plan's ``transient`` kind)."""


class ExecutorLost(Transient):
    """The executor holding this work died or wedged; the item was (or
    could not be) redispatched."""


#: stdlib exception types that count as transient without wrapping —
#: infrastructure hiccups, not user-code failures.
TRANSIENT_TYPES = (ConnectionError, InterruptedError)


def is_transient(error: BaseException) -> bool:
    """Is this failure worth a redispatch?  Only typed transients (and a
    short list of infrastructure exceptions) qualify — unknown user
    exceptions are permanent by default."""
    if isinstance(error, Permanent):
        return False
    return isinstance(error, (Transient,) + TRANSIENT_TYPES)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff with jitter, bounded by the request's
    deadline budget.

    ``max_attempts`` counts *dispatches*, not retries: 3 means the
    original plus at most two redispatches.  ``jitter`` spreads a
    correlated failure burst (every member of a dead executor's queue
    retrying at once) across the backoff window.
    """
    max_attempts: int = 3
    base_s: float = 0.002
    multiplier: float = 2.0
    cap_s: float = 0.05
    jitter: float = 0.5              # +/- fraction of the raw backoff

    def backoff_s(self, attempt: int,
                  rng: Optional[random.Random] = None) -> float:
        """Backoff before dispatch attempt ``attempt + 1`` (0-based
        attempt index of the one that just failed)."""
        raw = min(self.cap_s, self.base_s * (self.multiplier ** attempt))
        if self.jitter <= 0:
            return raw
        r = (rng or random).uniform(-self.jitter, self.jitter)
        return max(0.0, raw * (1.0 + r))

    def next_delay(self, attempt: int, error: BaseException, now: float,
                   deadline_t: Optional[float] = None,
                   rng: Optional[random.Random] = None) -> Optional[float]:
        """Seconds to wait before redispatching, or None when this
        failure must be delivered: attempts exhausted, the error is
        permanent, or the backoff would land past the deadline."""
        if attempt + 1 >= self.max_attempts:
            return None
        if not is_transient(error):
            return None
        d = self.backoff_s(attempt, rng)
        if deadline_t is not None and now + d >= deadline_t:
            return None              # never retry past the budget
        return d


class CompletionToken:
    """One logical completion shared by every dispatch attempt of a work
    item.  ``claim()`` returns True exactly once, process-wide: the
    winner delivers the callback; crash-requeues, hedges, and stragglers
    that lose the race fall silent."""

    __slots__ = ("_lock", "_claimed", "winner", "claimed_t")

    def __init__(self):
        self._lock = threading.Lock()
        self._claimed = False
        self.winner: Optional[str] = None
        # monotonic time of the winning claim — attribution reads it to
        # split an exec span at the moment the result actually existed
        self.claimed_t: Optional[float] = None

    @property
    def claimed(self) -> bool:
        return self._claimed

    def claim(self, who: Optional[str] = None) -> bool:
        with self._lock:
            if self._claimed:
                return False
            self._claimed = True
            self.winner = who
            self.claimed_t = _mono()
            return True
