"""Request batcher — the paper's Batching optimization (§4, Fig 8).

Collects individual requests into one batched model invocation (pad to the
batch bucket), runs a single jitted call, and demultiplexes the results.
Used by the runtime's batch-aware executor; also usable standalone.

Deadline awareness (overload protection): items may carry an absolute
``deadline_t``.  The flush loop orders its backlog earliest-deadline-first
(plain FIFO when no item has a deadline, so the steady-state path is
untouched), and items whose deadline has already passed are *expired*
before dispatch — they fail fast with a typed
:class:`~repro.serving.admission.DeadlineExceeded` instead of occupying
batch slots, and ``on_drop`` + the ``expired`` counter surface every such
decision to the runtime's metrics.
"""
from __future__ import annotations

import threading
import queue
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.admission import DeadlineExceeded


#: queued by close() to wake the batch loop out of its poll immediately —
#: without it, close() blocks its caller (possibly an executor callback
#: thread on the serving path) for up to the full poll timeout
_WAKE = object()


class BatchItem:
    __slots__ = ("args", "event", "result", "error", "enqueue_t",
                 "deadline_t", "done")

    def __init__(self, args, deadline_t: Optional[float] = None):
        self.args = args
        self.event = threading.Event()
        self.result = None
        self.error: Optional[BaseException] = None
        self.enqueue_t = time.perf_counter()
        # absolute perf_counter time after which dispatching is pointless
        self.deadline_t = deadline_t
        # completion is idempotent: exactly ONE path (flush, expiry, close
        # drain, call-timeout) decrements the accepted-minus-completed
        # counter, whichever claims the item first
        self.done = False


class Batcher:
    """Micro-batching queue in front of a batched function.

    ``fn`` maps a list of per-request arg dicts to a list of results (it is
    responsible for stacking/padding).  ``max_batch`` bounds the bucket
    (paper default: 10); ``max_wait_ms`` bounds queueing delay.

    The wait deadline is *adaptive*: an EWMA of recent inter-arrival gaps
    decides how much of ``max_wait`` is actually worth spending.  Under
    dense traffic (gaps well inside the window) the full window is used and
    requests coalesce; under sparse traffic the wait shrinks toward zero —
    a lone request should not sit out the whole window when the expected
    next arrival lies beyond it.  ``adaptive_wait=False`` restores the
    fixed-deadline behavior.
    """

    #: EWMA smoothing for inter-arrival gaps.
    GAP_ALPHA = 0.3

    def __init__(self, fn: Callable[[List[Any]], List[Any]], *,
                 max_batch: int = 10, max_wait_ms: float = 2.0,
                 adaptive_wait: bool = True,
                 on_drop: Optional[Callable[[Any, BaseException],
                                            None]] = None):
        self.fn = fn
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1000.0
        self.adaptive_wait = adaptive_wait
        # called (args, error) for items failed by close()'s drain: a
        # caller whose waiters are callbacks buried in ``args`` (the
        # runtime) would otherwise hang them — nobody waits on
        # ``item.event`` there, so the event alone reaches no one
        self.on_drop = on_drop
        self.q: "queue.Queue[BatchItem]" = queue.Queue()
        self._stop = False
        self._lock = threading.Lock()       # serializes submit vs close
        # items accepted but not yet completed (queued OR popped into an
        # in-progress flush).  ``q.empty()`` alone is NOT a drain signal:
        # the batch loop pops items before running fn, so the queue can be
        # empty while a flush still holds live requests
        self._pending = 0
        # items popped off the queue but deferred past a full flush (EDF
        # overflow): owned by the batch loop thread; close() drains it
        # after joining that thread
        self._backlog: List[BatchItem] = []
        self._gap_ewma: Optional[float] = None
        self._last_submit_t: Optional[float] = None
        #: items failed before dispatch because their deadline passed
        self.expired = 0
        #: batches whose members were EDF-reordered out of arrival order
        self.reorders = 0
        #: whether the batch currently being flushed was EDF-reordered —
        #: written by the flush thread just before it invokes ``fn``, read
        #: by the batch fn (same thread) to annotate the batch-level span
        self.last_reordered = False
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        self.batch_sizes: List[int] = []

    def _complete(self, item: BatchItem) -> bool:
        """Claim ``item``'s completion: True for exactly one caller.  The
        winner decrements the pending counter; losers must not touch the
        item's result/error."""
        with self._lock:
            if item.done:
                return False
            item.done = True
            self._pending -= 1
            return True

    def submit(self, args, deadline_t: Optional[float] = None) -> BatchItem:
        item = BatchItem(args, deadline_t)
        with self._lock:
            if self._stop:
                raise RuntimeError("batcher is closed")
            if self._last_submit_t is not None:
                # clamp the sample: beyond ~4 windows a gap is just "idle",
                # and folding a minutes-long pause into the EWMA would pin
                # the wait at zero for dozens of requests into the next
                # dense burst (clamped, recovery takes ~3 samples)
                gap = min(item.enqueue_t - self._last_submit_t,
                          4.0 * self.max_wait)
                self._gap_ewma = gap if self._gap_ewma is None else \
                    ((1.0 - self.GAP_ALPHA) * self._gap_ewma
                     + self.GAP_ALPHA * gap)
            self._last_submit_t = item.enqueue_t
            self._pending += 1
            self.q.put(item)
        return item

    def pending(self) -> int:
        """Live requests in this batcher: accepted and not yet completed
        (queued, mid-flush, or dispatched awaiting their callback).  The
        counter the accountancy tests reconcile against offered traffic —
        it must return to zero after every fault-recovery path."""
        with self._lock:
            return self._pending

    def quiescent(self) -> bool:
        """True when the batcher holds NO live requests: nothing queued
        *and* no flush in progress.  This is the drain signal retirement
        logic must use — ``q.empty()`` races with an active flush whose
        popped items are still being served."""
        with self._lock:
            return self._pending == 0

    def reconfigure(self, *, max_batch: Optional[int] = None,
                    max_wait_ms: Optional[float] = None) -> None:
        """Hot-apply new batching knobs (the SLO controller's safe config
        delta).  The batch loop reads ``max_batch``/``max_wait`` fresh on
        every iteration, so the change takes effect on the next batch —
        in-flight batches are untouched."""
        with self._lock:
            if max_batch is not None:
                self.max_batch = max(1, int(max_batch))
            if max_wait_ms is not None:
                self.max_wait = max(0.0, float(max_wait_ms)) / 1000.0

    def arrival_gap_s(self) -> Optional[float]:
        """The EWMA of recent inter-arrival gaps (None before 2 submits) —
        the controller's cheap read on how dense this node's traffic is."""
        with self._lock:
            return self._gap_ewma

    def effective_wait(self) -> float:
        """How long the batch loop holds a partial batch open.  Arrivals
        expected WITHIN the window keep the full window (so every merge
        the fixed deadline achieved still happens); beyond it the wait
        shrinks linearly, reaching zero at twice the window — a lone
        request during sparse traffic fires immediately."""
        if not self.adaptive_wait:
            return self.max_wait
        with self._lock:
            gap = self._gap_ewma
        if gap is None or gap <= self.max_wait:
            return self.max_wait
        return max(0.0, 2.0 * self.max_wait - gap)

    def call(self, args, timeout: Optional[float] = 30.0,
             deadline_t: Optional[float] = None):
        item = self.submit(args, deadline_t)
        if not item.event.wait(timeout):
            if self._complete(item):
                # claimed: the flush loop will skip this item, and the
                # accepted-minus-completed counter stays honest — a timed
                # out call must never wedge quiescent()/retirement
                item.error = TimeoutError("batched call timed out")
                item.event.set()
                raise item.error
            # lost the race: the flush completed it concurrently with our
            # timeout — fall through to its real result
        if item.error is not None:
            raise item.error
        return item.result

    def _fail_undispatched(self, item: BatchItem, err: BaseException):
        """Fail an item that never reached a dispatch (expiry, close
        drain); no-op if another path already claimed it."""
        if not self._complete(item):
            return
        item.error = err
        item.event.set()
        if self.on_drop is not None:
            try:
                self.on_drop(item.args, err)
            except BaseException:
                pass

    def _collect(self) -> List[BatchItem]:
        """One flush worth of items: queue arrivals (holding the adaptive
        window open only when there is no deferred backlog) merged with
        the backlog, expired items failed, the rest EDF-ordered."""
        items: List[BatchItem] = []
        if self._backlog:
            # deferred items already waited out a window — drain whatever
            # the queue has RIGHT NOW and flush without holding another
            while len(items) + len(self._backlog) < self.max_batch:
                try:
                    nxt = self.q.get_nowait()
                except queue.Empty:
                    break
                if nxt is _WAKE:
                    break
                items.append(nxt)
        else:
            try:
                first = self.q.get(timeout=0.1)
            except queue.Empty:
                return []
            if first is _WAKE:
                return []                   # close() signal; re-check _stop
            items = [first]
            deadline = time.perf_counter() + self.effective_wait()
            while len(items) < self.max_batch:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                try:
                    nxt = self.q.get(timeout=remaining)
                except queue.Empty:
                    break
                if nxt is _WAKE:
                    break                   # flush what we hold, then exit
                items.append(nxt)
        pool = self._backlog + items        # backlog first: it is older
        self._backlog = []
        now = time.perf_counter()
        live: List[BatchItem] = []
        for it in pool:
            if it.done:
                continue                    # call() timeout already claimed
            if it.deadline_t is not None and it.deadline_t <= now:
                self.expired += 1
                self._fail_undispatched(it, DeadlineExceeded(
                    "deadline passed before dispatch",
                    deadline_s=it.deadline_t))
            else:
                live.append(it)
        reordered = False
        if any(it.deadline_t is not None for it in live):
            # earliest deadline first; deadline-less items ride behind in
            # arrival order (sort is stable).  Plain FIFO traffic never
            # reaches this sort.
            before = list(live)
            live.sort(key=lambda it: (it.deadline_t is None,
                                      it.deadline_t or 0.0))
            reordered = live != before
            if reordered:
                self.reorders += 1
        self.last_reordered = reordered
        self._backlog = live[self.max_batch:]
        return live[:self.max_batch]

    def _loop(self):
        while not self._stop:
            items = self._collect()
            if not items:
                continue
            self.batch_sizes.append(len(items))
            try:
                results = self.fn([it.args for it in items])
                for it, r in zip(items, results):
                    it.result = r
            except BaseException as e:  # propagate to all waiters
                for it in items:
                    it.error = e
            for it in items:
                if self._complete(it):
                    it.event.set()

    def close(self):
        """Stop the batch thread and fail anything still queued.

        ``submit``/``close`` are serialized by ``_lock``: after close wins
        the race, concurrent submitters get an immediate ``RuntimeError``
        instead of a silently dropped item, and items enqueued before the
        close are drained with an error so no waiter sits out its full
        ``call`` timeout."""
        with self._lock:
            if self._stop:
                return
            self._stop = True
        # wake the loop out of its poll so the join below returns
        # promptly — close() may run on an executor callback thread (the
        # generation-drain path), where a poll-timeout-long block would
        # stall the serving hot path
        self.q.put(_WAKE)
        if threading.current_thread() is not self._thread:
            self._thread.join(timeout=1.0)
        # drain the EDF backlog as well as the queue: deferred items are
        # just as undispatched as queued ones
        leftovers, self._backlog = list(self._backlog), []
        while True:
            try:
                it = self.q.get_nowait()
            except queue.Empty:
                break
            if it is _WAKE:
                continue
            leftovers.append(it)
        for it in leftovers:
            self._fail_undispatched(
                it, RuntimeError("batcher closed before dispatch"))
