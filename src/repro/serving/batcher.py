"""Request batcher — the paper's Batching optimization (§4, Fig 8).

Collects individual requests into one batched model invocation (pad to the
batch bucket), runs a single jitted call, and demultiplexes the results.
Used by the runtime's batch-aware executor; also usable standalone.
"""
from __future__ import annotations

import threading
import queue
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np


#: queued by close() to wake the batch loop out of its poll immediately —
#: without it, close() blocks its caller (possibly an executor callback
#: thread on the serving path) for up to the full poll timeout
_WAKE = object()


class BatchItem:
    __slots__ = ("args", "event", "result", "error", "enqueue_t")

    def __init__(self, args):
        self.args = args
        self.event = threading.Event()
        self.result = None
        self.error: Optional[BaseException] = None
        self.enqueue_t = time.perf_counter()


class Batcher:
    """Micro-batching queue in front of a batched function.

    ``fn`` maps a list of per-request arg dicts to a list of results (it is
    responsible for stacking/padding).  ``max_batch`` bounds the bucket
    (paper default: 10); ``max_wait_ms`` bounds queueing delay.

    The wait deadline is *adaptive*: an EWMA of recent inter-arrival gaps
    decides how much of ``max_wait`` is actually worth spending.  Under
    dense traffic (gaps well inside the window) the full window is used and
    requests coalesce; under sparse traffic the wait shrinks toward zero —
    a lone request should not sit out the whole window when the expected
    next arrival lies beyond it.  ``adaptive_wait=False`` restores the
    fixed-deadline behavior.
    """

    #: EWMA smoothing for inter-arrival gaps.
    GAP_ALPHA = 0.3

    def __init__(self, fn: Callable[[List[Any]], List[Any]], *,
                 max_batch: int = 10, max_wait_ms: float = 2.0,
                 adaptive_wait: bool = True,
                 on_drop: Optional[Callable[[Any, BaseException],
                                            None]] = None):
        self.fn = fn
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1000.0
        self.adaptive_wait = adaptive_wait
        # called (args, error) for items failed by close()'s drain: a
        # caller whose waiters are callbacks buried in ``args`` (the
        # runtime) would otherwise hang them — nobody waits on
        # ``item.event`` there, so the event alone reaches no one
        self.on_drop = on_drop
        self.q: "queue.Queue[BatchItem]" = queue.Queue()
        self._stop = False
        self._lock = threading.Lock()       # serializes submit vs close
        # items accepted but not yet completed (queued OR popped into an
        # in-progress flush).  ``q.empty()`` alone is NOT a drain signal:
        # the batch loop pops items before running fn, so the queue can be
        # empty while a flush still holds live requests
        self._pending = 0
        self._gap_ewma: Optional[float] = None
        self._last_submit_t: Optional[float] = None
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        self.batch_sizes: List[int] = []

    def submit(self, args) -> BatchItem:
        item = BatchItem(args)
        with self._lock:
            if self._stop:
                raise RuntimeError("batcher is closed")
            if self._last_submit_t is not None:
                # clamp the sample: beyond ~4 windows a gap is just "idle",
                # and folding a minutes-long pause into the EWMA would pin
                # the wait at zero for dozens of requests into the next
                # dense burst (clamped, recovery takes ~3 samples)
                gap = min(item.enqueue_t - self._last_submit_t,
                          4.0 * self.max_wait)
                self._gap_ewma = gap if self._gap_ewma is None else \
                    ((1.0 - self.GAP_ALPHA) * self._gap_ewma
                     + self.GAP_ALPHA * gap)
            self._last_submit_t = item.enqueue_t
            self._pending += 1
            self.q.put(item)
        return item

    def quiescent(self) -> bool:
        """True when the batcher holds NO live requests: nothing queued
        *and* no flush in progress.  This is the drain signal retirement
        logic must use — ``q.empty()`` races with an active flush whose
        popped items are still being served."""
        with self._lock:
            return self._pending == 0

    def reconfigure(self, *, max_batch: Optional[int] = None,
                    max_wait_ms: Optional[float] = None) -> None:
        """Hot-apply new batching knobs (the SLO controller's safe config
        delta).  The batch loop reads ``max_batch``/``max_wait`` fresh on
        every iteration, so the change takes effect on the next batch —
        in-flight batches are untouched."""
        with self._lock:
            if max_batch is not None:
                self.max_batch = max(1, int(max_batch))
            if max_wait_ms is not None:
                self.max_wait = max(0.0, float(max_wait_ms)) / 1000.0

    def arrival_gap_s(self) -> Optional[float]:
        """The EWMA of recent inter-arrival gaps (None before 2 submits) —
        the controller's cheap read on how dense this node's traffic is."""
        with self._lock:
            return self._gap_ewma

    def effective_wait(self) -> float:
        """How long the batch loop holds a partial batch open.  Arrivals
        expected WITHIN the window keep the full window (so every merge
        the fixed deadline achieved still happens); beyond it the wait
        shrinks linearly, reaching zero at twice the window — a lone
        request during sparse traffic fires immediately."""
        if not self.adaptive_wait:
            return self.max_wait
        with self._lock:
            gap = self._gap_ewma
        if gap is None or gap <= self.max_wait:
            return self.max_wait
        return max(0.0, 2.0 * self.max_wait - gap)

    def call(self, args, timeout: Optional[float] = 30.0):
        item = self.submit(args)
        if not item.event.wait(timeout):
            raise TimeoutError("batched call timed out")
        if item.error is not None:
            raise item.error
        return item.result

    def _loop(self):
        while not self._stop:
            try:
                first = self.q.get(timeout=0.1)
            except queue.Empty:
                continue
            if first is _WAKE:
                continue                    # close() signal; re-check _stop
            items = [first]
            deadline = time.perf_counter() + self.effective_wait()
            while len(items) < self.max_batch:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                try:
                    nxt = self.q.get(timeout=remaining)
                except queue.Empty:
                    break
                if nxt is _WAKE:
                    break                   # flush what we hold, then exit
                items.append(nxt)
            self.batch_sizes.append(len(items))
            try:
                results = self.fn([it.args for it in items])
                for it, r in zip(items, results):
                    it.result = r
            except BaseException as e:  # propagate to all waiters
                for it in items:
                    it.error = e
            for it in items:
                it.event.set()
            with self._lock:
                self._pending -= len(items)

    def close(self):
        """Stop the batch thread and fail anything still queued.

        ``submit``/``close`` are serialized by ``_lock``: after close wins
        the race, concurrent submitters get an immediate ``RuntimeError``
        instead of a silently dropped item, and items enqueued before the
        close are drained with an error so no waiter sits out its full
        ``call`` timeout."""
        with self._lock:
            if self._stop:
                return
            self._stop = True
        # wake the loop out of its poll so the join below returns
        # promptly — close() may run on an executor callback thread (the
        # generation-drain path), where a poll-timeout-long block would
        # stall the serving hot path
        self.q.put(_WAKE)
        if threading.current_thread() is not self._thread:
            self._thread.join(timeout=1.0)
        while True:
            try:
                it = self.q.get_nowait()
            except queue.Empty:
                break
            if it is _WAKE:
                continue
            it.error = RuntimeError("batcher closed before dispatch")
            it.event.set()
            if self.on_drop is not None:
                try:
                    self.on_drop(it.args, it.error)
                except BaseException:
                    pass
            with self._lock:
                self._pending -= 1
