"""Serving-layer building blocks: request batching, overload protection
(admission control, request classes, deadlines), and fault tolerance
(typed retries, fault injection, straggler hedging).

* :mod:`repro.serving.batcher` — deadline-aware micro-batching
  (``Batcher``): adaptive coalescing windows, earliest-deadline-first
  backlog ordering, pre-dispatch expiry;
* :mod:`repro.serving.admission` — the front-door gate
  (``AdmissionController``): per-class token buckets plus a
  priority-ordered M/M/c estimator check blended with live executor
  queue depth, typed ``Overloaded`` / ``DeadlineExceeded`` fast-fail
  errors, and ``DegradePolicy``-based degraded serving for low-priority
  traffic;
* :mod:`repro.serving.retry` — the ``Transient`` / ``Permanent`` error
  taxonomy, deadline-budget-aware ``RetryPolicy`` backoff, and the
  ``CompletionToken`` exactly-once-delivery primitive for at-least-once
  redispatch;
* :mod:`repro.serving.faults` — seeded deterministic fault injection
  (``FaultPlan`` / ``FaultInjector``: crash, hang, transient) and
  profile-derived straggler-hedge delays (``install_hedging``).
"""
from repro.serving.admission import (AdmissionController, ClassPolicy,
                                     DeadlineExceeded, Decision, Overloaded,
                                     TokenBucket, default_classes)
from repro.serving.batcher import Batcher, BatchItem
from repro.serving.faults import (FaultInjector, FaultPlan, FaultSpec,
                                  hedge_delays_from_profile, install_hedging)
from repro.serving.retry import (CompletionToken, ExecutorLost, Permanent,
                                 RetryPolicy, Transient, TransientFault,
                                 is_transient)

__all__ = [
    "AdmissionController", "Batcher", "BatchItem", "ClassPolicy",
    "CompletionToken", "DeadlineExceeded", "Decision", "ExecutorLost",
    "FaultInjector", "FaultPlan", "FaultSpec", "Overloaded", "Permanent",
    "RetryPolicy", "TokenBucket", "Transient", "TransientFault",
    "default_classes", "hedge_delays_from_profile", "install_hedging",
    "is_transient",
]
