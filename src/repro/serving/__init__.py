"""Serving-layer building blocks: request batching and overload
protection (admission control, request classes, deadlines).

* :mod:`repro.serving.batcher` — deadline-aware micro-batching
  (``Batcher``): adaptive coalescing windows, earliest-deadline-first
  backlog ordering, pre-dispatch expiry;
* :mod:`repro.serving.admission` — the front-door gate
  (``AdmissionController``): per-class token buckets plus a
  priority-ordered M/M/c estimator check, typed ``Overloaded`` /
  ``DeadlineExceeded`` fast-fail errors, and ``DegradePolicy``-based
  degraded serving for low-priority traffic.
"""
from repro.serving.admission import (AdmissionController, ClassPolicy,
                                     DeadlineExceeded, Decision, Overloaded,
                                     TokenBucket, default_classes)
from repro.serving.batcher import Batcher, BatchItem

__all__ = [
    "AdmissionController", "Batcher", "BatchItem", "ClassPolicy",
    "DeadlineExceeded", "Decision", "Overloaded", "TokenBucket",
    "default_classes",
]
