"""Static plan verification: abstract interpretation + invariant
checking + resource linting over the ``PhysicalPlan`` IR, before any
XLA trace and before any traffic.

Entry points:

* :func:`analyze` — run shape/dtype/placement inference and every
  registered :class:`~repro.analysis.checks.Check` over one plan,
  returning a :class:`~repro.analysis.diagnostics.Report`.
* ``compile_flow(verify=...)`` — the compiler wiring (see
  ``repro.core.compiler``): ``verify=True``/``"error"`` raises
  :class:`~repro.analysis.diagnostics.VerificationError` on any
  severity=error diagnostic, ``"warn"`` only attaches the report.
* ``PassPipeline(verify=True)`` — differential pass checking: every
  pass must preserve inferred edge types (CF502) and introduce no new
  error diagnostics (CF501).
* ``python -m repro.check`` — the CLI linter over example/benchmark
  flows (see ``repro.analysis.cli``).
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.analysis.checks import (AnalysisContext, Check,
                                   default_checks, device_edge_info)
from repro.analysis.diagnostics import (CODES, Diagnostic, Report,
                                        VerificationError)
from repro.analysis.infer import (EdgeType, edge_signature, infer,
                                  specs_from_table)
from repro.analysis.memory import footprint_diagnostics

__all__ = [
    "AnalysisContext", "CODES", "Check", "Diagnostic", "EdgeType",
    "Report", "VerificationError", "analyze", "default_checks",
    "device_edge_info", "edge_signature", "infer", "pass_snapshot",
    "specs_from_table", "verify_pass_step",
]


def analyze(plan, *, runtime=None, plan_config=None,
            input_specs: Optional[Dict[str, object]] = None,
            sample=None, max_batch: Optional[int] = None,
            budget_bytes: Optional[int] = None,
            checks=None, name: str = "plan",
            check_buckets: bool = True) -> Report:
    """Verify one plan: infer per-edge types/shapes, then run every
    invariant check.  ``sample`` (a request Table) is a convenience
    source for ``input_specs``; ``budget_bytes`` defaults to the
    runtime's per-executor cache budget when a runtime is given."""
    report = Report(plan_name=name)
    if input_specs is None and sample is not None:
        input_specs = specs_from_table(sample)
    types, report = infer(plan, input_specs, report,
                          check_buckets=check_buckets)
    ctx = AnalysisContext(plan=plan, types=types, runtime=runtime,
                          plan_config=plan_config, max_batch=max_batch,
                          budget_bytes=budget_bytes)
    if budget_bytes is None and runtime is not None:
        budget_bytes = getattr(getattr(runtime, "pool", None),
                               "cache_bytes", None)
    for check in (checks if checks is not None else default_checks()):
        try:
            report.extend(check.run(ctx))
        except Exception as e:          # a broken check must not mask
            raise RuntimeError(         # real diagnostics silently
                f"static check {check.name!r} crashed: {e}") from e
    report.extend(footprint_diagnostics(
        plan, types, budget_bytes=budget_bytes,
        max_batch_of=lambda op_id: (
            ctx.node_max_batch(op_id)
            if plan.op(op_id).batching else 1)))
    return report


# -- differential pass checking (PassPipeline(verify=True)) ----------------

def pass_snapshot(plan):
    """Structural snapshot of one plan for differential pass checking:
    (error-code counts, per-edge type signature).  Runs the structural
    checks with no runtime/specs — cheap, and identical context before
    and after each pass so only the pass's own effect shows up."""
    import collections

    report = Report(plan_name="pipeline")
    types, report = infer(plan, None, report, check_buckets=False)
    ctx = AnalysisContext(plan=plan, types=types)
    for check in default_checks():
        report.extend(check.run(ctx))
    codes = collections.Counter(d.code for d in report.errors())
    return codes, edge_signature(types), report


def verify_pass_step(pass_name: str, plan, baseline):
    """Compare a plan against the pre-pass snapshot; raise
    :class:`VerificationError` if the pass introduced new error
    diagnostics (CF501) or changed the inferred type of an edge that
    survived the pass (CF502).  Returns the new snapshot to feed the
    next pass."""
    base_codes, base_sig, _ = baseline
    codes, sig, rep = pass_snapshot(plan)
    vr = Report(plan_name=f"after pass {pass_name}")
    for code, n in sorted(codes.items()):
        extra = n - base_codes.get(code, 0)
        if extra > 0:
            first = next(d for d in rep.errors() if d.code == code)
            vr.add(Diagnostic(
                "CF501",
                f"pass {pass_name!r} introduced {extra} new {code} "
                f"error(s); first: {first.message}",
                hint="the pass produced a plan the structural checks "
                     "reject — fix the pass, not the plan"))
    for op_id, s in sorted(base_sig.items()):
        if op_id in sig and sig[op_id] != s:
            vr.add(Diagnostic(
                "CF502",
                f"pass {pass_name!r} changed the inferred edge type of "
                f"op {op_id}: {s} -> {sig[op_id]}",
                op_id=op_id,
                hint="passes must preserve per-edge schemas/groupings "
                     "for ops they keep"))
    if not vr.ok:
        raise VerificationError(
            vr, context=f"pipeline self-verification after {pass_name!r}")
    return codes, sig, rep
