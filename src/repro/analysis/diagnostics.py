"""Structured diagnostics for the static plan verifier.

Every finding the verifier makes is a :class:`Diagnostic` with a stable
code (``CF101``-style, greppable and testable), a severity, the op/edge
it anchors to, and a fix hint — the shape PRETZEL argues white-box
pipeline analysis should surface *before* traffic, not as a runtime
stack trace.  A :class:`Report` aggregates them per analyzed plan and
renders the CLI's diagnostic table; :class:`VerificationError` is what
``compile_flow(verify="error")`` raises, carrying the report so callers
(and tests) can inspect exactly what fired.

Code ranges:

* ``CF1xx`` — abstract interpretation (shapes/dtypes/traceability)
* ``CF2xx`` — IR invariants (donation, residency, wait-any, buckets,
  executor classes)
* ``CF3xx`` — resource bounds (device-memory footprint)
* ``CF4xx`` — observability lints (metric key registry)
* ``CF5xx`` — pipeline self-verification (differential pass checking)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

#: code -> (title, default severity).  The registry is the single source
#: of truth: a Diagnostic with an unknown code is a programming error.
CODES: Dict[str, Tuple[str, str]] = {
    "CF101": ("edge shape/dtype mismatch", "error"),
    "CF102": ("step not traceable for jit lowering", "error"),
    "CF103": ("kernel tile params incompatible with operand shapes",
              "error"),
    "CF104": ("filter return type cannot lower to a mask", "warning"),
    "CF201": ("buffer donation on a shared/fan-out edge", "error"),
    "CF202": ("device-resident edge crosses executor classes", "error"),
    "CF203": ("wait-any arity vs competitive replica count", "error"),
    "CF204": ("batch buckets do not cover max_batch", "warning"),
    "CF205": ("placement names a class with zero executors", "error"),
    "CF206": ("all executors of a class are reserved", "error"),
    "CF301": ("static device-memory footprint exceeds budget", "error"),
    "CF401": ("recorded metric key not in the obs key registry",
              "warning"),
    "CF501": ("pass introduced new error diagnostics", "error"),
    "CF502": ("pass changed inferred edge types", "error"),
}

_SEV_ORDER = {"error": 0, "warning": 1, "info": 2}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding: where, what, how bad, and how to fix it."""
    code: str
    message: str
    severity: str = ""            # defaults from CODES when empty
    op_id: Optional[int] = None
    edge: Optional[Tuple[int, int]] = None    # (producer, consumer)
    hint: str = ""

    def __post_init__(self):
        if self.code not in CODES:
            raise ValueError(f"unknown diagnostic code {self.code!r}")
        if not self.severity:
            object.__setattr__(self, "severity", CODES[self.code][1])
        if self.severity not in _SEV_ORDER:
            raise ValueError(f"unknown severity {self.severity!r}")

    @property
    def title(self) -> str:
        return CODES[self.code][0]

    def where(self) -> str:
        if self.edge is not None:
            return f"edge {self.edge[0]}->{self.edge[1]}"
        if self.op_id is not None:
            return f"op {self.op_id}"
        return "plan"

    def __str__(self) -> str:
        s = f"{self.code} {self.severity} [{self.where()}]: {self.message}"
        if self.hint:
            s += f" (hint: {self.hint})"
        return s


class Report:
    """All diagnostics from one verification run."""

    def __init__(self, plan_name: str = "plan"):
        self.plan_name = plan_name
        self.diagnostics: List[Diagnostic] = []

    def add(self, diag: Diagnostic) -> None:
        self.diagnostics.append(diag)

    def extend(self, diags) -> None:
        self.diagnostics.extend(diags)

    def by_code(self, code: str) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.code == code]

    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "error"]

    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "warning"]

    @property
    def ok(self) -> bool:
        return not self.errors()

    def sorted(self) -> List[Diagnostic]:
        return sorted(self.diagnostics,
                      key=lambda d: (_SEV_ORDER[d.severity], d.code,
                                     d.op_id if d.op_id is not None else -1))

    def table(self) -> str:
        """The CLI's diagnostic table: one row per finding, worst first."""
        if not self.diagnostics:
            return f"{self.plan_name}: clean (no diagnostics)"
        rows = [("CODE", "SEV", "WHERE", "MESSAGE")]
        for d in self.sorted():
            rows.append((d.code, d.severity, d.where(),
                         d.message + (f"  [hint: {d.hint}]" if d.hint
                                      else "")))
        widths = [max(len(r[i]) for r in rows) for i in range(3)]
        lines = [f"-- {self.plan_name}: {len(self.errors())} error(s), "
                 f"{len(self.warnings())} warning(s) --"]
        for r in rows:
            lines.append(f"{r[0]:<{widths[0]}}  {r[1]:<{widths[1]}}  "
                         f"{r[2]:<{widths[2]}}  {r[3]}")
        return "\n".join(lines)

    def __repr__(self) -> str:
        return (f"Report({self.plan_name!r}, errors={len(self.errors())}, "
                f"warnings={len(self.warnings())})")


class VerificationError(RuntimeError):
    """Raised when verification finds severity=error diagnostics and the
    caller asked for errors to be fatal (``compile_flow(verify=...)``,
    ``PassPipeline(verify=True)``)."""

    def __init__(self, report: Report, context: str = ""):
        self.report = report
        head = f"plan verification failed ({context})" if context \
            else "plan verification failed"
        msgs = "\n".join(str(d) for d in report.errors())
        super().__init__(f"{head}:\n{msgs}")
