"""Abstract interpretation over the ``PhysicalPlan`` IR.

Propagates per-edge ``jax.ShapeDtypeStruct``s through the topo-sorted
plan with ``jax.eval_shape`` — tracing annotated map/filter/kernel/
ModelOp steps abstractly, never compiling anything — so shape/dtype
mismatches (CF101) and non-traceable steps destined for jit lowering
(CF102) surface *before the first XLA trace*.  Fused chains are walked
step by step (the live router would too), and batch-lowered chains are
re-evaluated under ``jax.vmap`` at every padding bucket, which is
exactly the set of shapes ``warm_deployment`` will trace.

Shape inference needs concrete input shapes: pass ``input_specs`` (a
``{column: ShapeDtypeStruct}`` dict, or derive one from a sample request
with :func:`specs_from_table`).  Without specs — or without jax — the
shape-dependent diagnostics skip gracefully; schema/placement/residency
inference still runs off the IR's type annotations alone.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.analysis.diagnostics import Diagnostic, Report
from repro.core import operators as ops
from repro.core.ir import SOURCE_ID, PhysicalPlan
from repro.core.lowering import BatchedJittedFuse, array_annotation

try:                                    # mirrors core.lowering's guard
    import jax
except Exception:                       # pragma: no cover
    jax = None

#: exception types that mean "the step cannot be traced" (data-dependent
#: python control flow, concretization of tracers) as opposed to a plain
#: shape error.  Resolved lazily because jax may be absent.
def _trace_error_types():
    errs = []
    for name in ("ConcretizationTypeError", "TracerArrayConversionError",
                 "TracerBoolConversionError", "TracerIntegerConversionError"):
        t = getattr(getattr(jax, "errors", None), name, None)
        if t is not None:
            errs.append(t)
    return tuple(errs)


@dataclasses.dataclass
class EdgeType:
    """What the verifier knows about one plan edge (an op's output)."""
    schema: Tuple[Tuple[str, type], ...]
    grouping: Optional[str] = None
    #: per-column ShapeDtypeStructs at ROW level (no batch dim); None
    #: entries are columns whose shape is unknown (non-array types,
    #: un-analyzable producers)
    specs: Optional[Tuple[object, ...]] = None
    placement: str = "cpu"
    device_resident: bool = False

    def spec_map(self) -> Dict[str, object]:
        if self.specs is None:
            return {}
        return {name: s for (name, _t), s in zip(self.schema, self.specs)
                if s is not None}


def specs_from_table(table) -> Optional[Dict[str, object]]:
    """Derive row-level input specs from a sample request table (row 0's
    values).  Non-numeric columns map to None (shape unknown)."""
    if jax is None or not getattr(table, "rows", None):
        return None
    out: Dict[str, object] = {}
    row = table.rows[0]
    for (name, _t), v in zip(table.schema, row.values):
        try:
            a = np.asarray(v)
            if a.dtype.kind in "OUS":       # strings/objects: no shape
                out[name] = None
            else:
                out[name] = jax.ShapeDtypeStruct(a.shape, a.dtype)
        except Exception:
            out[name] = None
    return out


def _chain_of(op) -> Optional[List[object]]:
    """The map/filter step list of a fusable op (Fuse and its jitted
    subclasses), a single-element list for a bare Map/Filter, or None
    for ops abstract interpretation cannot step through."""
    if isinstance(op, ops.Fuse):
        return list(op.ops)
    if isinstance(op, (ops.Map, ops.Filter)):
        return [op]
    return None


def _jit_destined(phys_op) -> bool:
    """Will this op's steps run under jit?  Already-lowered chains did;
    gpu-placed fusable chains will when jit lowering is on."""
    from repro.core.lowering import JittedFuse
    if isinstance(phys_op.op, JittedFuse):
        return True
    return phys_op.placement == "gpu" and _chain_of(phys_op.op) is not None


def _eval_step(step, in_specs, *, vmapped: bool = False):
    """eval_shape one map/filter step against positional column specs;
    returns the output spec list (filters pass their input through).
    ``vmapped`` means the specs already carry a leading batch dim and the
    step runs under ``jax.vmap`` (the batched-lowered dispatch shape)."""
    fn = step.fn
    if vmapped:
        fn = jax.vmap(fn)
    out = jax.eval_shape(fn, *in_specs)
    if isinstance(step, ops.Filter):
        return list(in_specs)       # a filter only drops rows
    return list(out) if isinstance(out, (tuple, list)) else [out]


def _steps_analyzable(steps, in_specs) -> bool:
    """All step annotations are jax arrays and every input column has a
    known spec — the precondition for abstract interpretation."""
    if jax is None or in_specs is None or any(s is None for s in in_specs):
        return False
    for s in steps:
        # a fused chain can carry non-Map/Filter sub-ops (e.g. a Lookup
        # merged in by the locality pass) — those have no annotations and
        # no pure step function, so the chain is not abstractly steppable
        arg_types = getattr(s, "_arg_types", None)
        if arg_types is None:
            return False
        if any(not array_annotation(t) for t in arg_types):
            return False
        if isinstance(s, ops.Map) and \
                any(not array_annotation(t) for _n, t in s._schema):
            return False
    return True


def _walk_chain(phys_op, steps, in_specs, report: Report,
                *, bucket: int = 0) -> Optional[List[object]]:
    """Step through a (possibly fused) chain with eval_shape, emitting
    CF101/CF102 on failure.  Returns the final column specs or None."""
    destined = _jit_destined(phys_op)
    cur = list(in_specs)
    if bucket:      # the padded dispatch shape: batch dim added ONCE
        cur = [jax.ShapeDtypeStruct((bucket,) + tuple(s.shape), s.dtype)
               for s in cur]
    trace_errs = _trace_error_types()
    for step in steps:
        at = f" at bucket {bucket}" if bucket else ""
        try:
            cur = _eval_step(step, cur, vmapped=bool(bucket))
        except trace_errs as e:
            if destined:
                report.add(Diagnostic(
                    "CF102", f"step {step.name!r} is not traceable for "
                    f"jit lowering{at}: {_first_line(e)}",
                    op_id=phys_op.op_id,
                    hint="remove data-dependent python control flow or "
                         "drop the jax.Array annotations so the step "
                         "stays eager"))
            return None
        except Exception as e:
            report.add(Diagnostic(
                "CF101", f"step {step.name!r} rejects the inferred input "
                f"shapes{at} "
                f"({', '.join(_fmt_spec(s) for s in cur)}): "
                f"{_first_line(e)}",
                op_id=phys_op.op_id,
                hint="fix the producing op's output shape or this step's "
                     "expected operand shapes"))
            return None
    if bucket:      # strip the batch dim back off for edge storage
        cur = [jax.ShapeDtypeStruct(tuple(s.shape[1:]), s.dtype)
               for s in cur]
    return cur


def _fmt_spec(s) -> str:
    try:
        return f"{np.dtype(s.dtype).name}{list(s.shape)}"
    except Exception:
        return repr(s)


def _first_line(e: BaseException) -> str:
    return f"{type(e).__name__}: {str(e).splitlines()[0] if str(e) else ''}"


def infer(plan: PhysicalPlan,
          input_specs: Optional[Dict[str, object]] = None,
          report: Optional[Report] = None,
          *, check_buckets: bool = True
          ) -> Tuple[Dict[int, EdgeType], Report]:
    """Propagate schemas + shape specs through the plan.  Returns the
    per-op-id edge types and the report the walk appended to."""
    report = report if report is not None else Report()
    types: Dict[int, EdgeType] = {}

    # schemas/groupings come from the IR typechecker; a failure there IS
    # the shape/dtype-mismatch diagnostic, at schema granularity
    try:
        schemas = plan.typecheck()
    except Exception as e:
        report.add(Diagnostic(
            "CF101", f"plan typecheck failed: {_first_line(e)}",
            hint="fix the op annotations so consecutive schemas agree"))
        return types, report

    src_specs = None
    if input_specs is not None and jax is not None:
        src_specs = tuple(input_specs.get(name)
                          for name, _t in plan.input_schema)
    types[SOURCE_ID] = EdgeType(schema=tuple(plan.input_schema),
                                specs=src_specs)

    for o in plan.ops:
        schema, grouping = schemas[o.op_id]
        et = EdgeType(schema=tuple(schema), grouping=grouping,
                      placement=o.placement,
                      device_resident=o.device_resident)
        ins = [types.get(i) for i in o.inputs]
        steps = _chain_of(o.op)
        if steps is not None and len(ins) == 1 and ins[0] is not None:
            in_specs = ins[0].specs
            if _steps_analyzable(steps, in_specs):
                out = _walk_chain(o, steps, list(in_specs), report)
                if out is not None and isinstance(o.op, BatchedJittedFuse) \
                        and check_buckets:
                    for b in o.op.bucket_sizes:
                        if _walk_chain(o, steps, list(in_specs), report,
                                       bucket=b) is None:
                            break       # one bucket failure explains all
                if out is not None and len(out) == len(schema):
                    et.specs = tuple(out)
        elif isinstance(o.op, (ops.AnyOf, ops.Union)) and ins and \
                all(i is not None and i.specs is not None for i in ins):
            # pass-through ops: every input must agree; AnyOf/Union
            # schemas were already checked compatible by the typechecker
            first = ins[0].specs
            if all(_specs_eq(i.specs, first) for i in ins):
                et.specs = first
        types[o.op_id] = et
    return types, report


def _specs_eq(a, b) -> bool:
    if a is None or b is None or len(a) != len(b):
        return False
    for x, y in zip(a, b):
        if x is None or y is None:
            if x is not y:
                return False
            continue
        if tuple(x.shape) != tuple(y.shape) or \
                np.dtype(x.dtype) != np.dtype(y.dtype):
            return False
    return True


def edge_signature(types: Dict[int, EdgeType]) -> Dict[int, Tuple]:
    """A comparable per-op-id summary of inferred edge types — what the
    differential pass verifier (CF502) asserts every pass preserves."""
    out: Dict[int, Tuple] = {}
    for op_id, et in types.items():
        cols = tuple((name, getattr(t, "__name__", str(t)))
                     for name, t in et.schema)
        out[op_id] = (cols, et.grouping)
    return out
