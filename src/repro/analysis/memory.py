"""Static device-memory footprint bound (CF301).

``warm_deployment`` walks every batch-lowered chain at every padding
bucket — including the covering bucket a full batcher merge pads to —
so the first warm materializes each chain's live columns at the LARGEST
bucket.  This module bounds that footprint statically (live columns ×
bucket cap × dtype itemsize, walked step by step through each fused
chain with ``jax.eval_shape``) and diagnoses chains whose peak exceeds
a configurable budget *before* the warm OOMs the device.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.analysis.diagnostics import Diagnostic
from repro.analysis.infer import EdgeType, _chain_of, _eval_step, jax
from repro.core.ir import PhysicalPlan
from repro.core.lowering import BatchedJittedFuse, bucket_rows


def _row_bytes(specs) -> int:
    total = 0
    for s in specs:
        if s is None:
            return -1
        total += int(np.prod(s.shape, dtype=np.int64) *
                     np.dtype(s.dtype).itemsize)
    return total


def chain_peak_row_bytes(steps, in_specs) -> Optional[int]:
    """Peak live bytes per ROW through a fused chain: at every step the
    step's inputs and outputs are live simultaneously (donation can at
    best alias one of them — we bound, not model, the allocator)."""
    if jax is None:
        return None
    cur = list(in_specs)
    if any(s is None for s in cur):
        return None
    peak = _row_bytes(cur)
    for step in steps:
        try:
            nxt = _eval_step(step, cur)
        except Exception:
            return None         # the shape checks own that failure
        live = _row_bytes(cur) + _row_bytes(nxt)
        peak = max(peak, live)
        cur = nxt
    return peak


def footprint_diagnostics(plan: PhysicalPlan, types: Dict[int, EdgeType],
                          *, budget_bytes: Optional[int],
                          max_batch_of=None) -> List[Diagnostic]:
    """CF301 for every device-resident batch-lowered chain.  ``types``
    must carry inferred input specs (from :func:`repro.analysis.infer`);
    chains without specs are skipped.  ``max_batch_of(op_id)`` supplies
    the effective merge cap (defaults to 1 = no batching)."""
    out: List[Diagnostic] = []
    if budget_bytes is None or budget_bytes <= 0:
        return out
    for o in plan.ops:
        op = o.op
        if not isinstance(op, BatchedJittedFuse):
            continue
        steps = _chain_of(op)
        if steps is None or len(o.inputs) != 1:
            continue
        et = types.get(o.inputs[0])
        if et is None or et.specs is None:
            continue
        per_row = chain_peak_row_bytes(steps, list(et.specs))
        if per_row is None or per_row < 0:
            continue
        mb = int(max_batch_of(o.op_id)) if max_batch_of is not None else 1
        sizes = set(op.bucket_sizes or (1,))
        if mb > 1:
            sizes.add(bucket_rows(mb, op.bucket_sizes))
        cap = max(sizes)
        peak = per_row * cap
        if peak > budget_bytes:
            out.append(Diagnostic(
                "CF301",
                f"op {o.op_id} ({op.name}) peaks at "
                f"~{peak / 2**20:.1f} MiB on device at bucket {cap} "
                f"({per_row / 2**20:.3f} MiB/row), over the "
                f"{budget_bytes / 2**20:.1f} MiB budget — "
                f"warm_deployment would OOM on first warm",
                op_id=o.op_id,
                hint="shrink the bucket table / max_batch, split the "
                     "chain, or raise the device-memory budget"))
    return out
