"""Composable IR invariant checkers.

Each :class:`Check` inspects one invariant of a ``PhysicalPlan`` (plus
optional runtime/plan-config context) and emits structured
:class:`~repro.analysis.diagnostics.Diagnostic`s.  The residency checks
mirror ``RuntimeDag.from_plan``'s device-edge analysis statically, so
what the verifier calls a device edge is exactly what the runtime will
treat as one.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.analysis.diagnostics import Diagnostic
from repro.analysis.infer import EdgeType, _chain_of
from repro.core import operators as ops
from repro.core.ir import PhysicalPlan
from repro.core.lowering import BatchedJittedFuse, bucket_rows

#: the runtime's default merge cap (Runtime(max_batch=10)) — what bucket
#: coverage is judged against when no explicit cap is configured
DEFAULT_MAX_BATCH = 10


@dataclasses.dataclass
class AnalysisContext:
    """Everything a check may consult.  ``runtime`` and ``plan_config``
    are optional — checks that need them skip when absent."""
    plan: PhysicalPlan
    types: Dict[int, EdgeType] = dataclasses.field(default_factory=dict)
    runtime: object = None
    plan_config: object = None
    max_batch: Optional[int] = None
    budget_bytes: Optional[int] = None

    def consumers(self) -> Dict[int, List]:
        out: Dict[int, List] = {}
        for o in self.plan.ops:
            for i in o.inputs:
                out.setdefault(i, []).append(o)
        return out

    def node_max_batch(self, op_id: int) -> int:
        if self.plan_config is not None:
            try:
                mb = int(self.plan_config.node(op_id).max_batch)
                if mb > 1:
                    return mb
            except Exception:
                pass
        if self.max_batch is not None:
            return int(self.max_batch)
        if self.runtime is not None:
            return int(getattr(self.runtime, "max_batch",
                               DEFAULT_MAX_BATCH))
        return DEFAULT_MAX_BATCH


def device_edge_info(plan: PhysicalPlan) -> Dict[int, Tuple[bool, bool]]:
    """Static mirror of ``RuntimeDag.from_plan``'s residency analysis:
    per op id, (emits_device, donates).  An explicit ``op.donate=True``
    annotation forces the donation intent (that is what CF201 audits);
    ``donate=None`` derives the runtime's safe default."""
    consumers: Dict[int, List] = {}
    for o in plan.ops:
        for i in o.inputs:
            consumers.setdefault(i, []).append(o)
    info: Dict[int, Tuple[bool, bool]] = {}
    for o in plan.ops:
        dev = isinstance(o.op, BatchedJittedFuse) and o.device_resident
        cons = consumers.get(o.op_id, [])
        emits = (dev and bool(cons) and o.op_id != plan.output_id
                 and all(c.device_resident and not c.wait_any
                         and not c.batching and len(c.inputs) == 1
                         for c in cons))
        explicit = getattr(o, "donate", None)
        donate = bool(explicit) if explicit is not None \
            else (emits and len(cons) == 1)
        info[o.op_id] = (emits, donate)
    return info


class Check:
    """Base: subclasses set ``name`` and implement ``run(ctx)``."""
    name = "check"

    def run(self, ctx: AnalysisContext) -> List[Diagnostic]:
        raise NotImplementedError


class DonatedFanOutCheck(Check):
    """CF201: a buffer donated on a shared edge is deleted out from
    under every consumer but the one that received it."""
    name = "donated-fan-out"

    def run(self, ctx):
        out = []
        consumers = ctx.consumers()
        for o in ctx.plan.ops:
            if getattr(o, "donate", None) is not True:
                continue
            cons = consumers.get(o.op_id, [])
            if len(cons) > 1:
                out.append(Diagnostic(
                    "CF201",
                    f"op {o.op_id} ({o.op.name}) donates its output "
                    f"buffers but the edge fans out to "
                    f"{len(cons)} consumers "
                    f"({', '.join(str(c.op_id) for c in cons)})",
                    op_id=o.op_id,
                    edge=(o.op_id, cons[1].op_id),
                    hint="drop donate=True (the runtime derives safe "
                         "donation) or restructure so the edge has one "
                         "consumer"))
                continue
            bad = [c for c in cons
                   if c.wait_any or c.batching or len(c.inputs) > 1]
            for c in bad:
                out.append(Diagnostic(
                    "CF201",
                    f"op {o.op_id} ({o.op.name}) donates into consumer "
                    f"{c.op_id} ({c.op.name}), which "
                    + ("waits on any input" if c.wait_any else
                       "re-batches requests" if c.batching else
                       "joins multiple inputs")
                    + " — the donated buffer outlives the dispatch",
                    op_id=o.op_id, edge=(o.op_id, c.op_id),
                    hint="drop donate=True on this edge"))
        return out


class DeviceCrossClassCheck(Check):
    """CF202: a device-resident edge whose consumer is placed on a
    different executor class — the runtime will pin the consumer to the
    producer's device, silently overriding the declared placement."""
    name = "device-cross-class"

    def run(self, ctx):
        out = []
        info = device_edge_info(ctx.plan)
        consumers = ctx.consumers()
        for o in ctx.plan.ops:
            emits, _ = info[o.op_id]
            if not emits:
                continue
            for c in consumers.get(o.op_id, []):
                if c.placement != o.placement:
                    out.append(Diagnostic(
                        "CF202",
                        f"device-resident edge {o.op_id}->{c.op_id}: "
                        f"producer {o.op.name!r} emits on "
                        f"{o.placement!r} but consumer {c.op.name!r} is "
                        f"placed on {c.placement!r}; the runtime will "
                        f"pin the consumer to the producer's device",
                        op_id=c.op_id, edge=(o.op_id, c.op_id),
                        hint=f"place op {c.op_id} on {o.placement!r} or "
                             f"mark it device_resident=False to force a "
                             f"host round-trip"))
        return out


class WaitAnyArityCheck(Check):
    """CF203: wait-any consumers need >=2 upstreams to race; and a
    competitive-replica annotation that no pass materialized races
    nothing at all."""
    name = "wait-any-arity"

    def run(self, ctx):
        out = []
        consumers = ctx.consumers()
        for o in ctx.plan.ops:
            if o.wait_any and len(o.inputs) < 2:
                out.append(Diagnostic(
                    "CF203",
                    f"op {o.op_id} ({o.op.name}) has wait-any semantics "
                    f"but only {len(o.inputs)} upstream — nothing to "
                    f"race, first-completion degenerates to "
                    f"wait-for-all",
                    op_id=o.op_id,
                    hint="give the anyof >=2 upstream branches or run "
                         "the competitive pass to replicate its input"))
            if not o.wait_any and o.replicas >= 2:
                raced = any(c.wait_any for c in consumers.get(o.op_id, []))
                if not raced:
                    out.append(Diagnostic(
                        "CF203",
                        f"op {o.op_id} ({o.op.name}) is annotated with "
                        f"{o.replicas} competitive replicas but no pass "
                        f"materialized the race (no wait-any consumer)",
                        severity="warning", op_id=o.op_id,
                        hint="compile with competitive_exec=True (or a "
                             "plan-config replica override) to "
                             "materialize the replicas"))
        return out


class BucketCoverageCheck(Check):
    """CF204: the PR-5 covering-bucket rule — a full batcher merge pads
    to ``bucket_rows(max_batch)``; if that exceeds the configured bucket
    table, the first full batch pays a fresh XLA trace in serving."""
    name = "bucket-coverage"

    def run(self, ctx):
        out = []
        for o in ctx.plan.ops:
            op = o.op
            if not isinstance(op, BatchedJittedFuse) or not op.bucket_sizes:
                continue
            if not o.batching:
                continue        # unbatched nodes serve one request a time
            mb = ctx.node_max_batch(o.op_id)
            cover = bucket_rows(mb, op.bucket_sizes)
            top = max(op.bucket_sizes)
            if cover > top:
                out.append(Diagnostic(
                    "CF204",
                    f"op {o.op_id} ({op.name}) batches up to {mb} rows "
                    f"but its bucket table tops out at {top}; a full "
                    f"merge pads to {cover} and traces a fresh "
                    f"executable on the serving path",
                    op_id=o.op_id,
                    hint=f"add bucket {cover} to the node's "
                         f"batch_buckets or cap max_batch at {top}"))
        return out


class PlacementClassCheck(Check):
    """CF205/CF206: placements must name executor classes that can
    actually serve.  Needs a runtime (skipped without one)."""
    name = "placement-class"

    def run(self, ctx):
        if ctx.runtime is None:
            return []
        pool = getattr(ctx.runtime, "pool", None)
        if pool is None:
            return []
        out = []
        seen = set()
        for o in ctx.plan.ops:
            cls = o.placement
            if cls in seen:
                continue
            seen.add(cls)
            serving = pool.by_class(cls)
            if serving:
                continue
            reserved = pool.by_class(cls, reserved=True)
            ops_on = [p.op_id for p in ctx.plan.ops if p.placement == cls]
            if reserved:
                out.append(Diagnostic(
                    "CF206",
                    f"every {cls!r} executor is reserved for "
                    f"warm-up/canary traffic; ops {ops_on} have no "
                    f"serving worker and every dispatch will fail",
                    op_id=ops_on[0],
                    hint=f"provision at least one unreserved {cls!r} "
                         f"executor (e.g. Runtime(n_{cls}=1))"))
            else:
                out.append(Diagnostic(
                    "CF205",
                    f"ops {ops_on} are placed on class {cls!r} but the "
                    f"pool has zero {cls!r} executors; dispatch will "
                    f"raise at the first request",
                    op_id=ops_on[0],
                    hint=f"provision {cls!r} executors or override the "
                         f"placement in the plan config"))
        return out


class KernelTileCheck(Check):
    """CF103: kernel tile parameters must tile the inferred operand
    shapes (the Pallas kernels assert divisibility at call time — this
    surfaces the same failure before any trace).  Needs inferred shape
    specs; steps without them are skipped."""
    name = "kernel-tiles"

    def run(self, ctx):
        from repro.kernels.ops import KERNEL_REGISTRY, kernel_call_of
        out = []
        for o in ctx.plan.ops:
            steps = _chain_of(o.op)
            if steps is None or len(o.inputs) != 1:
                continue
            et = ctx.types.get(o.inputs[0])
            cur = list(et.specs) if et is not None and et.specs is not None \
                else None
            for step in steps:
                fn = getattr(step, "fn", None)
                if fn is None:
                    # non-map/filter sub-op fused into the chain (lookup,
                    # groupby): no step function, and shapes past it are
                    # unknown
                    cur = None
                    continue
                kc = kernel_call_of(fn)
                if kc is not None:
                    spec = KERNEL_REGISTRY.get(kc.kernel)
                    if spec is not None:
                        shapes = None
                        if cur is not None and \
                                all(s is not None for s in cur):
                            shapes = {a: tuple(s.shape) for a, s in
                                      zip(spec.args, cur)}
                        for problem in spec.check_tiles(shapes, kc.params):
                            out.append(Diagnostic(
                                "CF103",
                                f"op {o.op_id} kernel {kc.kernel}: "
                                f"{problem}",
                                op_id=o.op_id,
                                hint="pick tile params that divide the "
                                     "operand's tiled dimension"))
                # advance specs through the step so a later kernel in
                # the chain sees its true operand shapes
                if cur is not None:
                    from repro.analysis.infer import _eval_step
                    try:
                        cur = _eval_step(step, cur)
                    except Exception:
                        cur = None      # CF101/CF102 territory, not ours
        return out


class FilterMaskCheck(Check):
    """CF104: a gpu-placed chain with a filter whose return annotation
    is missing cannot lower the filter to a mask — the chain silently
    stays eager."""
    name = "filter-mask"

    def run(self, ctx):
        out = []
        for o in ctx.plan.ops:
            if o.placement != "gpu":
                continue
            steps = _chain_of(o.op)
            if steps is None:
                continue
            for step in steps:
                if isinstance(step, ops.Filter) and step._ret is not bool:
                    out.append(Diagnostic(
                        "CF104",
                        f"op {o.op_id}: filter {step.name!r} is placed "
                        f"on gpu but its return type is not annotated "
                        f"bool; it cannot lower to a mask, so the chain "
                        f"will not jit-fuse",
                        op_id=o.op_id,
                        hint="annotate the predicate's return type as "
                             "bool"))
        return out


class KeyRegistryCheck(Check):
    """CF401: every metric series the runtime recorded must match the
    ``obs.keys`` registry — a typo'd key otherwise just creates an
    empty, never-read series."""
    name = "metric-key-registry"

    def run(self, ctx):
        if ctx.runtime is None:
            return []
        from repro.obs import keys as K
        out = []
        snapshot = getattr(ctx.runtime, "metrics_snapshot", None)
        if snapshot is None:
            return []
        for key in sorted(snapshot()):
            if not K.known_key(key):
                out.append(Diagnostic(
                    "CF401",
                    f"recorded metric key {key!r} matches no pattern in "
                    f"repro.obs.keys",
                    hint="use the obs.keys constants/formatters instead "
                         "of inline f-strings, or register the new "
                         "series pattern"))
        return out


def default_checks() -> List[Check]:
    return [DonatedFanOutCheck(), DeviceCrossClassCheck(),
            WaitAnyArityCheck(), BucketCoverageCheck(),
            PlacementClassCheck(), KernelTileCheck(), FilterMaskCheck(),
            KeyRegistryCheck()]
