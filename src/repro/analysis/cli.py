"""``python -m repro.check`` — lint example/benchmark flows statically.

Any module (example, benchmark, user script) opts in by exposing

    def check_flows():
        return [{"name": "quickstart",
                 "flow": build_flow(),
                 "compile": {"fusion": True, "jit_fusion": True},
                 "sample": sample_table(),        # optional
                 "max_batch": 10,                 # optional
                 "budget_bytes": 2 << 30},        # optional
                ...]

The CLI imports each module by file path, compiles every declared flow
through the real pass pipeline (no runtime, no traffic, no XLA trace),
runs the full verifier, and prints one diagnostic table per flow.
Exit status 1 iff any severity=error diagnostic fired.
"""
from __future__ import annotations

import argparse
import importlib.util
import sys
import traceback
from pathlib import Path
from typing import Iterable, List, Optional, Tuple

from repro.analysis import Report, analyze
from repro.analysis.diagnostics import CODES
from repro.core.ir import PhysicalPlan
from repro.core.passes import PassContext, build_pipeline

#: build_pipeline kwargs a check entry's "compile" dict may set
_COMPILE_KEYS = ("fusion", "competitive_exec", "locality", "jit_fusion",
                 "batched_lowering", "default_replicas", "plan_config",
                 "place_kernels")


def load_module(path: Path):
    """Import a script by file path under a synthetic module name (the
    ``tests/test_examples_smoke.py`` idiom — examples are scripts, not
    packages)."""
    name = f"repro_check_{path.stem}"
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules[name] = mod
    spec.loader.exec_module(mod)
    return mod


def check_entry(entry: dict) -> Report:
    """Compile one declared flow through the pass pipeline and verify
    the resulting plan."""
    name = entry.get("name", "flow")
    flow = entry["flow"]
    compile_kwargs = {k: v for k, v in
                      dict(entry.get("compile") or {}).items()
                      if k in _COMPILE_KEYS}
    flow.typecheck()
    plan = PhysicalPlan.from_dataflow(flow)
    pipeline = build_pipeline(**compile_kwargs)
    plan = pipeline.run(plan, PassContext())
    return analyze(plan, name=name,
                   plan_config=compile_kwargs.get("plan_config"),
                   sample=entry.get("sample"),
                   input_specs=entry.get("input_specs"),
                   max_batch=entry.get("max_batch"),
                   budget_bytes=entry.get("budget_bytes"))


def check_module(path: Path) -> Optional[List[Tuple[str, Report]]]:
    """All reports for one module, or None when it declares no flows."""
    mod = load_module(path)
    hook = getattr(mod, "check_flows", None)
    if hook is None:
        return None
    return [(e.get("name", f"{path.stem}#{i}"), check_entry(e))
            for i, e in enumerate(hook())]


def discover(paths: Iterable[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(path.glob("*.py")))
        elif path.suffix == ".py":
            out.append(path)
    return [p for p in out if not p.name.startswith("_")]


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.check",
        description="Statically verify serving dataflow plans.")
    ap.add_argument("paths", nargs="*", default=["examples", "benchmarks"],
                    help="modules or directories to lint "
                         "(default: examples/ benchmarks/)")
    ap.add_argument("--errors-only", action="store_true",
                    help="print only flows with error diagnostics")
    ap.add_argument("--list-codes", action="store_true",
                    help="print the diagnostic code registry and exit")
    args = ap.parse_args(argv)
    if args.list_codes:
        for code, (title, sev) in sorted(CODES.items()):
            print(f"{code}  {sev:<8}{title}")
        return 0

    n_flows = n_errors = n_warnings = 0
    failed_imports: List[str] = []
    for path in discover(args.paths):
        try:
            reports = check_module(path)
        except Exception:
            failed_imports.append(str(path))
            print(f"!! {path}: crashed while checking", file=sys.stderr)
            traceback.print_exc()
            continue
        if reports is None:
            continue
        for _name, report in reports:
            n_flows += 1
            n_errors += len(report.errors())
            n_warnings += len(report.warnings())
            if args.errors_only and report.ok:
                continue
            print(report.table())
            print()
    print(f"checked {n_flows} flow(s): {n_errors} error(s), "
          f"{n_warnings} warning(s)"
          + (f", {len(failed_imports)} module(s) crashed"
             if failed_imports else ""))
    return 1 if n_errors or failed_imports else 0
