"""Parameter / optimizer-state / input PartitionSpecs (DESIGN.md §6).

Weight sharding rules (by leaf name within the params pytree):

* TP over ``model`` on head / d_ff / expert / vocab dims.
* Training additionally FSDP-shards the complementary dim over ``data``
  (ZeRO: optimizer state inherits the spec -> per-chip state = total/256).
* MoE expert weights are FSDP-sharded even for serving (480B would not fit
  TP-only, DESIGN.md §4); XLA all-gathers them per scanned layer.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models.partition import AxisInfo

# leaves sharded [*, fsdp, mp] (input-projection-like: last dim is TP)
_IN_PROJ = {"wq", "wk", "wv", "w_up", "w_gate", "cm_wk", "wx", "wgate",
            "cm_wr", "wg", "wr"}
# leaves sharded [*, mp, fsdp] (output-projection-like: first matrix dim TP)
_OUT_PROJ = {"wo", "w_down", "cm_wv"}
# small per-channel (R- or D-sized) leaves sharded on the channel dim
_CHANNEL_MP = {"lam", "wi_a", "wi_b", "wr_a", "wr_b", "conv_b"}


def _leaf_spec(path, leaf, cfg: ModelConfig, ax: AxisInfo, *,
               fsdp: Optional[str]) -> P:
    keys = [str(p.key) for p in path if hasattr(p, "key")]
    name = keys[-1] if keys else ""
    joined = "/".join(keys)
    mp = ax.model
    nd = leaf.ndim
    moe_fsdp = ax.data

    if name == "embed":
        return P(mp, fsdp)
    if "moe" in keys:
        if name == "router":
            return P(*([None] * nd))  # replicated (shard_map reads it whole)
        if name == "s":               # int8 scales [n, E, F]
            return P(None, mp, None)
        # [n, E, D, F] / [n, E, F, D] (or int8 "q"): experts over model,
        # dim2 FSDP'd
        return P(None, mp, moe_fsdp, None)
    if name in _IN_PROJ and nd >= 2:
        return P(*([None] * (nd - 2)), fsdp, mp)
    if name in _OUT_PROJ and nd >= 2:
        return P(*([None] * (nd - 2)), mp, fsdp)
    if name in ("tm_w1", "dw1") and nd >= 2:  # [*, D, lora]
        return P(*([None] * (nd - 2)), fsdp, None)
    if name in ("tm_w2", "dw2", "conv_w"):    # [..., last dim model-sharded]
        return P(*([None] * (nd - 1)), mp)
    if name == "u" and nd >= 2:               # [*, H, hd]
        return P(*([None] * (nd - 2)), mp, None)
    if name in ("gn_scale", "gn_bias"):       # [*, D] head-major channels
        return P(*([None] * (nd - 1)), mp)
    if name in _CHANNEL_MP:                   # [*, R]
        return P(*([None] * (nd - 1)), mp)
    return P(*([None] * nd))                  # norms, gates, mus: replicated


def param_pspecs(params, cfg: ModelConfig, ax: AxisInfo, *,
                 mode: str = "train"):
    """Spec pytree matching ``params``.  mode: train (TP+FSDP) | serve (TP).
    FSDP uses the full data tuple (('pod','data') on the multi-pod mesh)."""
    fsdp = ax.data if mode == "train" else None
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf, cfg, ax, fsdp=fsdp), params)


def opt_state_pspecs(params, param_specs, opt_name: str):
    """Spec pytree for the optimizer state of ``make_optimizer(opt_name)``."""
    if opt_name == "adamw":
        return {"m": param_specs, "v": param_specs, "step": P()}
    if opt_name == "adafactor":
        from repro.training.optim import _factored

        def v_spec(p, s):
            parts = list(s) + [None] * (p.ndim - len(s))
            if _factored(p):
                return {"vr": P(*parts[:-1]),
                        "vc": P(*(parts[:-2] + parts[-1:]))}
            return {"v": P(*parts)}

        return {"v": jax.tree.map(v_spec, params, param_specs,
                                  is_leaf=lambda x: isinstance(x, P)),
                "step": P()}
    raise ValueError(opt_name)


def state_pspecs(state, cfg: ModelConfig, ax: AxisInfo):
    pspecs = param_pspecs(state["params"], cfg, ax, mode="train")
    return {"params": pspecs,
            "opt": opt_state_pspecs(state["params"], pspecs, cfg.optimizer)}


def batch_pspecs(cfg: ModelConfig, ax: AxisInfo, shape: InputShape):
    """Input batch specs for the given input shape."""
    b = ax.batch  # None when batch unshardable (long_500k)
    if shape.kind == "train":
        specs = {"tokens": P(b, None), "labels": P(b, None)}
    elif shape.kind == "prefill":
        specs = {"tokens": P(b, None)}
    else:
        specs = {"tokens": P(b, None), "pos": P(b)}
    if cfg.family == "vlm" and shape.kind in ("train", "prefill"):
        specs["media"] = P(b, None, None)
    if cfg.family == "audio" and shape.kind in ("train", "prefill"):
        specs["frames"] = P(b, None, None)
    return specs


def to_shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
