import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# NOTE: the two lines above MUST be first — jax locks device count on init.
DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination
with ShapeDtypeStruct inputs (no allocation), print memory/cost analysis, and
emit roofline terms as JSON (consumed by EXPERIMENTS.md and benchmarks).

The XLA_FLAGS line above MUST run before any other import (jax locks device
count on first init) and must live only here — tests/benches see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape decode_32k \
      --multipod --out results/
Options: --moe-dispatch {all_to_all,allgather}  --remat {nothing,dots}
         --seq-shard {model,none}  (perf-iteration knobs)
"""

import argparse
import dataclasses
import json
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, SHAPES, LONG_CONTEXT_OK, ARCH_IDS
from repro.configs.shapes import InputShape
from repro.launch.mesh import make_production_mesh, make_axis_info
from repro.launch import sharding as sh
from repro.models.registry import build_model
from repro.roofline import analysis, hw
from repro.training import optim, train_step as ts_lib


def should_skip(arch: str, shape_name: str) -> Optional[str]:
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_OK:
        return "full-attention arch: long_500k skipped (DESIGN.md §5)"
    return None


def _eval_state_specs(model, cfg, ax):
    """Abstract train state + matching sharding specs (no allocation)."""
    state_shape = jax.eval_shape(
        lambda k: ts_lib.init_train_state(model, k), jax.random.PRNGKey(0))
    specs = sh.state_pspecs(state_shape, cfg, ax)
    return state_shape, specs


def build_dryrun(arch: str, shape_name: str, *, multi_pod: bool = False,
                 moe_dispatch: str = "all_to_all",
                 remat: Optional[str] = None,
                 kv_quant: bool = False, expert_quant: bool = False,
                 bf16_boundary: bool = False,
                 grad_accum: Optional[int] = None, seq_shard: bool = True,
                 rs_outputs: bool = False, causal_skip: bool = False,
                 serve_mode: str = "tp") -> Dict[str, Any]:
    cfg = get_config(arch)
    if remat:
        cfg = dataclasses.replace(cfg, remat_policy=remat)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    if expert_quant:
        cfg = dataclasses.replace(cfg, expert_quant=True)
    if bf16_boundary:
        cfg = dataclasses.replace(cfg, bf16_boundary=True)
    if grad_accum is not None:
        cfg = dataclasses.replace(cfg, grad_accum=grad_accum)
    if not seq_shard:
        cfg = dataclasses.replace(cfg, seq_shard=False)
    if rs_outputs:
        cfg = dataclasses.replace(cfg, rs_outputs=True)
    if causal_skip:
        cfg = dataclasses.replace(cfg, causal_skip=True)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    long_context = shape_name == "long_500k"
    shard_batch = shape.global_batch % (
        mesh.size // mesh.shape["model"]) == 0
    ax = make_axis_info(mesh, shard_batch=shard_batch)
    model = build_model(cfg, ax, long_context=long_context,
                        moe_dispatch=moe_dispatch)

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            state_shape, state_specs = _eval_state_specs(model, cfg, ax)
            batch_specs = sh.batch_pspecs(cfg, ax, shape)
            step = ts_lib.make_train_step(model)
            jitted = jax.jit(
                step,
                in_shardings=(sh.to_shardings(mesh, state_specs),
                              sh.to_shardings(mesh, batch_specs)),
                out_shardings=(sh.to_shardings(mesh, state_specs), None),
                donate_argnums=(0,),
            )
            specs = model.input_specs(shape)
            state_abs = jax.tree.map(
                lambda s, sp: jax.ShapeDtypeStruct(
                    s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
                state_shape, state_specs,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
            lowered = jitted.lower(state_abs, specs)
        elif shape.kind == "prefill":
            pspecs = sh.param_pspecs(
                jax.eval_shape(model.init, jax.random.PRNGKey(0)), cfg, ax,
                mode="serve" if cfg.num_experts == 0 else "train")
            batch_specs = sh.batch_pspecs(cfg, ax, shape)
            cache_specs = model.cache_pspecs()

            def prefill_fn(params, batch):
                return model.prefill(params, batch, cache_len=shape.seq_len)

            jitted = jax.jit(
                prefill_fn,
                in_shardings=(sh.to_shardings(mesh, pspecs),
                              sh.to_shardings(mesh, batch_specs)),
                out_shardings=(None, sh.to_shardings(mesh, cache_specs)))
            params_abs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            lowered = jitted.lower(params_abs, model.input_specs(shape))
        else:  # decode
            pspecs = sh.param_pspecs(
                jax.eval_shape(model.init, jax.random.PRNGKey(0)), cfg, ax,
                mode="serve" if cfg.num_experts == 0 else "train")
            cache_specs = model.cache_pspecs()
            b = ax.batch

            def decode_fn(params, tokens, pos, cache):
                return model.decode_step(params, tokens, pos, cache)

            jitted = jax.jit(
                decode_fn,
                in_shardings=(sh.to_shardings(mesh, pspecs),
                              NamedSharding(mesh, P(b, None)),
                              NamedSharding(mesh, P(b)),
                              sh.to_shardings(mesh, cache_specs)),
                out_shardings=(None, sh.to_shardings(mesh, cache_specs)),
                donate_argnums=(3,))
            specs = model.input_specs(shape)
            params_abs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            lowered = jitted.lower(params_abs, specs["tokens"], specs["pos"],
                                   specs["cache"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    # tokens processed per step (for per-token metrics)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    n_active = cfg.active_param_count()
    mult = 6 if shape.kind == "train" else 2
    model_flops = float(mult * n_active * tokens)
    roof = analysis.from_compiled(compiled, model_flops=model_flops,
                                  chips=chips)
    # analytic executed-cost model (primary; HLO cost_analysis counts scan
    # bodies once — see repro.roofline.flops docstring)
    from repro.roofline import flops as flops_lib
    est = flops_lib.estimate(cfg, shape, chips=chips, mp=mesh.shape["model"],
                             long_context=long_context,
                             moe_dispatch=moe_dispatch)
    coll_corr = analysis.collective_bytes_corrected(compiled.as_text())
    coll_total = sum(v for k, v in coll_corr.items() if k != "count")
    roof_analytic = analysis.Roofline(
        flops=est.step_flops / chips,
        hbm_bytes=est.hbm_bytes_per_chip,
        coll_bytes=coll_total,
        model_flops=est.model_flops, chips=chips)
    mem = compiled.memory_analysis()
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": shape.kind,
        "moe_dispatch": moe_dispatch,
        "remat": cfg.remat_policy,
        "kv_quant": cfg.kv_quant,
        "bf16_boundary": cfg.bf16_boundary,
        "grad_accum": cfg.grad_accum,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "tokens_per_step": tokens,
        "params": cfg.param_count(), "active_params": n_active,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_est_bytes": mem.argument_size_in_bytes
            - mem.alias_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes,
            "hbm_per_chip": hw.HBM_BYTES,
        },
        "collectives_raw": analysis.collective_bytes(compiled.as_text()),
        "collectives": coll_corr,
        "roofline_hlo": roof.to_dict(),
        "roofline": roof_analytic.to_dict(),
        "analytic": est.to_dict(),
    }
    return result


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    p.add_argument("--shape", default=None, choices=list(SHAPES))
    p.add_argument("--batch-archs", default=None,
                   help="comma-list or 'all': run arch x shape x mesh matrix")
    p.add_argument("--batch-shapes", default="all")
    p.add_argument("--meshes", default="both",
                   choices=["single", "multi", "both"])
    p.add_argument("--multipod", action="store_true")
    p.add_argument("--moe-dispatch", default="all_to_all",
                   choices=["all_to_all", "allgather"])
    p.add_argument("--remat", default=None, choices=["nothing", "dots",
                                                     "everything"])
    p.add_argument("--kv-quant", action="store_true")
    p.add_argument("--expert-quant", action="store_true")
    p.add_argument("--barrier", action="store_true", dest="bf16_boundary")
    p.add_argument("--grad-accum", type=int, default=None)
    p.add_argument("--no-seq-shard", action="store_false", dest="seq_shard")
    p.add_argument("--rs-outputs", action="store_true")
    p.add_argument("--causal-skip", action="store_true")
    p.add_argument("--tag", default=None, help="suffix for the output JSON")
    p.add_argument("--out", default=None, help="directory for the JSON")
    args = p.parse_args(argv)

    if args.batch_archs:
        archs = (list(ARCH_IDS) if args.batch_archs == "all"
                 else args.batch_archs.split(","))
        shapes = (list(SHAPES) if args.batch_shapes == "all"
                  else args.batch_shapes.split(","))
        meshes = {"single": [False], "multi": [True],
                  "both": [False, True]}[args.meshes]
        run_batch(archs, shapes, meshes, args.out or "results/dryrun",
                  moe_dispatch=args.moe_dispatch)
        return 0

    skip = should_skip(args.arch, args.shape)
    if skip:
        result = {"arch": args.arch, "shape": args.shape,
                  "mesh": "2x16x16" if args.multipod else "16x16",
                  "skipped": skip}
    else:
        result = build_dryrun(args.arch, args.shape, multi_pod=args.multipod,
                              moe_dispatch=args.moe_dispatch,
                              remat=args.remat, kv_quant=args.kv_quant,
                              expert_quant=args.expert_quant,
                              bf16_boundary=args.bf16_boundary,
                              grad_accum=args.grad_accum,
                              seq_shard=args.seq_shard,
                              rs_outputs=args.rs_outputs,
                              causal_skip=args.causal_skip)
    print(json.dumps(result, indent=2))
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        tag = f"{args.arch}__{args.shape}__{result['mesh']}"
        if args.moe_dispatch != "all_to_all":
            tag += f"__{args.moe_dispatch}"
        if args.remat:
            tag += f"__remat-{args.remat}"
        if args.tag:
            tag += f"__{args.tag}"
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(result, f, indent=2)
    return 0


def run_batch(archs, shapes, meshes, out_dir: str, *,
              moe_dispatch: str = "all_to_all", skip_existing: bool = True):
    """Run many combos in one process (amortizes jax import/trace cost).
    One JSON per combo; failures recorded, not fatal."""
    os.makedirs(out_dir, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            for multi_pod in meshes:
                mesh_tag = "2x16x16" if multi_pod else "16x16"
                tag = f"{arch}__{shape}__{mesh_tag}"
                path = os.path.join(out_dir, tag + ".json")
                if skip_existing and os.path.exists(path):
                    print("skip (exists):", tag, flush=True)
                    continue
                skip = should_skip(arch, shape)
                if skip:
                    result = {"arch": arch, "shape": shape, "mesh": mesh_tag,
                              "skipped": skip}
                else:
                    t0 = time.time()
                    try:
                        result = build_dryrun(arch, shape,
                                              multi_pod=multi_pod,
                                              moe_dispatch=moe_dispatch)
                    except Exception as e:
                        import traceback
                        result = {"arch": arch, "shape": shape,
                                  "mesh": mesh_tag, "error": str(e)[:2000],
                                  "traceback":
                                  traceback.format_exc()[-4000:]}
                    result["wall_s"] = round(time.time() - t0, 1)
                with open(path, "w") as f:
                    json.dump(result, f, indent=2)
                status = ("SKIP" if "skipped" in result else
                          "FAIL" if "error" in result else "ok  ")
                print(f"{status} {tag} ({result.get('wall_s', 0)}s)",
                      flush=True)
                jax.clear_caches()


if __name__ == "__main__":
    sys.exit(main())
