"""Training launcher.

On real hardware this runs under the production mesh; on this container it
runs tiny configs on one CPU device (the e2e example) or, with --dryrun,
defers to repro.launch.dryrun.

  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --tiny --steps 50
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_tiny_config, ARCH_IDS
from repro.models.registry import build_model
from repro.training import checkpoint, optim
from repro.training.data import DataConfig, SyntheticLM
from repro.training.train_step import init_train_state, make_train_step


def run(arch: str, *, tiny: bool = True, steps: int = 50, batch_size: int = 8,
        seq_len: int = 64, lr: float = 1e-3, ckpt_dir: str = "",
        log_every: int = 10, seed: int = 0):
    cfg = get_tiny_config(arch) if tiny else get_config(arch)
    model = build_model(cfg)
    opt_cfg = optim.OptConfig(name=cfg.optimizer, lr=lr, warmup_steps=20)
    state = init_train_state(model, jax.random.PRNGKey(seed), opt_cfg)
    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0,))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                                  batch_size=batch_size, seed=seed))
    extras = {}
    if cfg.family == "vlm":
        extras["media"] = jnp.zeros((batch_size, cfg.num_media_tokens,
                                     cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "audio":
        extras["frames"] = jnp.zeros((batch_size, cfg.encoder_seq,
                                      cfg.d_model), jnp.dtype(cfg.dtype))
    losses = []
    t0 = time.time()
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch().items()}
        batch.update(extras)
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if log_every and (i % log_every == 0 or i == steps - 1):
            print(f"step {i:4d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    if ckpt_dir:
        path = checkpoint.save(ckpt_dir, state, steps)
        print("saved", path)
    return losses, state


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    p.add_argument("--tiny", action="store_true", default=True)
    p.add_argument("--full", dest="tiny", action="store_false")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch-size", type=int, default=8)
    p.add_argument("--seq-len", type=int, default=64)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--ckpt-dir", default="")
    args = p.parse_args()
    losses, _ = run(args.arch, tiny=args.tiny, steps=args.steps,
                    batch_size=args.batch_size, seq_len=args.seq_len,
                    lr=args.lr, ckpt_dir=args.ckpt_dir)
    print(f"first loss {losses[0]:.4f} -> last loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
