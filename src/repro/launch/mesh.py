"""Production mesh construction (function, not module constant — importing
this module never touches jax device state)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.models.partition import AxisInfo


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Version-compatible ``jax.make_mesh`` with Auto axis types.
    jax.sharding.AxisType only exists in newer jax; omit on 0.4.x."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_axis_info(mesh: Mesh, *, shard_batch: bool = True) -> AxisInfo:
    names = mesh.axis_names
    data = tuple(n for n in names if n in ("pod", "data"))
    return AxisInfo(mesh=mesh, data=data, model="model",
                    shard_batch=shard_batch)


def make_host_mesh(shape: Tuple[int, ...] = (1, 1),
                   axes: Tuple[str, ...] = ("data", "model")) -> Mesh:
    """Small mesh over however many (host) devices exist — used by tests."""
    return make_mesh(shape, axes)
