"""Serving launcher: stand up a Cloudflow pipeline over a zoo model and run
batched requests through the serverless runtime (tiny config on CPU).

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --requests 16
"""
from __future__ import annotations

import argparse
import time
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_tiny_config, ARCH_IDS
from repro.core.dataflow import Dataflow
from repro.core.table import Table
from repro.runtime.netmodel import NetModel
from repro.runtime.runtime import Runtime
from repro.serving.engine import make_engine


def build_flow(arch: str, *, max_new_tokens: int = 8,
               batching: bool = True) -> Tuple[Dataflow, object]:
    cfg = get_tiny_config(arch)
    engine = make_engine(cfg, cache_len=128)
    params = engine.model.init(jax.random.PRNGKey(0))

    def tokenize(text: str) -> np.ndarray:
        toks = np.frombuffer(text.encode()[:16].ljust(16), np.uint8)
        return toks.astype(np.int32) % cfg.vocab_size

    def generate(tokens: np.ndarray) -> np.ndarray:
        batch = {"tokens": jnp.asarray(tokens)[None]}
        if cfg.family == "vlm":
            batch["media"] = jnp.zeros((1, cfg.num_media_tokens, cfg.d_model),
                                       jnp.dtype(cfg.dtype))
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros((1, cfg.encoder_seq, cfg.d_model),
                                        jnp.dtype(cfg.dtype))
        return engine.generate(params, batch, max_new_tokens)[0]

    def detok(out: np.ndarray) -> str:
        return " ".join(str(int(t)) for t in out)

    flow = Dataflow([("text", str)])
    toks = flow.map(tokenize, names=["tokens"])
    gen = toks.map(generate, names=["out"], gpu=False, batching=batching)
    flow.output = gen.map(detok, names=["completion"])
    return flow, engine


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="yi-9b", choices=list(ARCH_IDS))
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--new-tokens", type=int, default=8)
    args = p.parse_args()
    flow, _ = build_flow(args.arch, max_new_tokens=args.new_tokens)
    rt = Runtime(n_cpu=2, net=NetModel(scale=0.0))
    flow.deploy(rt, fusion=True)
    t0 = time.time()
    futs = [flow.execute(Table([("text", str)], [(f"request {i}",)]))
            for i in range(args.requests)]
    for i, f in enumerate(futs):
        r = f.result(timeout=120)
        print(f"req {i}: {r.to_dicts()[0]['completion']}")
    dt = time.time() - t0
    print(f"{args.requests} requests in {dt:.2f}s "
          f"({args.requests / dt:.1f} req/s)")
    rt.stop()


if __name__ == "__main__":
    main()
