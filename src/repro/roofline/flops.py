"""Analytic executed-FLOPs / HBM-bytes model (primary roofline source).

XLA's ``cost_analysis()`` counts scan (while-loop) bodies ONCE, not x trip
count (verified by probe: rwkv6 decode reports ~1/L of the expected FLOPs),
so for scanned-layer models the HLO numbers badly undercount.  This module
computes the *executed* FLOPs/bytes from first principles, mirroring the
actual implementation including its overheads:

* padded Q-heads / replicated KV-heads (SPMD divisibility, DESIGN §4)
* chunked attention computes ALL nq*nk chunk pairs (masked, not skipped)
* MoE capacity padding (capacity_factor) + router
* remat recompute (+1 forward for policy "nothing")
* padded vocab

MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (inference) is computed
separately; useful_ratio = MODEL_FLOPS / executed.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models.transformer import block_layout
from repro.models import rglru as rglru_lib


@dataclasses.dataclass
class CostEstimate:
    fwd_flops: float = 0.0          # global forward FLOPs
    step_flops: float = 0.0         # global executed FLOPs for the step
    model_flops: float = 0.0        # 6*N_active*tokens (or 2* for inference)
    weight_bytes: int = 0           # global param bytes (padded)
    cache_bytes: int = 0            # global decode-cache bytes
    hbm_bytes_per_chip: float = 0.0 # first-order per-chip traffic / step
    act_bytes: float = 0.0

    def to_dict(self):
        return dataclasses.asdict(self)


def _attn_layer_flops(cfg: ModelConfig, T: float, S_ctx: float, mp: int,
                      window: int, decode: bool) -> float:
    """Per-layer attention FLOPs over T tokens with context S_ctx."""
    hd = cfg.head_dim
    Hp = cfg.padded_heads(mp)
    Kp = cfg.replicated_kv_heads(mp)
    D = cfg.d_model
    proj = 2 * T * D * (Hp + 2 * Kp) * hd + 2 * T * Hp * hd * D
    if decode:
        ctx = min(window, S_ctx) if window else S_ctx
        scores = 2 * T * Hp * hd * ctx * 2
    else:
        # chunked implementation computes all nq*nk chunk pairs unless the
        # triangle-pair path is enabled (causal_skip: ~(n+1)/2n of the work)
        frac = 1.0
        if cfg.causal_skip and not window:
            n = max(1, S_ctx // 1024)
            frac = (n + 1) / (2 * n)
        scores = 2 * T * Hp * hd * S_ctx * 2 * frac
    return proj + scores


def _mlp_flops(cfg: ModelConfig, T: float) -> float:
    return 2 * T * cfg.d_model * cfg.d_ff * cfg.mlp_mats


def _moe_flops(cfg: ModelConfig, T: float, chips: int, mp: int,
               decode: bool) -> float:
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    D, F = cfg.d_model, cfg.expert_ff
    router = 2 * T * D * E
    if mp <= 1:
        # reference path: every expert over all tokens
        expert_tokens = T * E
    else:
        dp = max(1, chips // mp)
        T_loc = max(1.0, T / chips) if not decode else max(
            1.0, math.ceil(T / dp / mp))
        C = max(1, math.ceil(T_loc * k * cfg.capacity_factor / E))
        expert_tokens = chips * E * C   # each chip computes E*C padded slots
    return router + 2 * expert_tokens * D * F * cfg.mlp_mats


def _rwkv_layer_flops(cfg: ModelConfig, T: float) -> float:
    D, F = cfg.d_model, cfg.d_ff
    tm = 2 * T * D * D * 5                 # r,k,v,g,o projections
    lora = 2 * T * D * (5 * 32 + 64) * 2
    wkv = 4 * T * D * cfg.rwkv_head_dim    # state outer/dot/decay per channel
    cm = 2 * T * (2 * D * F + D * D)
    return tm + lora + wkv + cm


def _rglru_rec_flops(cfg: ModelConfig, T: float) -> float:
    D, R = cfg.d_model, cfg.rnn_dim
    return (2 * T * D * R * 2 + 2 * T * R * D + 2 * T * R * cfg.conv_width
            + 12 * T * R)


def estimate(cfg: ModelConfig, shape: InputShape, *, chips: int = 256,
             mp: int = 16, long_context: bool = False,
             moe_dispatch: str = "all_to_all") -> CostEstimate:
    B, S = shape.global_batch, shape.seq_len
    decode = shape.kind == "decode"
    T = B * (1 if decode else S)
    S_ctx = S
    est = CostEstimate()
    D = cfg.d_model
    Vp = cfg.padded_vocab
    fwd = 0.0

    if cfg.family in ("dense", "moe", "vlm"):
        specs, n_blocks = block_layout(cfg, long_context=long_context)
        for spec in specs:
            fwd += n_blocks * _attn_layer_flops(cfg, T, S_ctx, mp,
                                                spec.window, decode)
            if spec.is_moe:
                fwd += n_blocks * _moe_flops(cfg, T, chips, mp, decode)
                if spec.aux_mlp:
                    fwd += n_blocks * _mlp_flops(cfg, T)
            else:
                fwd += n_blocks * _mlp_flops(cfg, T)
            if spec.has_cross:
                M = cfg.num_media_tokens
                hd, Hp = cfg.head_dim, cfg.padded_heads(mp)
                Kp = cfg.replicated_kv_heads(mp)
                fwd += n_blocks * (2 * T * D * Hp * hd           # q proj
                                   + 2 * B * M * D * 2 * Kp * hd  # kv proj
                                   + 2 * T * Hp * hd * M * 2      # attn
                                   + 2 * T * Hp * hd * D)         # out proj
    elif cfg.family == "ssm":
        fwd += cfg.num_layers * _rwkv_layer_flops(cfg, T)
    elif cfg.family == "hybrid":
        for kind in rglru_lib.layer_types(cfg):
            if kind == "rec":
                fwd += _rglru_rec_flops(cfg, T)
            else:
                fwd += _attn_layer_flops(cfg, T, S_ctx, mp,
                                         cfg.sliding_window, decode)
            fwd += _mlp_flops(cfg, T)
    elif cfg.family == "audio":
        Te = B * cfg.encoder_seq
        for _ in range(cfg.encoder_layers):
            fwd += _attn_layer_flops(cfg, Te, cfg.encoder_seq, mp, 0, False)
            fwd += _mlp_flops(cfg, Te)
        for _ in range(cfg.num_layers):
            fwd += _attn_layer_flops(cfg, T, S_ctx, mp, 0, decode)
            # cross attention (+ enc kv proj when not cached)
            hd, Hp = cfg.head_dim, cfg.padded_heads(mp)
            Kp = cfg.replicated_kv_heads(mp)
            fwd += 2 * T * D * (Hp + Hp) * hd
            fwd += 2 * T * Hp * hd * cfg.encoder_seq * 2
            if not decode:
                fwd += 2 * Te * D * 2 * Kp * hd
            fwd += _mlp_flops(cfg, T)

    # embedding / head / loss
    fwd += 2 * T * D * Vp
    if shape.kind == "train":
        fwd += 5 * T * Vp

    est.fwd_flops = fwd
    if shape.kind == "train":
        remat_extra = 1.0 if cfg.remat_policy == "nothing" else 0.0
        est.step_flops = fwd * (3.0 + remat_extra)
    else:
        est.step_flops = fwd
    n_active = cfg.active_param_count()
    mult = 6 if shape.kind == "train" else 2
    est.model_flops = float(mult * n_active * T)

    # ---- bytes ----
    bpe = 2  # bf16
    kv_bpe = 1.03 if cfg.kv_quant else 2  # int8 + per-(slot,head) f32 scale
    n_params_padded = cfg.param_count()  # padding delta is small; first-order
    est.weight_bytes = n_params_padded * bpe
    if decode:
        Kp = cfg.replicated_kv_heads(mp)
        hd = cfg.head_dim
        if cfg.family == "ssm":
            est.cache_bytes = cfg.num_layers * B * D * cfg.rwkv_head_dim * 4
        elif cfg.family == "hybrid":
            n_attn = sum(1 for k in rglru_lib.layer_types(cfg)
                         if k == "attn")
            W = min(cfg.sliding_window or S, S)
            est.cache_bytes = n_attn * B * W * Kp * hd * 2 * bpe
            est.cache_bytes += (cfg.num_layers - n_attn) * B * cfg.rnn_dim * 4
        else:
            specs, n_blocks = block_layout(cfg, long_context=long_context)
            for spec in specs:
                W = min(spec.window or S, S)
                est.cache_bytes += int(n_blocks * B * W * Kp * hd * 2
                                       * kv_bpe)
    # first-order per-chip traffic: weights touched + cache + activations
    act_per_token = D * 12 * bpe  # ~12 residual-sized tensors per layer
    layers_eff = cfg.num_layers + cfg.encoder_layers
    if shape.kind == "train":
        passes = 3 + (1 if cfg.remat_policy == "nothing" else 0)
        state_mult = 3  # params r/w + opt state r/w (approx, ZeRO-sharded)
        est.act_bytes = T * act_per_token * layers_eff * passes / chips
        est.hbm_bytes_per_chip = (
            est.weight_bytes * (passes + state_mult) / chips + est.act_bytes)
    elif shape.kind == "prefill":
        est.act_bytes = T * act_per_token * layers_eff / chips
        est.hbm_bytes_per_chip = est.weight_bytes / chips + est.act_bytes
    else:
        est.act_bytes = T * act_per_token * layers_eff / chips
        est.hbm_bytes_per_chip = (est.weight_bytes / chips
                                  + est.cache_bytes / chips + est.act_bytes)
    return est
