"""Three-term roofline from a compiled SPMD artifact (DESIGN.md §7).

``cost_analysis()`` on this jax version reports PER-DEVICE FLOPs and HBM
bytes (verified by probe — a [16,32]x[32,64] matmul sharded 8 ways reports
~1/8 of global FLOPs).  Collective bytes are parsed from the post-SPMD
optimized HLO text: we sum the output-tensor bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
For ring implementations the on-wire bytes per device are ~(n-1)/n of the
gathered output; we report raw output bytes (slightly conservative).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.roofline import hw

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of 'bf16[8,64]' or a tuple '(bf16[8], f32[4,4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output bytes per collective type from optimized HLO."""
    out: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        # '%name = TYPE op-name(' — match the op right before '('
        for op in COLLECTIVE_OPS:
            token = f" {op}("
            mstart = ls.find(" = ")
            if mstart < 0 or token not in ls:
                continue
            lhs = ls[mstart + 3:ls.index(token) + 1]
            out[op] += _shape_bytes(lhs)
            out["count"] += 1
            break
    return out


_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_S32_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _split_computations(hlo_text: str):
    """Map computation name -> its body lines.  Header lines look like
    ``%name (args...) -> type {`` or ``ENTRY %name ...``, at column 0."""
    comps, cur, name = {}, [], None
    for line in hlo_text.splitlines():
        if (line and not line[0].isspace() and "->" in line
                and line.rstrip().endswith("{")):
            tok = line.split("(", 1)[0].strip()
            tok = tok.replace("ENTRY", "").strip().lstrip("%")
            if tok:
                if name is not None:
                    comps[name] = cur
                name, cur = tok, []
                continue
        if name is not None:
            if line.strip() == "}":
                comps[name] = cur
                name, cur = None, []
            else:
                cur.append(line)
    if name is not None:
        comps[name] = cur
    return comps


def collective_bytes_corrected(hlo_text: str) -> Dict[str, float]:
    """Collective output-bytes with while-loop trip-count multipliers.

    XLA prints scan loops as ``while`` ops; collectives inside the body
    appear once in the text but execute trip-count times.  Trip count is
    recovered from the largest s32 constant in the loop condition (the
    standard jax scan lowering compares an induction variable against the
    length).  Nested loops multiply.
    """
    comps = _split_computations(hlo_text)
    entry = None
    for cand in ("main", "entry"):
        for name in comps:
            if name.startswith(cand):
                entry = name
                break
        if entry:
            break
    if entry is None and comps:
        entry = next(iter(comps))

    def comp_collectives(lines):
        out = {k: 0 for k in COLLECTIVE_OPS}
        for line in lines:
            ls = line.strip()
            for op in COLLECTIVE_OPS:
                token = f" {op}("
                mstart = ls.find(" = ")
                if mstart < 0 or token not in ls:
                    continue
                lhs = ls[mstart + 3:ls.index(token) + 1]
                out[op] += _shape_bytes(lhs)
                break
        return out

    def trip_count(cond_name: str) -> int:
        consts = [int(x) for x in _S32_CONST_RE.findall(
            "\n".join(comps.get(cond_name, [])))]
        return max(consts) if consts else 1

    totals = {k: 0.0 for k in COLLECTIVE_OPS}
    visited_stack = []

    def walk(name: str, mult: float):
        if name not in comps or name in visited_stack:
            return
        visited_stack.append(name)
        lines = comps[name]
        own = comp_collectives(lines)
        for k, v in own.items():
            totals[k] += v * mult
        for line in lines:
            if " while(" in line:
                mcond = re.search(r"condition=%?([\w\.\-]+)", line)
                mbody = re.search(r"body=%?([\w\.\-]+)", line)
                if mcond and mbody:
                    walk(mbody.group(1), mult * trip_count(mcond.group(1)))
            else:
                for callee in _CALL_RE.findall(line):
                    walk(callee, mult)
        visited_stack.pop()

    if entry:
        walk(entry, 1.0)
    totals["count"] = sum(1 for _ in ())  # kept for schema compat
    return totals


@dataclasses.dataclass
class Roofline:
    flops: float                  # per device
    hbm_bytes: float              # per device
    coll_bytes: float             # per device (output-bytes heuristic)
    model_flops: float = 0.0      # 6*N_active*D global
    chips: int = 1

    @property
    def t_compute(self) -> float:
        return self.flops / hw.PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / hw.HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (hw.ICI_BW_PER_LINK * hw.ICI_LINKS)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / (global HLO flops): how much compute is 'useful'."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    def to_dict(self) -> Dict[str, float]:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "coll_bytes_per_device": self.coll_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
        }


def from_compiled(compiled, *, model_flops: float, chips: int) -> Roofline:
    ca = compiled.cost_analysis()
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    coll_total = sum(v for k, v in coll.items() if k != "count")
    return Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=coll_total,
                    model_flops=model_flops, chips=chips)
