"""TPU v5e hardware constants for the roofline model (per chip)."""
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW_PER_LINK = 50e9         # bytes/s per link (uni-directional here)
ICI_LINKS = 4                  # 2D torus on v5e: 4 links/chip
CHIPS_PER_POD = 256
HBM_BYTES = 16e9               # 16 GB HBM per v5e chip
