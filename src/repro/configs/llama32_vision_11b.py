"""Meta Llama-3.2-Vision 11B — cross-attention image layers.
[hf:meta-llama/Llama-3.2-11B-Vision]

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256; every 5th layer has a
gated cross-attention block over vision patch embeddings (STUB frontend —
``input_specs`` supplies precomputed patch embeddings, DESIGN §4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500_000.0,
    cross_attn_period=5,
    num_media_tokens=1601,   # 1 tile x (1600 patches + cls) from the stub ViT
)


def tiny() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="llama32v-tiny", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, head_dim=32, d_ff=384, vocab_size=512,
        cross_attn_period=2, num_media_tokens=16)
