"""Base model configuration for the repro model zoo.

Every assigned architecture gets one file in this package exposing a module-
level ``CONFIG: ModelConfig`` with the exact numbers from the assignment
(citation in the ``source`` field) plus a ``tiny()`` reduced variant used by
the per-arch smoke tests (2 layers, d_model <= 512, <= 4 experts).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description consumed by ``repro.models.registry``.

    The fields cover all six assigned families: dense / moe / ssm / hybrid /
    vlm / audio.  Family-specific fields are ignored by other families.
    """

    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    source: str                       # citation (arXiv / HF model card)

    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0                # 0 for attention-free (rwkv)
    num_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 128

    # --- attention behaviour ---
    rope_theta: float = 10_000.0
    sliding_window: int = 0           # 0 = full attention
    local_global_pattern: int = 0     # gemma2: every Nth layer is global (N=2)
    attn_logit_softcap: float = 0.0   # gemma2: 50.0
    final_logit_softcap: float = 0.0  # gemma2: 30.0
    # long-context decode: serve "global" layers with a window (DESIGN §5)
    long_context_windowed: bool = False

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0      # top-k
    moe_layer_period: int = 1         # llama4: 2 (every other layer is MoE)
    dense_residual: bool = False      # arctic: parallel dense FFN in MoE layers
    shared_expert: bool = False       # llama4: one always-on expert
    expert_d_ff: int = 0              # defaults to d_ff
    capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.01

    # --- SSM / hybrid ---
    rnn_width: int = 0                # rglru recurrent width (default d_model)
    conv_width: int = 4               # temporal conv window (rglru)
    attn_layer_period: int = 0        # recurrentgemma: every 3rd layer is attn
    rwkv_head_dim: int = 64

    # --- vlm / audio (frontends are stubs per DESIGN §4) ---
    cross_attn_period: int = 0        # llama3.2-vision: every 5th layer
    num_media_tokens: int = 0         # stub patch/frame embedding count
    encoder_layers: int = 0           # whisper: encoder depth
    encoder_seq: int = 0              # whisper: 1500 frames
    is_encoder_decoder: bool = False

    # --- norms / misc ---
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    post_norms: bool = False          # gemma2 post-attn/post-ffn norms
    act: str = "silu"                 # silu | gelu
    gated_mlp: bool = True            # 3-matrix SwiGLU vs 2-matrix MLP
    tie_embeddings: bool = True
    embedding_scale: bool = False     # gemma-style sqrt(d) input scaling
    dtype: str = "bfloat16"

    # --- training ---
    optimizer: str = "adamw"          # adamw | adafactor (giant MoEs)
    remat_policy: str = "nothing"     # nothing | dots | everything
    grad_accum: int = 1               # microbatch accumulation steps
    accum_dtype: str = "float32"      # bf16 for 480B-class (memory, DESIGN §4)

    # --- kernels ---
    use_pallas: bool = False          # Pallas kernels (interpret on CPU)
    kv_quant: bool = False            # int8 KV cache (beyond-paper, §Perf C)
    expert_quant: bool = False        # int8 expert weights (serving, §Perf A)
    bf16_boundary: bool = False       # pin bf16 at reshard boundaries (§Perf B)
    seq_shard: bool = True            # sequence-parallel residual (§Perf B alt)
    rs_outputs: bool = False          # constrain layer outputs seq-sharded
                                      # to induce reduce-scatter (§Perf B)
    causal_skip: bool = False         # triangle-pair chunked attention
                                      # (skip masked chunks, §Perf prefill)

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def mlp_mats(self) -> int:
        return 3 if self.gated_mlp else 2

    @property
    def expert_ff(self) -> int:
        return self.expert_d_ff or self.d_ff

    @property
    def rnn_dim(self) -> int:
        return self.rnn_width or self.d_model

    @property
    def num_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def padded_heads(self, model_axis: int) -> int:
        """Q heads padded to a multiple of the model-parallel axis."""
        if self.num_heads == 0:
            return 0
        return _round_up(self.num_heads, model_axis)

    def replicated_kv_heads(self, model_axis: int) -> int:
        """KV heads replicated Megatron-style to a multiple of model axis."""
        if self.num_kv_heads == 0:
            return 0
        if self.num_kv_heads >= model_axis:
            return _round_up(self.num_kv_heads, model_axis)
        return model_axis

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (unpadded heads, untied count once)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        hd = self.head_dim
        n = V * D  # embedding
        if not self.tie_embeddings:
            n += V * D
        for layer in range(L):
            if self.family == "ssm":  # rwkv6
                n += 4 * D * D + int(2.5 * D * D)  # time-mix r,k,v,o,g + loras
                n += 2 * D * self.d_ff             # channel mix
                n += 2 * D
                continue
            is_attn = True
            if self.family == "hybrid" and self.attn_layer_period:
                is_attn = (layer % self.attn_layer_period) == (
                    self.attn_layer_period - 1)
            if is_attn and self.num_heads:
                n += D * self.num_heads * hd               # wq
                n += 2 * D * self.num_kv_heads * hd        # wk, wv
                n += self.num_heads * hd * D               # wo
            elif self.family == "hybrid":
                R = self.rnn_dim
                n += 2 * D * R + R * D + R * self.conv_width + 2 * R * R // 8
            is_moe = (self.num_experts > 0
                      and (layer % self.moe_layer_period)
                      == (self.moe_layer_period - 1))
            if is_moe:
                n += D * self.num_experts                   # router
                n += self.num_experts * self.mlp_mats * D * self.expert_ff
                if self.dense_residual or self.shared_expert:
                    n += self.mlp_mats * D * F
            else:
                n += self.mlp_mats * D * F
            if self.cross_attn_period and (layer % self.cross_attn_period
                                           == self.cross_attn_period - 1):
                n += 2 * D * self.num_heads * hd
                n += 2 * D * self.num_kv_heads * hd
            n += 2 * D                                      # norms
        if self.is_encoder_decoder:
            # encoder layers: self-attn + ffn; decoder cross-attn counted here
            n += self.encoder_layers * (4 * D * D + 2 * D * self.d_ff + 2 * D)
            n += self.num_layers * (4 * D * D)              # decoder cross-attn
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE top-k) for MODEL_FLOPS = 6*N_active*D."""
        if self.num_experts == 0:
            return self.param_count()
        full = self.param_count()
        # subtract inactive expert params
        moe_layers = sum(1 for layer in range(self.num_layers)
                         if (layer % self.moe_layer_period)
                         == (self.moe_layer_period - 1))
        per_expert = self.mlp_mats * self.d_model * self.expert_ff
        inactive = moe_layers * (self.num_experts
                                 - self.num_experts_per_tok) * per_expert
        return full - inactive

    def validate(self) -> None:
        assert self.family in ("dense", "moe", "ssm", "hybrid", "vlm", "audio")
        if self.family != "ssm":
            assert self.num_heads > 0 and self.head_dim > 0
        if self.num_experts:
            assert self.num_experts_per_tok >= 1
        assert self.d_model > 0 and self.num_layers > 0 and self.vocab_size > 0


def human(n: float) -> str:
    for unit in ("", "K", "M", "B", "T"):
        if abs(n) < 1000:
            return f"{n:.1f}{unit}"
        n /= 1000
    return f"{n:.1f}P"
