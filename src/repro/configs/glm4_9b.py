"""THUDM GLM-4-9B — dense GQA with RoPE, large vocab. [hf:THUDM/glm-4-9b]

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    source="hf:THUDM/glm-4-9b",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=151552,
    rope_theta=10_000.0,
)


def tiny() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="glm4-tiny", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, head_dim=32, d_ff=384, vocab_size=512)
