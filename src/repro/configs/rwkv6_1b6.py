"""RWKV-6 "Finch" 1.6B — attention-free, data-dependent decay.
[arXiv:2404.05892]

24L d_model=2048 (attn-free; 32 wkv heads of dim 64) d_ff=7168 vocab=65536.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    source="arXiv:2404.05892",
    num_layers=24,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=7168,
    vocab_size=65536,
    rwkv_head_dim=64,
    norm="layernorm",
)


def tiny() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="rwkv6-tiny", num_layers=2, d_model=128, d_ff=256,
        vocab_size=512, rwkv_head_dim=32)
