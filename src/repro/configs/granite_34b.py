"""IBM Granite-34B-Code — deep llama-arch MQA. [arXiv:2405.04324]

88L d_model=6144 48H (GQA kv=1, i.e. MQA) d_ff=24576 vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    source="arXiv:2405.04324",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    gated_mlp=False,
    rope_theta=10_000.0,
)


def tiny() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="granite-tiny", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=1, head_dim=32, d_ff=384, vocab_size=512)
