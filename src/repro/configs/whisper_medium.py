"""OpenAI Whisper-medium — encoder-decoder with conv/mel frontend stub.
[arXiv:2212.04356]

24L (decoder) d_model=1024 16H (kv=16, i.e. MHA) d_ff=4096 vocab=51865, plus a
24L encoder over 1500 stub frame embeddings (the mel+conv frontend is the
allowed stub; ``input_specs`` supplies (B, 1500, 1024) frames).
vocab padded 51865 -> 51968 for SPMD divisibility (DESIGN §4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    source="arXiv:2212.04356",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    gated_mlp=False,
    is_encoder_decoder=True,
    encoder_layers=24,
    encoder_seq=1500,
    norm="layernorm",
    act="gelu",
    rope_theta=0.0,          # whisper uses learned/sinusoidal positions
)


def tiny() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="whisper-tiny", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
        encoder_layers=2, encoder_seq=64)
