"""Snowflake Arctic 480B — dense-MoE hybrid. [hf:Snowflake/snowflake-arctic-base]

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128 experts top-2
with a parallel dense residual FFN on every layer.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    source="hf:Snowflake/snowflake-arctic-base",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    num_experts=128,
    num_experts_per_tok=2,
    moe_layer_period=1,
    dense_residual=True,
    expert_d_ff=4864,
    rope_theta=10_000.0,
    optimizer="adafactor",       # AdamW m+v at 480B does not fit 16GB/chip
    grad_accum=8,                # fits 480B-class train under 16GB/chip
    accum_dtype="bfloat16",
    remat_policy="nothing",
)


def tiny() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="arctic-tiny", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, head_dim=32, d_ff=256, expert_d_ff=256, vocab_size=512,
        num_experts=4, num_experts_per_tok=2, optimizer="adamw",
        grad_accum=1, accum_dtype="float32")
