"""01.AI Yi-9B — llama-architecture dense GQA. [arXiv:2403.04652]

48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    source="arXiv:2403.04652",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=10_000.0,
)


def tiny() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="yi-tiny", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, head_dim=32, d_ff=384, vocab_size=512)
