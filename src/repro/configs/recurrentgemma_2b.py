"""Google RecurrentGemma-2B (Griffin) — RG-LRU + local attention 2:1.
[arXiv:2402.19427]

26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000.  Pattern: two
RG-LRU recurrent blocks (temporal conv width 4) then one 2048-window local
attention block.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    source="arXiv:2402.19427",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    rnn_width=2560,
    conv_width=4,
    attn_layer_period=3,     # layers 2,5,8,... are local attention
    sliding_window=2048,
    embedding_scale=True,
    act="gelu",
)


def tiny() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="rgemma-tiny", num_layers=6, d_model=128, num_heads=4,
        num_kv_heads=1, head_dim=32, d_ff=256, vocab_size=512, rnn_width=128,
        sliding_window=32)
