"""Config registry: one module per assigned architecture (+ shapes)."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig
from repro.configs.shapes import (  # noqa: F401
    SHAPES, LONG_CONTEXT_OK, InputShape,
    TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)

_MODULES = {
    "arctic-480b": "repro.configs.arctic_480b",
    "yi-9b": "repro.configs.yi_9b",
    "glm4-9b": "repro.configs.glm4_9b",
    "granite-34b": "repro.configs.granite_34b",
    "gemma2-9b": "repro.configs.gemma2_9b",
    "llama-3.2-vision-11b": "repro.configs.llama32_vision_11b",
    "whisper-medium": "repro.configs.whisper_medium",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b",
    "rwkv6-1.6b": "repro.configs.rwkv6_1b6",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    cfg = importlib.import_module(_MODULES[arch]).CONFIG
    cfg.validate()
    return cfg


def get_tiny_config(arch: str) -> ModelConfig:
    cfg = importlib.import_module(_MODULES[arch]).tiny()
    cfg.validate()
    return cfg


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
