"""Meta Llama-4 Maverick 400B-A17B — interleaved MoE, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E]

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048; MoE 128 experts top-1
on every 2nd layer plus a shared expert (matches the 400B total / 17B active
and the Llama-4 interleave).  Early fusion: image tokens from the stub
frontend are interleaved in the input sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    num_experts=128,
    num_experts_per_tok=1,
    moe_layer_period=2,      # every other layer is MoE
    shared_expert=True,
    expert_d_ff=8192,
    rope_theta=500_000.0,
    optimizer="adafactor",
    grad_accum=8,                # fits 480B-class train under 16GB/chip
    accum_dtype="bfloat16",
    remat_policy="nothing",
)


def tiny() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="llama4-tiny", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, head_dim=32, d_ff=256, expert_d_ff=256, vocab_size=512,
        num_experts=4, num_experts_per_tok=1, optimizer="adamw",
        grad_accum=1, accum_dtype="float32")
