"""Google Gemma-2 9B — alternating local/global attention, logit softcaps.
[arXiv:2408.00118]

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000.  Odd layers are
global, even layers use a 4096 sliding window; attention-logit softcap 50,
final-logit softcap 30; gemma-style post-norms and sqrt(d) embedding scale.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    source="arXiv:2408.00118",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    rope_theta=10_000.0,
    sliding_window=4096,
    local_global_pattern=2,        # every 2nd layer global, others local
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    post_norms=True,
    embedding_scale=True,
    act="gelu",
    long_context_windowed=True,    # DESIGN §5: windowed globals for long_500k
)


def tiny() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="gemma2-tiny", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, head_dim=32, d_ff=384, vocab_size=512,
        sliding_window=64)
