"""Dataflow rewrites (paper §4) — compatibility shims.

The transforms now live as passes over the physical-plan IR
(``repro.core.passes``); these wrappers keep the original logical-level
API: each lowers the ``Dataflow`` to a ``PhysicalPlan``, runs the
corresponding pass, and lifts the result back to a ``Dataflow``.

* ``fuse_chains``  -> ``FuseChainsPass``
* ``competitive``  -> ``CompetitivePass``
* ``fuse_lookups`` -> ``FuseLookupsPass``
* ``apply_rewrites`` -> ``build_pipeline`` over the optimization flags

New code should use ``PhysicalPlan.from_dataflow`` + ``PassPipeline``
directly (as ``repro.core.compiler`` does) and skip the round-trip.
"""
from __future__ import annotations

from repro.core.dataflow import Dataflow
from repro.core.ir import PhysicalPlan
from repro.core.passes import (CompetitivePass, FuseChainsPass,
                               FuseLookupsPass, PassContext, build_pipeline)


def _via_pass(flow: Dataflow, p) -> Dataflow:
    plan = PhysicalPlan.from_dataflow(flow)
    return p.run(plan, PassContext()).to_dataflow()


def fuse_chains(flow: Dataflow, *, across_resource_classes: bool = False,
                preserve_lookup_boundaries: bool = False) -> Dataflow:
    """Collapse single-consumer linear chains into ``Fuse`` ops (§4)."""
    return _via_pass(flow, FuseChainsPass(
        across_resource_classes=across_resource_classes,
        preserve_lookup_boundaries=preserve_lookup_boundaries))


def competitive(flow: Dataflow, *, default_replicas: int = 3) -> Dataflow:
    """Replicate high-variance ops and consume with ``anyof`` (§4)."""
    return _via_pass(flow, CompetitivePass(default_replicas=default_replicas))


def fuse_lookups(flow: Dataflow) -> Dataflow:
    """Fuse lookups into their consumer for data locality (§4)."""
    return _via_pass(flow, FuseLookupsPass())


def apply_rewrites(flow: Dataflow, *, fusion: bool = False,
                   competitive_exec: bool = False,
                   locality: bool = False,
                   default_replicas: int = 3) -> Dataflow:
    flow.typecheck()
    pipeline = build_pipeline(fusion=fusion, competitive_exec=competitive_exec,
                              locality=locality, jit_fusion=False,
                              default_replicas=default_replicas)
    plan = pipeline.run(PhysicalPlan.from_dataflow(flow))
    out = plan.to_dataflow()
    out.typecheck()
    return out
