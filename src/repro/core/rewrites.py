"""Dataflow rewrites (paper §4): Cloudflow -> Cloudflow graph transforms.

* ``fuse_chains`` — operator fusion: greedily collapse linear chains into a
  single ``Fuse`` operator (optionally not across resource-class boundaries).
* ``competitive`` — replicate high-variance operators k times and consume the
  results with ``anyof`` (wait-for-any).
* ``fuse_lookups`` — locality: fuse each ``lookup`` with its *downstream*
  operator so processing is colocated with the data; the compiler then marks
  the fused node for dynamic dispatch.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core import operators as ops
from repro.core.dataflow import Dataflow, Node


def _clone_flow(flow: Dataflow) -> Dataflow:
    new = Dataflow(flow.input_schema)
    mapping: Dict[int, Node] = {flow.source.id: new.source}

    def clone(n: Node) -> Node:
        if n.id in mapping:
            return mapping[n.id]
        ups = [clone(u) for u in n.upstreams]
        nn = Node(new, n.op, ups)
        mapping[n.id] = nn
        return nn

    new.output = clone(flow.output)
    return new


def _downstream_counts(flow: Dataflow) -> Dict[int, int]:
    counts: Dict[int, int] = {}
    for n in flow.sorted_nodes():
        for u in n.upstreams:
            counts[u.id] = counts.get(u.id, 0) + 1
    return counts


def _starts_with_lookup(op) -> bool:
    return isinstance(op, ops.Lookup) or (
        isinstance(op, ops.Fuse) and op.ops
        and isinstance(op.ops[0], ops.Lookup))


def fuse_chains(flow: Dataflow, *, across_resource_classes: bool = False,
                preserve_lookup_boundaries: bool = False) -> Dataflow:
    """Collapse a->b chains where a has exactly one consumer (b) and b has a
    single input.  ``Fuse(ops)`` executes at one location (paper §4).
    With ``preserve_lookup_boundaries`` a node whose chain STARTS with a
    lookup keeps its upstream un-fused so the dynamic-dispatch scheduler
    sees the resolved ref (the paper's to-be-continued split point)."""
    flow = _clone_flow(flow)
    changed = True
    while changed:
        changed = False
        counts = _downstream_counts(flow)
        for n in flow.sorted_nodes():
            if n.op is None or len(n.upstreams) != 1:
                continue
            up = n.upstreams[0]
            if up.op is None or counts.get(up.id, 0) != 1:
                continue
            if len(up.upstreams) != 1:   # never fuse across multi-input ops
                continue
            if isinstance(up.op, ops.AnyOf):
                continue
            if preserve_lookup_boundaries and _starts_with_lookup(n.op):
                continue
            if not across_resource_classes:
                if up.op.resource_class != n.op.resource_class:
                    continue
            if up.op.batching != n.op.batching:
                continue
            up_ops = up.op.ops if isinstance(up.op, ops.Fuse) else [up.op]
            n_ops = n.op.ops if isinstance(n.op, ops.Fuse) else [n.op]
            fused = ops.Fuse(up_ops + n_ops)
            fused.resource_class = n.op.resource_class
            fused.batching = n.op.batching
            n.op = fused
            n.upstreams = list(up.upstreams)
            changed = True
            break
    return flow


def competitive(flow: Dataflow, *, default_replicas: int = 3) -> Dataflow:
    """Replicate operators flagged high_variance (or with explicit
    ``competitive_replicas``) and add ``anyof`` (paper §4)."""
    flow = _clone_flow(flow)
    for n in list(flow.sorted_nodes()):
        if n.op is None:
            continue
        k = n.op.competitive_replicas or (
            default_replicas if n.op.high_variance else 0)
        if k <= 1:
            continue
        replicas = []
        for _ in range(k):
            rep_op = copy.copy(n.op)
            rep = Node(flow, rep_op, list(n.upstreams))
            replicas.append(rep)
        # n becomes the anyof consuming the replicas
        n.op = ops.AnyOf()
        n.upstreams = replicas
    return flow


def fuse_lookups(flow: Dataflow) -> Dataflow:
    """Fuse each lookup with its single downstream consumer so computation is
    colocated with the cached data (paper §4: Data Locality)."""
    flow = _clone_flow(flow)
    changed = True
    while changed:
        changed = False
        counts = _downstream_counts(flow)
        for n in flow.sorted_nodes():
            if n.op is None or len(n.upstreams) != 1:
                continue
            up = n.upstreams[0]
            if up.op is None or counts.get(up.id, 0) != 1:
                continue
            if len(up.upstreams) != 1:
                continue
            is_lookup = isinstance(up.op, ops.Lookup) or (
                isinstance(up.op, ops.Fuse)
                and isinstance(up.op.ops[-1], ops.Lookup))
            if not is_lookup or isinstance(n.op, (ops.Fuse,)):
                pass
            if not is_lookup:
                continue
            up_ops = up.op.ops if isinstance(up.op, ops.Fuse) else [up.op]
            n_ops = n.op.ops if isinstance(n.op, ops.Fuse) else [n.op]
            fused = ops.Fuse(up_ops + n_ops)
            fused.resource_class = n.op.resource_class
            n.op = fused
            n.upstreams = list(up.upstreams)
            changed = True
            break
    return flow


def apply_rewrites(flow: Dataflow, *, fusion: bool = False,
                   competitive_exec: bool = False,
                   locality: bool = False,
                   default_replicas: int = 3) -> Dataflow:
    flow.typecheck()
    if locality:
        flow = fuse_lookups(flow)
    if competitive_exec:
        flow = competitive(flow, default_replicas=default_replicas)
    if fusion:
        flow = fuse_chains(flow, preserve_lookup_boundaries=locality)
    flow.typecheck()
    return flow
