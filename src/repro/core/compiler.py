"""Dataflow-to-FaaS compilation (paper §4), as an explicit pipeline:

    logical ``Dataflow``
      -> ``PhysicalPlan`` IR        (``PhysicalPlan.from_dataflow``)
      -> optimization passes        (``repro.core.passes.PassPipeline``)
      -> runtime DAG                (``RuntimeDag.from_plan``)

The pass pipeline carries the paper's rewrites (fusion, competitive
execution, locality) plus XLA lowering of fused JAX chains; scheduling
annotations (placement, batching, wait-for-any, dynamic-dispatch locality
refs) travel on the IR and are consumed verbatim by the runtime lowering.
``anyof`` nodes get *wait-for-any* semantics; fused ``lookup`` chains get
the *to-be-continued* dynamic-dispatch treatment: the scheduler defers
placement of the node until the resolved ref exists, then prefers an
executor caching it (paper's split-DAG decision point).
"""
from __future__ import annotations

import itertools
from typing import List, Optional

from repro.core.dataflow import Dataflow
from repro.core.ir import PhysicalPlan
from repro.core.passes import PassContext, PassPipeline, PassTrace, \
    build_pipeline
from repro.core.table import Table
from repro.runtime.dag import RuntimeDag

_flow_ids = itertools.count()


def compile_flow(flow: Dataflow, runtime, *, fusion: bool = False,
                 competitive_exec: bool = False, locality: bool = False,
                 jit_fusion: bool = True, batched_lowering: bool = True,
                 default_replicas: int = 3,
                 place_kernels: bool = True,
                 pipeline: Optional[PassPipeline] = None,
                 plan_config=None,
                 name: Optional[str] = None,
                 register: bool = True,
                 verify=None,
                 verify_input=None,
                 verify_budget_bytes: Optional[int] = None) -> "DeployedFlow":
    """Compile + register ``flow``.  Pass either optimization flags (mapped
    to a pass configuration via ``build_pipeline``) or an explicit
    ``pipeline``.  ``plan_config`` (a ``repro.profiling.optimizer``
    ``PlanConfig``) threads the SLO optimizer's per-node choices through
    the pass pipeline AND applies the runtime-side knobs (per-node batcher
    window/max-batch, padding buckets) to the fresh deployment.

    ``register=False`` compiles OFF the serving path: the DAG is prepared
    (generation assigned, drivable via ``Runtime.call_dag_object``) but no
    traffic routes to it and any live deployment under ``name`` is
    untouched — the blue/green replanner's green-compile step.  The caller
    activates it later with ``runtime.register_dag(dep.dag, plan=dep.plan)``
    and applies the plan-config's runtime knobs after the swap.

    ``verify`` runs the static plan verifier (``repro.analysis``) over
    the optimized plan BEFORE the DAG is registered or any XLA trace
    happens: ``True``/``"error"`` raises ``VerificationError`` on any
    severity=error diagnostic; ``"warn"`` only attaches the report
    (``DeployedFlow.verification``); ``None``/``False`` skips analysis.
    ``verify_input`` (a sample request ``Table`` or a ``{column:
    ShapeDtypeStruct}`` dict) enables shape/dtype/kernel-tile/memory
    inference; ``verify_budget_bytes`` overrides the device-memory
    budget (default: the runtime pool's cache budget)."""
    flow.typecheck()
    plan = PhysicalPlan.from_dataflow(flow)
    # remember the flag set (None under an explicit pipeline): a replan
    # recompile must reproduce the pass configuration, because PlanConfig
    # op ids are only stable across recompiles with the SAME flags
    compile_flags = None if pipeline is not None else {
        "fusion": fusion, "competitive_exec": competitive_exec,
        "locality": locality, "jit_fusion": jit_fusion,
        "batched_lowering": batched_lowering,
        "default_replicas": default_replicas,
        "place_kernels": place_kernels}
    if pipeline is None:
        pipeline = build_pipeline(
            fusion=fusion, competitive_exec=competitive_exec,
            locality=locality, jit_fusion=jit_fusion,
            batched_lowering=batched_lowering,
            default_replicas=default_replicas,
            place_kernels=place_kernels,
            plan_config=plan_config)
    ctx = PassContext()
    plan = pipeline.run(plan, ctx)
    dag_name = name or f"flow{next(_flow_ids)}"
    verification = None
    if verify:
        # verify BEFORE register/prepare: jit tracing is lazy, so raising
        # here guarantees a rejected plan never reaches XLA or traffic
        from repro.analysis import VerificationError, analyze
        from repro.core.table import Table as _Table
        sample = verify_input if isinstance(verify_input, _Table) else None
        specs = verify_input if isinstance(verify_input, dict) else None
        verification = analyze(
            plan, runtime=runtime, plan_config=plan_config,
            sample=sample, input_specs=specs,
            budget_bytes=verify_budget_bytes, name=dag_name)
        if verify != "warn" and not verification.ok:
            raise VerificationError(verification,
                                    context=f"compile of {dag_name!r}")
    if register:
        dag = runtime.register_plan(plan, dag_name)
    else:
        dag = RuntimeDag.from_plan(plan, dag_name)
        runtime.prepare_dag(dag)
    deployed = DeployedFlow(flow, plan, dag, runtime, ctx.trace)
    deployed.compile_flags = compile_flags
    deployed.verification = verification
    if plan_config is not None and register:
        plan_config.apply_runtime(runtime, dag)
    return deployed


class DeployedFlow:
    def __init__(self, flow: Dataflow, plan: PhysicalPlan, dag: RuntimeDag,
                 runtime, pass_trace: Optional[List[PassTrace]] = None):
        self.flow = flow
        self.plan = plan
        self.dag = dag
        self.runtime = runtime
        self.pass_trace = pass_trace or []
        #: the build_pipeline flag set this flow was compiled with (None
        #: when an explicit pipeline was passed) — what a blue/green
        #: recompile must reuse for op-id-stable PlanConfig application
        self.compile_flags: Optional[dict] = None
        #: the static verifier's Report when compiled with ``verify=``
        #: (None when verification was skipped)
        self.verification = None

    @property
    def rewritten(self) -> Dataflow:
        """The optimized plan, lifted back to a logical ``Dataflow``
        (compatibility view; prefer ``.plan``)."""
        return self.plan.to_dataflow()

    def execute(self, table: Table):
        return self.runtime.call_dag(self.dag.name, table)

    @property
    def function_names(self):
        return list(self.dag.nodes)

    def explain(self) -> str:
        """Human-readable compile report: plan + per-pass trace, plus —
        when the runtime's tracer holds kept traces for this flow — the
        per-node SLO-miss attribution table (where the milliseconds of
        the interesting requests actually went)."""
        lines = [repr(self.plan), ""]
        lines += [repr(t) for t in self.pass_trace]
        tracer = getattr(self.runtime, "tracer", None)
        if tracer is not None and tracer.enabled:
            kept = tracer.kept(self.dag.name)
            if kept:
                from repro.obs.attribution import attribute
                att = attribute(kept)
                lines += ["", f"-- observed attribution "
                          f"({att.n_traces} kept traces, "
                          f"{att.n_miss} SLO misses, {att.n_shed} shed) --",
                          att.table()]
        return "\n".join(lines)
