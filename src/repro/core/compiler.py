"""Dataflow-to-FaaS compilation (paper §4).

Maps a (rewritten) Cloudflow DAG onto a runtime DAG of functions:

* each operator (or fused chain) becomes one runtime function;
* ``anyof`` nodes get *wait-for-any* semantics;
* fused ``lookup`` chains get the *to-be-continued* dynamic-dispatch
  treatment: executor choice for the continuation is deferred until the
  upstream half has produced the resolved ref, and the scheduler then
  prefers an executor caching that ref.  (The paper splits into two
  Cloudburst DAGs + a scheduler callback; our scheduler defers placement of
  the single node until its inputs exist, which is the same decision point.)
"""
from __future__ import annotations

import itertools
from typing import Any, Dict, Optional

from repro.core import operators as ops
from repro.core.dataflow import Dataflow, Node
from repro.core.rewrites import apply_rewrites
from repro.core.table import Table
from repro.runtime.dag import RuntimeDag, RuntimeNode

_flow_ids = itertools.count()


def _wrap(op: ops.Operator):
    def fn(tables, ctx):
        return op.apply(tables, ctx)
    return fn


def compile_flow(flow: Dataflow, runtime, *, fusion: bool = False,
                 competitive_exec: bool = False, locality: bool = False,
                 default_replicas: int = 3,
                 name: Optional[str] = None) -> "DeployedFlow":
    rewritten = apply_rewrites(
        flow, fusion=fusion, competitive_exec=competitive_exec,
        locality=locality, default_replicas=default_replicas)
    dag_name = name or f"flow{next(_flow_ids)}"
    nodes: Dict[str, RuntimeNode] = {}
    node_name: Dict[int, str] = {}
    out_name = None
    for n in rewritten.sorted_nodes():
        if n.op is None:
            continue
        nm = f"{dag_name}/{n.id}:{n.op.name}"[:120]
        node_name[n.id] = nm
        deps = [node_name[u.id] for u in n.upstreams if u.op is not None]
        rn = RuntimeNode(
            name=nm, fn=_wrap(n.op), deps=deps,
            resource_class=n.op.resource_class,
            batching=n.op.batching,
            wait_any=isinstance(n.op, ops.AnyOf),
        )
        # dynamic dispatch for fused lookups
        lk = None
        if isinstance(n.op, ops.Lookup):
            lk = n.op
        elif isinstance(n.op, ops.Fuse):
            for sub in n.op.ops:
                if isinstance(sub, ops.Lookup):
                    lk = sub
                    break
        if lk is not None and locality:
            if lk.is_column:
                rn.locality_ref_column = lk.key
            else:
                rn.locality_const = lk.key
        nodes[nm] = rn
        out_name = nm
    dag = RuntimeDag(dag_name, nodes, node_name[rewritten.output.id])
    runtime.register_dag(dag)
    return DeployedFlow(flow, rewritten, dag, runtime)


class DeployedFlow:
    def __init__(self, flow: Dataflow, rewritten: Dataflow, dag: RuntimeDag,
                 runtime):
        self.flow = flow
        self.rewritten = rewritten
        self.dag = dag
        self.runtime = runtime

    def execute(self, table: Table):
        return self.runtime.call_dag(self.dag.name, table)

    @property
    def function_names(self):
        return list(self.dag.nodes)
