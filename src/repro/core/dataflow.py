"""Lazy Dataflow DAG builder (paper §3.1, Fig 2).

    flow = Dataflow([("url", str)])
    img = flow.map(preproc)
    p1, p2 = img.map(model_a), img.map(model_b)
    flow.output = p1.union(p2).groupby("label").agg("max", "conf")
    flow.deploy(runtime)          # compiles + registers with the runtime
    fut = flow.execute(table)     # returns a future
    result = fut.result()
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import operators as ops
from repro.core.table import Table, Schema

_node_ids = itertools.count()


class Node:
    def __init__(self, flow: "Dataflow", op: Optional[ops.Operator],
                 upstreams: List["Node"]):
        self.flow = flow
        self.op = op
        self.upstreams = upstreams
        self.id = next(_node_ids)
        flow._nodes.append(self)

    # -- fluent operator API -------------------------------------------------
    def _hints(self, op: ops.Operator, *, gpu=False, batching=False,
               high_variance=False, competitive_replicas=0):
        op.resource_class = "gpu" if gpu else "cpu"
        op.batching = batching
        op.high_variance = high_variance
        op.competitive_replicas = competitive_replicas
        return op

    def map(self, fn: Callable, names: Optional[Sequence[str]] = None,
            **hints) -> "Node":
        return Node(self.flow, self._hints(ops.Map(fn, names), **hints),
                    [self])

    def filter(self, fn: Callable, **hints) -> "Node":
        return Node(self.flow, self._hints(ops.Filter(fn), **hints), [self])

    def apply_op(self, op: ops.Operator, **hints) -> "Node":
        """Attach a prebuilt single-input operator (e.g. a ``ModelOp`` from
        ``repro.models.registry.model_stage_op``) as the next node."""
        return Node(self.flow, self._hints(op, **hints), [self])

    def groupby(self, column: str) -> "Node":
        return Node(self.flow, ops.GroupBy(column), [self])

    def agg(self, agg_fn: str, column: str) -> "Node":
        return Node(self.flow, ops.Agg(agg_fn, column), [self])

    def lookup(self, key: str, *, column: bool = False,
               out_name: str = "lookup") -> "Node":
        return Node(self.flow, ops.Lookup(key, is_column=column,
                                          out_name=out_name), [self])

    def join(self, other: "Node", key: Optional[str] = None,
             how: str = "inner") -> "Node":
        return Node(self.flow, ops.Join(key, how), [self, other])

    def union(self, *others: "Node") -> "Node":
        return Node(self.flow, ops.Union(), [self, *others])

    def anyof(self, *others: "Node") -> "Node":
        return Node(self.flow, ops.AnyOf(), [self, *others])

    def __repr__(self):
        return f"Node#{self.id}({self.op.name if self.op else 'input'})"


class Dataflow:
    def __init__(self, input_schema: Schema):
        self.input_schema = [(str(n), t) for n, t in input_schema]
        self._nodes: List[Node] = []
        self.source = Node(self, None, [])
        self._output: Optional[Node] = None
        self._deployed = None

    # -- sugar: source-level ops ----------------------------------------------
    def map(self, fn, names=None, **hints):
        return self.source.map(fn, names, **hints)

    def filter(self, fn, **hints):
        return self.source.filter(fn, **hints)

    def lookup(self, key, **kw):
        return self.source.lookup(key, **kw)

    def apply_op(self, op, **hints):
        return self.source.apply_op(op, **hints)

    @property
    def output(self) -> Optional[Node]:
        return self._output

    @output.setter
    def output(self, node: Node):
        if node.flow is not self:
            raise ValueError("output must derive from this Dataflow")
        self._output = node

    # -- composition (paper §3.3) ----------------------------------------------
    def extend(self, other: "Dataflow") -> "Dataflow":
        """Append ``other``'s DAG after this flow's output."""
        if self._output is None or other._output is None:
            raise ValueError("both flows need outputs to extend")
        combined = Dataflow(self.input_schema)
        mapping: Dict[int, Node] = {self.source.id: combined.source}

        def clone(node: Node, flow_src: Dataflow) -> Node:
            if node.id in mapping:
                return mapping[node.id]
            ups = [clone(u, flow_src) for u in node.upstreams]
            nn = Node(combined, node.op, ups)
            mapping[node.id] = nn
            return nn

        tail = clone(self._output, self)
        mapping[other.source.id] = tail
        combined._output = clone(other._output, other)
        return combined

    # -- typechecking -----------------------------------------------------------
    def sorted_nodes(self) -> List[Node]:
        if self._output is None:
            raise ValueError("flow has no output assigned")
        seen: Dict[int, Node] = {}
        order: List[Node] = []

        def visit(n: Node):
            if n.id in seen:
                return
            seen[n.id] = n
            for u in n.upstreams:
                visit(u)
            order.append(n)

        visit(self._output)
        return order

    def typecheck(self) -> Dict[int, Tuple[Schema, Optional[str]]]:
        """Propagate (schema, grouping) through the DAG; raises on mismatch."""
        info: Dict[int, Tuple[Schema, Optional[str]]] = {}
        for n in self.sorted_nodes():
            if n.op is None:
                info[n.id] = (self.input_schema, None)
            else:
                schemas = [info[u.id][0] for u in n.upstreams]
                groupings = [info[u.id][1] for u in n.upstreams]
                info[n.id] = (n.op.typecheck(schemas),
                              n.op.out_grouping(groupings))
        return info

    # -- local interpreter (tests / reference semantics) -------------------------
    def execute_local(self, table: Table, ctx=None) -> Table:
        self.typecheck()
        results: Dict[int, Table] = {}
        for n in self.sorted_nodes():
            if n.op is None:
                results[n.id] = table
            else:
                ins = [results[u.id] for u in n.upstreams]
                results[n.id] = n.op.apply(ins, ctx)
        return results[self._output.id]

    # -- runtime deployment -------------------------------------------------------
    def deploy(self, runtime, **opt_flags):
        from repro.core.compiler import compile_flow
        self._deployed = compile_flow(self, runtime, **opt_flags)
        return self._deployed

    def execute(self, table: Table):
        if self._deployed is None:
            raise RuntimeError("deploy() the flow first")
        return self._deployed.execute(table)
