"""Cloudflow operators (paper Table 1) with schema propagation and local
evaluation semantics.

Every operator maps input Table(s) to an output Table.  ``Map``/``Filter``
require Python type annotations on their functions (paper §3.1
"Typechecking and Constraints"); annotations are verified against upstream
schemas at deploy time and against actual values at run time.

Operator hints (``resource_class``, ``batching``, ``high_variance``,
``competitive_replicas``) drive the paper's optimizations (§4).
"""
from __future__ import annotations

import dataclasses
import typing
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.table import Row, Table, Schema, schema_compatible

AGG_FNS = ("count", "sum", "min", "max", "avg")


class TypecheckError(TypeError):
    pass


def _type_ok(value, t) -> bool:
    if t in (Any, None, type(None)):
        return True
    origin = typing.get_origin(t)
    if origin is not None:  # typing generics: check origin only
        return isinstance(value, origin)
    if isinstance(t, type):
        if t is float:
            return isinstance(value, (int, float))
        return isinstance(value, t)
    return True


def fn_signature(fn) -> Tuple[List[Optional[type]], Any]:
    """(per-arg types — None when unannotated, return annotation)."""
    hints = typing.get_type_hints(fn)
    names = fn.__code__.co_varnames[:fn.__code__.co_argcount]
    args = [hints.get(p) for p in names]
    ret = hints.get("return")
    return args, ret


def _ret_schema(ret, names: Optional[Sequence[str]]) -> Schema:
    if ret is None:
        raise TypecheckError("map function needs a return annotation")
    if typing.get_origin(ret) is tuple:
        types = list(typing.get_args(ret))
    else:
        types = [ret]
    names = list(names) if names else [f"out{i}" for i in range(len(types))]
    if len(names) != len(types):
        raise TypecheckError(f"{len(names)} names for {len(types)} outputs")
    return list(zip(names, types))


@dataclasses.dataclass
class Operator:
    """Base: single-input operator."""
    # optimization hints (paper §4)
    resource_class: str = dataclasses.field(default="cpu", init=False)
    batching: bool = dataclasses.field(default=False, init=False)
    high_variance: bool = dataclasses.field(default=False, init=False)
    competitive_replicas: int = dataclasses.field(default=0, init=False)

    @property
    def name(self) -> str:
        return type(self).__name__.lower()

    def out_schema(self, in_schemas: List[Schema]) -> Schema:
        raise NotImplementedError

    def out_grouping(self, in_groupings: List[Optional[str]]):
        return in_groupings[0]

    def apply(self, tables: List[Table], ctx=None) -> Table:
        raise NotImplementedError

    def typecheck(self, in_schemas: List[Schema]) -> Schema:
        return self.out_schema(in_schemas)


def _check_values(values, schema: Schema, where: str):
    if len(values) != len(schema):
        raise TypecheckError(
            f"{where}: returned {len(values)} values for schema {schema}")
    for v, (n, t) in zip(values, schema):
        if not _type_ok(v, t):
            raise TypecheckError(
                f"{where}: column {n!r} expected {t}, got "
                f"{type(v).__name__} ({v!r})")


@dataclasses.dataclass
class Map(Operator):
    fn: Callable
    names: Optional[Sequence[str]] = None

    def __post_init__(self):
        self._arg_types, self._ret = fn_signature(self.fn)
        self._schema = _ret_schema(self._ret, self.names)

    def out_schema(self, in_schemas):
        (in_schema,) = in_schemas
        if self._arg_types and len(self._arg_types) != len(in_schema):
            raise TypecheckError(
                f"map {self.fn.__name__}: takes {len(self._arg_types)} args, "
                f"upstream schema has {len(in_schema)} columns")
        for (n, t), at in zip(in_schema, self._arg_types):
            if at is None:
                continue  # unannotated arg (e.g. injected lookup column)
            if at is not Any and isinstance(at, type) and isinstance(t, type):
                if not (issubclass(t, at) or (at is float and t is int)):
                    raise TypecheckError(
                        f"map {self.fn.__name__}: arg for column {n!r} "
                        f"annotated {at}, upstream type {t}")
        return self._schema

    def apply(self, tables, ctx=None):
        (t,) = tables
        rows = []
        for r in t.rows:
            out = self.fn(*r.values)
            if not isinstance(out, tuple):
                out = (out,)
            _check_values(out, self._schema, f"map {self.fn.__name__}")
            rows.append(r.replace(out))
        out_t = Table(self._schema, grouping=t.grouping)
        out_t.rows = rows
        return out_t


@dataclasses.dataclass
class ModelOp(Map):
    """A registry model stage (``prefill``/``decode_step``/``logits``) as a
    first-class plan operator (white-box models, per PRETZEL).

    Structurally a ``Map`` — ``fn`` is the stage function with declared
    ``jax.Array`` annotations, so the op typechecks, fuses, and lowers into
    ``JittedFuse``/``BatchedJittedFuse`` chains like any other map — plus:

    * identity: ``model_name``/``stage`` name the registry model and stage,
      so plans and explain output say *which* model runs where;
    * cost hook: ``cost_hook(batch_size) -> {"mean_s", "p99_s", "cv",
      "runs", "out_bytes"}`` measures (or estimates) the stage at a batch
      size — ``profiling.profiler.seed_from_model_ops`` turns these into
      ``OpLatencyCurve`` buckets so the SLO optimizer plans against real
      model profiles instead of synthetic curves.

    Built by ``repro.models.registry.model_stage_op``; attach to a flow
    with ``Node.apply_op``."""
    model_name: str = ""
    stage: str = "logits"
    cost_hook: Optional[Callable] = None

    @property
    def name(self):
        return f"model[{self.model_name}:{self.stage}]"


@dataclasses.dataclass
class Filter(Operator):
    fn: Callable

    def __post_init__(self):
        self._arg_types, self._ret = fn_signature(self.fn)
        if self._ret not in (bool, None):
            raise TypecheckError("filter function must return bool")

    def out_schema(self, in_schemas):
        return in_schemas[0]

    def apply(self, tables, ctx=None):
        (t,) = tables
        rows = []
        for r in t.rows:
            keep = self.fn(*r.values)
            # accept scalar boolean *arrays* too (numpy / jax 0-d bools):
            # an array-typed predicate like ``x.sum() > 0`` returns one,
            # and the jit-lowered masked path evaluates the same fn — the
            # interpreted oracle must agree with it
            if not isinstance(keep, bool):
                dtype = getattr(keep, "dtype", None)
                if dtype is not None and dtype == np.bool_ and \
                        getattr(keep, "ndim", None) == 0:
                    keep = bool(keep)
                else:
                    raise TypecheckError(
                        f"filter {self.fn.__name__} returned non-bool "
                        f"{type(keep).__name__}")
            if keep:
                rows.append(r)
        return t.with_rows(rows)


@dataclasses.dataclass
class GroupBy(Operator):
    column: str

    def out_schema(self, in_schemas):
        (s,) = in_schemas
        if self.column not in [n for n, _ in s]:
            raise TypecheckError(f"groupby: no column {self.column!r} in {s}")
        return s

    def out_grouping(self, in_groupings):
        if in_groupings[0] is not None:
            raise TypecheckError("groupby over an already-grouped table")
        return self.column

    def apply(self, tables, ctx=None):
        (t,) = tables
        i = t.column_index(self.column)
        rows = [r.replace(r.values, group=r.values[i]) for r in t.rows]
        out = t.with_rows(rows, grouping=self.column)
        return out


@dataclasses.dataclass
class Agg(Operator):
    agg_fn: str
    column: str

    def __post_init__(self):
        if self.agg_fn not in AGG_FNS:
            raise TypecheckError(f"agg fn {self.agg_fn!r} not in {AGG_FNS}")

    def out_schema(self, in_schemas):
        (s,) = in_schemas
        names = [n for n, _ in s]
        if self.column not in names:
            raise TypecheckError(f"agg: no column {self.column!r}")
        t = dict(s)[self.column]
        out_t = int if self.agg_fn == "count" else (
            float if self.agg_fn == "avg" else t)
        return [("group", Any), (self.agg_fn, out_t)]

    def out_grouping(self, in_groupings):
        return None  # agg always un-groups

    def apply(self, tables, ctx=None):
        (t,) = tables
        i = t.column_index(self.column)
        groups: Dict[Any, List[Any]] = {}
        for r in t.rows:
            groups.setdefault(r.group if t.grouping else None, []).append(
                r.values[i])
        out = Table(self.out_schema([t.schema]))
        for g, vals in groups.items():
            if self.agg_fn == "count":
                v = len(vals)
            elif self.agg_fn == "sum":
                v = sum(vals)
            elif self.agg_fn == "min":
                v = min(vals)
            elif self.agg_fn == "max":
                v = max(vals)
            else:
                v = sum(vals) / len(vals)
            out.insert((g, v))
        return out


@dataclasses.dataclass
class Lookup(Operator):
    """Retrieve object(s) from the KVS; ref is a constant key or a column."""
    key: str
    is_column: bool = False
    out_name: str = "lookup"

    def out_schema(self, in_schemas):
        (s,) = in_schemas
        if self.is_column and self.key not in [n for n, _ in s]:
            raise TypecheckError(f"lookup: no column {self.key!r}")
        return list(s) + [(self.out_name, Any)]

    def apply(self, tables, ctx=None):
        (t,) = tables
        if ctx is None or ctx.kvs is None:
            raise RuntimeError("lookup needs a KVS in the execution context")
        rows = []
        ki = t.column_index(self.key) if self.is_column else None
        for r in t.rows:
            key = r.values[ki] if self.is_column else self.key
            val = ctx.kvs_get(key)
            rows.append(r.replace(r.values + (val,)))
        out = Table(self.out_schema([t.schema]), grouping=t.grouping)
        out.rows = rows
        return out


@dataclasses.dataclass
class Join(Operator):
    key: Optional[str] = None      # None -> row ID
    how: str = "inner"             # inner | left | outer

    def __post_init__(self):
        if self.how not in ("inner", "left", "outer"):
            raise TypecheckError(f"join how={self.how!r}")

    def out_schema(self, in_schemas):
        left, right = in_schemas
        return list(left) + list(right)

    def out_grouping(self, in_groupings):
        if any(g is not None for g in in_groupings):
            raise TypecheckError("join inputs must be ungrouped")
        return None

    def apply(self, tables, ctx=None):
        left, right = tables
        lk = (lambda r: r.row_id) if self.key is None else (
            lambda r, i=left.column_index(self.key): r.values[i])
        rk = (lambda r: r.row_id) if self.key is None else (
            lambda r, i=right.column_index(self.key): r.values[i])
        rmap: Dict[Any, List[Row]] = {}
        for r in right.rows:
            rmap.setdefault(rk(r), []).append(r)
        out = Table(self.out_schema([left.schema, right.schema]))
        matched_right = set()
        nones_r = (None,) * len(right.schema)
        for l in left.rows:
            ms = rmap.get(lk(l), [])
            if ms:
                for m in ms:
                    matched_right.add(id(m))
                    out.rows.append(Row(l.values + m.values, l.row_id))
            elif self.how in ("left", "outer"):
                out.rows.append(Row(l.values + nones_r, l.row_id))
        if self.how == "outer":
            nones_l = (None,) * len(left.schema)
            for r in right.rows:
                if id(r) not in matched_right:
                    out.rows.append(Row(nones_l + r.values, r.row_id))
        return out


@dataclasses.dataclass
class Union(Operator):
    def out_schema(self, in_schemas):
        first = in_schemas[0]
        for s in in_schemas[1:]:
            if not schema_compatible(first, s):
                raise TypecheckError(f"union schema mismatch: {first} vs {s}")
        return first

    def apply(self, tables, ctx=None):
        out = tables[0].with_rows(
            [r for t in tables for r in t.rows])
        return out


@dataclasses.dataclass
class AnyOf(Operator):
    """Pass exactly one input through; the runtime picks (wait-for-any)."""
    def out_schema(self, in_schemas):
        return Union().out_schema(in_schemas)

    def apply(self, tables, ctx=None):
        for t in tables:
            if t is not None:
                return t
        raise RuntimeError("anyof: no input available")


@dataclasses.dataclass
class Fuse(Operator):
    """An encapsulated chain of operators executed at one location (§4)."""
    ops: List[Operator] = dataclasses.field(default_factory=list)

    @property
    def name(self):
        return "fuse[" + ",".join(o.name for o in self.ops) + "]"

    def out_schema(self, in_schemas):
        s = in_schemas[0]
        for op in self.ops:
            s = op.out_schema([s])
        return s

    def out_grouping(self, in_groupings):
        g = in_groupings[0]
        for op in self.ops:
            g = op.out_grouping([g])
        return g

    def apply(self, tables, ctx=None):
        (t,) = tables
        for op in self.ops:
            t = op.apply([t], ctx)
        return t
