from repro.core.dataflow import Dataflow  # noqa: F401
from repro.core.table import Table, Row  # noqa: F401
