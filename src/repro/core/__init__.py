from repro.core.dataflow import Dataflow  # noqa: F401
from repro.core.ir import PhysicalOp, PhysicalPlan  # noqa: F401
from repro.core.passes import PassPipeline, build_pipeline  # noqa: F401
from repro.core.table import Table, Row  # noqa: F401
