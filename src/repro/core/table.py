"""Cloudflow's core data structures: a small in-memory relational Table,
plus its device-resident columnar twin (``DeviceTable``).

A Table has a *schema* (list of (name, type) column descriptors), an optional
*grouping column*, and rows.  Every row carries a hidden ``row_id`` assigned
at dataflow execution time which persists through the pipeline (paper §3.1)
and is the default join key.

A ``DeviceTable`` holds the same logical rows as columns — one accelerator
array per schema column, rows stacked along axis 0 — so a chain of lowered
GPU operators can hand whole batches from stage to stage without a host
round-trip: ONE host->device stack when the batch enters the device chain,
ONE device->host gather when it leaves.  Row identity (``row_ids``,
``groups``) stays on the host; row *liveness* is a boolean ``mask`` column
carried on the device, which is how fused Filter operators drop rows
without forcing a compaction (masked rows are compacted only at the
device->host boundary in ``host_rows``/``to_table``).
"""
from __future__ import annotations

import itertools
import threading
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

try:  # keep the core importable without jax (DeviceTable then unusable)
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jax = None
    jnp = None

Schema = List[Tuple[str, type]]

_counter = itertools.count()


class Row:
    __slots__ = ("values", "row_id", "group")

    def __init__(self, values: Tuple[Any, ...], row_id: Optional[int] = None,
                 group: Any = None):
        self.values = tuple(values)
        self.row_id = row_id if row_id is not None else next(_counter)
        self.group = group

    def replace(self, values: Tuple[Any, ...], group=...) -> "Row":
        return Row(values, self.row_id,
                   self.group if group is ... else group)

    def __repr__(self):
        return f"Row(id={self.row_id}, {self.values!r})"


class Table:
    def __init__(self, schema: Schema, rows: Optional[Iterable] = None,
                 grouping: Optional[str] = None):
        self.schema: Schema = [(str(n), t) for n, t in schema]
        self.grouping = grouping
        self.rows: List[Row] = []
        if rows:
            for r in rows:
                self.insert(r)

    # -- construction -------------------------------------------------------
    def insert(self, values, group: Any = None) -> Row:
        if isinstance(values, Row):
            self.rows.append(values)
            return values
        if not isinstance(values, (tuple, list)):
            values = (values,)
        if len(values) != len(self.schema):
            raise ValueError(
                f"row arity {len(values)} != schema arity {len(self.schema)}")
        row = Row(tuple(values), group=group)
        self.rows.append(row)
        return row

    @property
    def columns(self) -> List[str]:
        return [n for n, _ in self.schema]

    def column_index(self, name: str) -> int:
        for i, (n, _) in enumerate(self.schema):
            if n == name:
                return i
        raise KeyError(f"no column {name!r} in {self.columns}")

    def column(self, name: str) -> List[Any]:
        i = self.column_index(name)
        return [r.values[i] for r in self.rows]

    def with_rows(self, rows: List[Row], grouping=...) -> "Table":
        t = Table(self.schema, grouping=self.grouping
                  if grouping is ... else grouping)
        t.rows = list(rows)
        return t

    # -- python sugar ---------------------------------------------------------
    def __len__(self):
        return len(self.rows)

    def __iter__(self):
        return iter(self.rows)

    def __repr__(self):
        g = f", grouped by {self.grouping!r}" if self.grouping else ""
        return (f"Table({self.columns}{g}, {len(self.rows)} rows)\n" +
                "\n".join(f"  {r}" for r in self.rows[:10]))

    def to_dicts(self) -> List[Dict[str, Any]]:
        return [dict(zip(self.columns, r.values)) for r in self.rows]

    @staticmethod
    def from_dicts(schema: Schema, dicts: Sequence[Dict[str, Any]]) -> "Table":
        t = Table(schema)
        for d in dicts:
            t.insert(tuple(d[n] for n, _ in schema))
        return t


def schema_compatible(a: Schema, b: Schema) -> bool:
    return len(a) == len(b) and all(ta == tb for (_, ta), (_, tb)
                                    in zip(a, b))


# ---------------------------------------------------------------------------
# device-resident columnar batches
# ---------------------------------------------------------------------------

#: process-wide host<->device copy accounting (read by benchmarks/tests):
#: a "stack" is one host->device columnar upload event, a "gather" one
#: device->host readback event.  Index uploads and mask bookkeeping (a few
#: bytes) are deliberately not counted — the counters track the bulk row
#: payload crossing the PCIe boundary.
HOST_COPIES: Dict[str, int] = {"stacks": 0, "gathers": 0}

# per-thread copy capture: an executor thread brackets one item's
# execution with start/end and gets THAT item's copy counts, without
# the races a global-counter delta would have across worker threads
_copy_capture = threading.local()


def note_host_copy(kind: str) -> None:
    """Count one host<->device bulk copy ('stacks' or 'gathers') against
    the global counters and, when the current thread has a capture open,
    against that capture."""
    HOST_COPIES[kind] += 1
    cap = getattr(_copy_capture, "counts", None)
    if cap is not None:
        cap[kind] = cap.get(kind, 0) + 1


def copy_capture_start() -> None:
    """Begin attributing this thread's host copies (until
    :func:`copy_capture_end`) to the current work item."""
    _copy_capture.counts = {}


def copy_capture_end() -> Optional[Dict[str, int]]:
    """Close this thread's capture; returns the counts since start (None
    when no capture was open, {} when no copies happened)."""
    cap = getattr(_copy_capture, "counts", None)
    _copy_capture.counts = None
    return cap


def reset_host_copies() -> None:
    HOST_COPIES["stacks"] = 0
    HOST_COPIES["gathers"] = 0


class DeviceTable:
    """A shape-uniform batch of rows living on the accelerator.

    ``columns[j]`` stacks column j of every row along axis 0, padded up to a
    bucketed capacity (``cap``); only the first ``nrows`` entries are
    logical rows, and of those only the ones whose ``mask`` entry is True
    (``mask is None`` means all live).  ``row_ids``/``groups`` keep per-row
    identity on the host so demultiplexing never needs device data.

    ``donatable=True`` marks a table whose buffers have no other live
    consumer — the executing chain may donate them to XLA
    (``donate_argnums``) so the output batch reuses the input allocation.
    Donated buffers are DELETED after the call; only ever set it on arrays
    this table exclusively owns.
    """

    __slots__ = ("schema", "grouping", "columns", "mask", "nrows",
                 "row_ids", "groups", "donatable")

    def __init__(self, schema: Schema, columns: Sequence[Any], nrows: int,
                 row_ids: Sequence[int], groups: Sequence[Any],
                 grouping: Optional[str] = None, mask: Any = None,
                 donatable: bool = False):
        self.schema: Schema = [(str(n), t) for n, t in schema]
        self.columns = list(columns)
        self.nrows = int(nrows)
        self.row_ids = list(row_ids)
        self.groups = list(groups)
        self.grouping = grouping
        self.mask = mask
        self.donatable = donatable

    # -- construction -------------------------------------------------------
    @staticmethod
    def from_columns(schema: Schema, host_cols: Sequence[Sequence[Any]],
                     row_ids: Sequence[int], groups: Sequence[Any],
                     pad_to: Optional[int] = None,
                     grouping: Optional[str] = None) -> "DeviceTable":
        """Build from per-column lists of per-row host (numpy) arrays: one
        ``np.stack`` memcpy + ONE device upload per column.  The row count
        is padded up to ``pad_to`` by repeating row 0 so device shapes stay
        bucket-sized; padding rows carry no mask entry — ``nrows`` bounds
        the live range."""
        if jnp is None:  # pragma: no cover
            raise RuntimeError("DeviceTable requires jax")
        n = len(row_ids)
        cap = max(pad_to or n, n)
        columns = []
        for col in host_cols:
            col = list(col)
            stacked = np.stack(col + col[:1] * (cap - n)) if col else \
                np.zeros((0,))
            columns.append(jnp.asarray(stacked))
        note_host_copy("stacks")
        return DeviceTable(schema, columns, n, row_ids, groups,
                           grouping=grouping, mask=None, donatable=True)

    @staticmethod
    def from_table(t: Table, pad_to: Optional[int] = None) -> "DeviceTable":
        """Stack a (shape-uniform) host table.  Raises ``ValueError`` when
        rows are ragged or values are not array-convertible — callers fall
        back to per-row execution."""
        arrs = [[np.asarray(v) for v in r.values] for r in t.rows]
        if arrs:
            key0 = [(a.shape, a.dtype) for a in arrs[0]]
            for row_arrs in arrs[1:]:
                if [(a.shape, a.dtype) for a in row_arrs] != key0:
                    raise ValueError("ragged rows cannot form a DeviceTable")
        host_cols = [[row_arrs[j] for row_arrs in arrs]
                     for j in range(len(t.schema))]
        return DeviceTable.from_columns(
            t.schema, host_cols, [r.row_id for r in t.rows],
            [r.group for r in t.rows], pad_to=pad_to, grouping=t.grouping)

    # -- accessors ----------------------------------------------------------
    def __len__(self) -> int:
        return self.nrows

    @property
    def cap(self) -> int:
        return int(self.columns[0].shape[0]) if self.columns else self.nrows

    @property
    def nbytes(self) -> int:
        return int(sum(getattr(c, "nbytes", 0) for c in self.columns))

    @property
    def column_names(self) -> List[str]:
        return [n for n, _ in self.schema]

    def column_index(self, name: str) -> int:
        for i, (n, _) in enumerate(self.schema):
            if n == name:
                return i
        raise KeyError(f"no column {name!r} in {self.column_names}")

    def __repr__(self):
        shapes = [tuple(getattr(c, "shape", ())) for c in self.columns]
        return (f"DeviceTable({self.column_names}, rows={self.nrows}"
                f"/cap={self.cap}, shapes={shapes}"
                f"{', masked' if self.mask is not None else ''})")

    # -- device-side row selection (no host copy) ----------------------------
    def take(self, positions: Sequence[int],
             pad_to: Optional[int] = None) -> "DeviceTable":
        """A new DeviceTable holding ``positions`` (indices < nrows), padded
        to ``pad_to``.  The gather runs on the device — no host round-trip
        beyond the tiny index/validity upload — so batcher demultiplexing
        can split a merged batch per request while staying device-resident."""
        pos = [int(p) for p in positions]
        k = len(pos)
        cap = max(pad_to or k, k)
        idx_host = np.asarray(pos + pos[:1] * (cap - k), np.int32)
        idx = jnp.asarray(idx_host)
        cols = [jnp.take(c, idx, axis=0) for c in self.columns]
        mask = None
        if self.mask is not None:
            mask = jnp.take(self.mask, idx, axis=0)
        if cap > k:
            valid = jnp.asarray(np.arange(cap) < k)
            mask = valid if mask is None else jnp.logical_and(mask, valid)
        return DeviceTable(self.schema, cols, k,
                           [self.row_ids[p] for p in pos],
                           [self.groups[p] for p in pos],
                           grouping=self.grouping, mask=mask, donatable=True)

    # -- device->host boundary ----------------------------------------------
    def host_rows(self) -> List[Tuple[int, Row]]:
        """Materialize live rows as ``(position, Row)`` pairs with ONE
        device->host readback; masked-out (filtered) and padding rows are
        compacted away here — and only here."""
        payload = tuple(self.columns)
        if self.mask is not None:
            payload = payload + (self.mask,)
        host = jax.device_get(payload)
        note_host_copy("gathers")
        ncol = len(self.columns)
        mask_h = host[ncol] if self.mask is not None else None
        out: List[Tuple[int, Row]] = []
        for i in range(self.nrows):
            if mask_h is not None and not bool(mask_h[i]):
                continue
            out.append((i, Row(tuple(c[i] for c in host[:ncol]),
                               self.row_ids[i], self.groups[i])))
        return out

    def to_table(self) -> Table:
        t = Table(self.schema, grouping=self.grouping)
        t.rows = [r for _, r in self.host_rows()]
        return t


#: the paper-facing name: a schema-tagged columnar batch (device-resident).
ColumnBatch = DeviceTable
