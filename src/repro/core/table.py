"""Cloudflow's core data structure: a small in-memory relational Table.

A Table has a *schema* (list of (name, type) column descriptors), an optional
*grouping column*, and rows.  Every row carries a hidden ``row_id`` assigned
at dataflow execution time which persists through the pipeline (paper §3.1)
and is the default join key.
"""
from __future__ import annotations

import itertools
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

Schema = List[Tuple[str, type]]

_counter = itertools.count()


class Row:
    __slots__ = ("values", "row_id", "group")

    def __init__(self, values: Tuple[Any, ...], row_id: Optional[int] = None,
                 group: Any = None):
        self.values = tuple(values)
        self.row_id = row_id if row_id is not None else next(_counter)
        self.group = group

    def replace(self, values: Tuple[Any, ...], group=...) -> "Row":
        return Row(values, self.row_id,
                   self.group if group is ... else group)

    def __repr__(self):
        return f"Row(id={self.row_id}, {self.values!r})"


class Table:
    def __init__(self, schema: Schema, rows: Optional[Iterable] = None,
                 grouping: Optional[str] = None):
        self.schema: Schema = [(str(n), t) for n, t in schema]
        self.grouping = grouping
        self.rows: List[Row] = []
        if rows:
            for r in rows:
                self.insert(r)

    # -- construction -------------------------------------------------------
    def insert(self, values, group: Any = None) -> Row:
        if isinstance(values, Row):
            self.rows.append(values)
            return values
        if not isinstance(values, (tuple, list)):
            values = (values,)
        if len(values) != len(self.schema):
            raise ValueError(
                f"row arity {len(values)} != schema arity {len(self.schema)}")
        row = Row(tuple(values), group=group)
        self.rows.append(row)
        return row

    @property
    def columns(self) -> List[str]:
        return [n for n, _ in self.schema]

    def column_index(self, name: str) -> int:
        for i, (n, _) in enumerate(self.schema):
            if n == name:
                return i
        raise KeyError(f"no column {name!r} in {self.columns}")

    def column(self, name: str) -> List[Any]:
        i = self.column_index(name)
        return [r.values[i] for r in self.rows]

    def with_rows(self, rows: List[Row], grouping=...) -> "Table":
        t = Table(self.schema, grouping=self.grouping
                  if grouping is ... else grouping)
        t.rows = list(rows)
        return t

    # -- python sugar ---------------------------------------------------------
    def __len__(self):
        return len(self.rows)

    def __iter__(self):
        return iter(self.rows)

    def __repr__(self):
        g = f", grouped by {self.grouping!r}" if self.grouping else ""
        return (f"Table({self.columns}{g}, {len(self.rows)} rows)\n" +
                "\n".join(f"  {r}" for r in self.rows[:10]))

    def to_dicts(self) -> List[Dict[str, Any]]:
        return [dict(zip(self.columns, r.values)) for r in self.rows]

    @staticmethod
    def from_dicts(schema: Schema, dicts: Sequence[Dict[str, Any]]) -> "Table":
        t = Table(schema)
        for d in dicts:
            t.insert(tuple(d[n] for n, _ in schema))
        return t


def schema_compatible(a: Schema, b: Schema) -> bool:
    return len(a) == len(b) and all(ta == tb for (_, ta), (_, tb)
                                    in zip(a, b))
