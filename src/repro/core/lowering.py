"""XLA-level lowering of fused JAX map chains (tentpole of the compilation
pipeline).

Graph-level fusion (``FuseChainsPass``) collapses a linear chain into one
``Fuse`` node, but that node still *interprets* its sub-operators one Python
call at a time — per-row, per-op dispatch plus runtime typechecks.  When the
chain is entirely JAX-array ``Map`` operators placed on a GPU-class
executor, we can do better: compose the per-op functions into one program
and hand the whole thing to ``jax.jit``, so XLA fuses the arithmetic across
operator boundaries and the runtime pays a single dispatch per row.

``JittedFuse`` keeps the exact ``Fuse`` interface (schema/grouping
propagation, ``ops`` list) so every graph-level invariant still holds; only
``apply`` changes.  ``jax.jit`` compiles lazily on first call and re-uses
the executable across rows and requests (shapes are stable in a serving
pipeline, which is what makes this profitable).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

from repro.core import operators as ops
from repro.core.table import Table

try:  # the container bakes jax in, but keep the core importable without it
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jax = None
    jnp = None

#: annotation types treated as "JAX array" for lowering.  Deliberately NOT
#: np.ndarray: the jitted chain emits jax.Array values, so only fns that
#: already declare jax.Array keep their downstream value types unchanged.
_ARRAY_TYPES: Tuple[type, ...] = ()
if jax is not None:
    _ARRAY_TYPES = (jax.Array,)


def _array_annotation(t) -> bool:
    return any(t is a for a in _ARRAY_TYPES)


def map_is_jax_lowerable(m: ops.Operator) -> bool:
    """A ``Map`` whose argument and return annotations are all arrays.
    ``m._schema`` already holds the expanded return types (tuple returns
    included) from ``operators._ret_schema``."""
    if not isinstance(m, ops.Map) or jax is None:
        return False
    arg_types = m._arg_types
    if not arg_types or any(a is None or not _array_annotation(a)
                            for a in arg_types):
        return False
    return all(_array_annotation(t) for _, t in m._schema)


def fuse_is_jax_lowerable(fuse: ops.Operator, placement: str,
                          min_ops: int = 2) -> bool:
    """Eligibility: a ``Fuse`` of >= ``min_ops`` JAX-array maps placed on a
    GPU-class node (accelerator-attached executor)."""
    return (isinstance(fuse, ops.Fuse)
            and not isinstance(fuse, JittedFuse)
            and placement == "gpu"
            and len(fuse.ops) >= min_ops
            and all(map_is_jax_lowerable(m) for m in fuse.ops))


@dataclasses.dataclass
class JittedFuse(ops.Fuse):
    """A fused chain of JAX map operators compiled to ONE jitted callable.

    The composed function applies every constituent ``fn`` in sequence
    inside a single trace, so XLA fuses across operator boundaries and each
    row costs one dispatch instead of ``len(ops)`` interpreted calls.
    """

    def __post_init__(self):
        if jax is None:  # pragma: no cover
            raise RuntimeError("JittedFuse requires jax")
        fns = [m.fn for m in self.ops]

        def composed(*vals):
            for fn in fns:
                out = fn(*vals)
                vals = out if isinstance(out, tuple) else (out,)
            return vals

        self._jitted = jax.jit(composed)
        self._out_arity = len(self.ops[-1]._schema)
        self._fallback = False
        self._jit_succeeded = False

    @property
    def name(self):
        return "jit[" + ",".join(o.name for o in self.ops) + "]"

    @property
    def jitted_fn(self):
        """The single compiled callable (one per fused chain)."""
        return self._jitted

    def apply(self, tables: List[Table], ctx=None) -> Table:
        if self._fallback:
            return ops.Fuse.apply(self, tables, ctx)
        (t,) = tables
        schema = self.out_schema([t.schema])
        rows = []
        try:
            for r in t.rows:
                out = self._jitted(*(jnp.asarray(v) for v in r.values))
                if len(out) != self._out_arity:
                    raise ops.TypecheckError(
                        f"{self.name}: returned {len(out)} values, schema "
                        f"expects {self._out_arity}")
                rows.append(r.replace(tuple(out)))
        except ops.TypecheckError:
            raise
        except (jax.errors.JAXTypeError, TypeError, NotImplementedError):
            # annotations said "array" but the fn is not jax-traceable
            # (data-dependent control flow, numpy side effects, ...).
            # Tracing happens on the first call, so only latch the
            # permanent fallback before any jitted call has succeeded;
            # a per-request data error on a proven-traceable chain (and
            # transient runtime errors like XLA OOM) propagates instead
            # of silently disabling the jitted path for the deployment.
            if self._jit_succeeded:
                raise
            self._fallback = True
            return ops.Fuse.apply(self, tables, ctx)
        self._jit_succeeded = True
        out_t = Table(schema, grouping=t.grouping)
        out_t.rows = rows
        return out_t


def lower_fuse(fuse: ops.Fuse) -> JittedFuse:
    """Lower an interpreted ``Fuse`` into a ``JittedFuse`` (annotations are
    the caller's job — this only swaps the execution strategy)."""
    lowered = JittedFuse(list(fuse.ops))
    lowered.resource_class = fuse.resource_class
    lowered.batching = fuse.batching
    lowered.high_variance = fuse.high_variance
    lowered.competitive_replicas = fuse.competitive_replicas
    return lowered
