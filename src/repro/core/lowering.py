"""XLA-level lowering of fused JAX map chains (tentpole of the compilation
pipeline).

Graph-level fusion (``FuseChainsPass``) collapses a linear chain into one
``Fuse`` node, but that node still *interprets* its sub-operators one Python
call at a time — per-row, per-op dispatch plus runtime typechecks.  When the
chain is entirely JAX-array ``Map`` operators placed on a GPU-class
executor, we can do better: compose the per-op functions into one program
and hand the whole thing to ``jax.jit``, so XLA fuses the arithmetic across
operator boundaries and the runtime pays a single dispatch per row.

``JittedFuse`` keeps the exact ``Fuse`` interface (schema/grouping
propagation, ``ops`` list) so every graph-level invariant still holds; only
``apply`` changes.  ``jax.jit`` compiles lazily on first call and re-uses
the executable across rows and requests (shapes are stable in a serving
pipeline, which is what makes this profitable).

``BatchedJittedFuse`` goes one step further (paper §4 Batching, Fig 8): it
stacks all rows of a table into device arrays and executes the whole chain
as a single ``jax.vmap``-over-rows ``jax.jit`` dispatch per batch.  Row
counts are padded up to power-of-two buckets so XLA recompiles are bounded
(O(log max_batch) shapes per chain instead of one per batch size), and
compiled executables live in a process-wide cache keyed on
``(chain signature, bucket shapes, dtypes)`` so identical chains across
re-registrations and plans reuse XLA programs instead of re-tracing.
Ragged batches (rows whose arrays differ in shape) are split into
shape-uniform groups — one dispatch per group — and anything that cannot
be stacked or traced falls back to the per-row jitted / interpreted path.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import operators as ops
from repro.core.table import Table

try:  # the container bakes jax in, but keep the core importable without it
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jax = None
    jnp = None

#: annotation types treated as "JAX array" for lowering.  Deliberately NOT
#: np.ndarray: the jitted chain emits jax.Array values, so only fns that
#: already declare jax.Array keep their downstream value types unchanged.
_ARRAY_TYPES: Tuple[type, ...] = ()
if jax is not None:
    _ARRAY_TYPES = (jax.Array,)


def _array_annotation(t) -> bool:
    return any(t is a for a in _ARRAY_TYPES)


def map_is_jax_lowerable(m: ops.Operator) -> bool:
    """A ``Map`` whose argument and return annotations are all arrays.
    ``m._schema`` already holds the expanded return types (tuple returns
    included) from ``operators._ret_schema``."""
    if not isinstance(m, ops.Map) or jax is None:
        return False
    arg_types = m._arg_types
    if not arg_types or any(a is None or not _array_annotation(a)
                            for a in arg_types):
        return False
    return all(_array_annotation(t) for _, t in m._schema)


def fuse_is_jax_lowerable(fuse: ops.Operator, placement: str,
                          min_ops: int = 2) -> bool:
    """Eligibility: a ``Fuse`` of >= ``min_ops`` JAX-array maps placed on a
    GPU-class node (accelerator-attached executor)."""
    return (isinstance(fuse, ops.Fuse)
            and not isinstance(fuse, JittedFuse)
            and placement == "gpu"
            and len(fuse.ops) >= min_ops
            and all(map_is_jax_lowerable(m) for m in fuse.ops))


@dataclasses.dataclass
class JittedFuse(ops.Fuse):
    """A fused chain of JAX map operators compiled to ONE jitted callable.

    The composed function applies every constituent ``fn`` in sequence
    inside a single trace, so XLA fuses across operator boundaries and each
    row costs one dispatch instead of ``len(ops)`` interpreted calls.
    """

    def __post_init__(self):
        if jax is None:  # pragma: no cover
            raise RuntimeError("JittedFuse requires jax")
        fns = [m.fn for m in self.ops]

        def composed(*vals):
            for fn in fns:
                out = fn(*vals)
                vals = out if isinstance(out, tuple) else (out,)
            return vals

        self._jitted = jax.jit(composed)
        self._out_arity = len(self.ops[-1]._schema)
        self._fallback = False
        self._jit_succeeded = False
        self.row_dispatches = 0     # jitted per-row XLA dispatches issued

    @property
    def name(self):
        return "jit[" + ",".join(o.name for o in self.ops) + "]"

    @property
    def jitted_fn(self):
        """The single compiled callable (one per fused chain)."""
        return self._jitted

    def apply(self, tables: List[Table], ctx=None) -> Table:
        if self._fallback:
            return ops.Fuse.apply(self, tables, ctx)
        (t,) = tables
        schema = self.out_schema([t.schema])
        rows = []
        try:
            for r in t.rows:
                out = self._jitted(*(jnp.asarray(v) for v in r.values))
                self.row_dispatches += 1
                if len(out) != self._out_arity:
                    raise ops.TypecheckError(
                        f"{self.name}: returned {len(out)} values, schema "
                        f"expects {self._out_arity}")
                rows.append(r.replace(tuple(out)))
        except ops.TypecheckError:
            raise
        except (jax.errors.JAXTypeError, TypeError, NotImplementedError):
            # annotations said "array" but the fn is not jax-traceable
            # (data-dependent control flow, numpy side effects, ...).
            # Tracing happens on the first call, so only latch the
            # permanent fallback before any jitted call has succeeded;
            # a per-request data error on a proven-traceable chain (and
            # transient runtime errors like XLA OOM) propagates instead
            # of silently disabling the jitted path for the deployment.
            if self._jit_succeeded:
                raise
            self._fallback = True
            return ops.Fuse.apply(self, tables, ctx)
        self._jit_succeeded = True
        out_t = Table(schema, grouping=t.grouping)
        out_t.rows = rows
        return out_t


# ---------------------------------------------------------------------------
# batched (vmap-over-rows) execution: shape buckets + executable cache
# ---------------------------------------------------------------------------

#: default row-count buckets: powers of two.  A batch of n rows is padded up
#: to the smallest bucket >= n, bounding recompiles to O(log max_batch)
#: distinct shapes per chain.
DEFAULT_BUCKETS: Tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64)


def bucket_rows(n: int, buckets: Tuple[int, ...] = DEFAULT_BUCKETS) -> int:
    """Smallest bucket >= n; beyond the table, next power of two."""
    for b in buckets:
        if n <= b:
            return b
    b = buckets[-1] if buckets else 1
    while b < n:
        b *= 2
    return b


def chain_signature(chain_ops: List[ops.Operator]) -> Tuple[Any, ...]:
    """Identity of a fused chain: the tuple of its map functions.  Two
    ``Fuse`` nodes built from the same function objects (the common case
    across re-registrations of the same flow) share compiled executables;
    redefining a function yields a new object and, correctly, a new entry."""
    return tuple(m.fn for m in chain_ops)


class ExecutableCache:
    """Process-wide cache of compiled batched chain executables.

    Entries are keyed on ``(chain signature, bucket shapes, dtypes)``.  All
    entries for one chain share a single ``jax.jit(jax.vmap(composed))``
    object (XLA specializes per shape under it); the explicit per-key
    bookkeeping is what lets callers *observe* reuse: ``misses`` count new
    (chain, shape, dtype) combinations, ``traces`` count actual re-traces
    of the composed function — zero new traces for a repeated identical
    chain is the cache's contract.
    """

    def __init__(self, max_chains: int = 128):
        self._lock = threading.Lock()
        self.max_chains = max_chains
        # chain signature -> (jitted vmapped callable, trace counter box);
        # insertion/access order maintained for LRU eviction — signatures
        # hold the chain's fn objects, so an unbounded cache would pin
        # every deploy-time closure (and its jitted executable) forever
        self._fns: "collections.OrderedDict[Tuple, Tuple[Callable, List[int]]]" = \
            collections.OrderedDict()
        # (chain signature, shapes, dtypes) -> per-entry hit count
        self._entries: Dict[Tuple, int] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def executable(self, sig: Tuple, fns: List[Callable],
                   shapes: Tuple, dtypes: Tuple) -> Callable:
        """The compiled callable for this (chain, bucket shapes, dtypes)."""
        with self._lock:
            rec = self._fns.get(sig)
            if rec is None:
                counter = [0]

                def composed(*vals, _fns=tuple(fns), _counter=counter):
                    # runs once per (re-)trace, never per compiled call
                    _counter[0] += 1
                    for fn in _fns:
                        out = fn(*vals)
                        vals = out if isinstance(out, tuple) else (out,)
                    return vals

                rec = (jax.jit(jax.vmap(composed)), counter)
                self._fns[sig] = rec
                while len(self._fns) > self.max_chains:
                    old_sig, _ = self._fns.popitem(last=False)
                    self._entries = {k: v for k, v in self._entries.items()
                                     if k[0] != old_sig}
                    self.evictions += 1
            else:
                self._fns.move_to_end(sig)
            key = (sig, shapes, dtypes)
            if key in self._entries:
                self._entries[key] += 1
                self.hits += 1
            else:
                self._entries[key] = 0
                self.misses += 1
            return rec[0]

    def traces(self, sig: Optional[Tuple] = None) -> int:
        """Total composed-fn traces (compilations), optionally per chain."""
        with self._lock:
            if sig is not None:
                rec = self._fns.get(sig)
                return rec[1][0] if rec else 0
            return sum(c[0] for _, c in self._fns.values())

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"chains": len(self._fns), "entries": len(self._entries),
                    "hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions,
                    "traces": sum(c[0] for _, c in self._fns.values())}

    def clear(self):
        with self._lock:
            self._fns.clear()
            self._entries.clear()
            self.hits = self.misses = self.evictions = 0


#: the process-wide cache: identical fused chains across plans and
#: re-registrations reuse compiled XLA programs instead of re-tracing.
EXECUTABLE_CACHE = ExecutableCache()


@dataclasses.dataclass
class BatchedJittedFuse(JittedFuse):
    """A jitted fused chain executed as ONE vmapped dispatch per batch.

    ``apply_batched`` stacks the table's rows into device arrays (padding
    the row count up to a power-of-two bucket), looks up the compiled
    executable in the process-wide ``EXECUTABLE_CACHE``, and issues a single
    XLA dispatch for the whole batch.  Rows with heterogeneous array shapes
    are split into shape-uniform groups (one dispatch each) — ragged dims
    participate in the cache key, so recompiles stay bounded per distinct
    shape.  ``apply`` delegates to the batched path, so even non-batching
    nodes pay one dispatch per *table* instead of one per row; the per-row
    jitted path and the interpreted ``Fuse`` path remain as fallbacks for
    non-stackable values and non-traceable functions.
    """
    bucket_sizes: Tuple[int, ...] = DEFAULT_BUCKETS

    def __post_init__(self):
        super().__post_init__()
        self._sig = chain_signature(self.ops)
        self._batch_succeeded = False
        self._vmap_fallback = False   # vmap untraceable; per-row jit works
        # dispatch accounting (read by benchmarks and runtime metrics)
        self.batch_dispatches = 0
        self.rows_batched = 0

    @property
    def name(self):
        return "vjit[" + ",".join(o.name for o in self.ops) + "]"

    # -- batched execution ---------------------------------------------------
    def _stack_groups(self, rows):
        """Group rows by per-column (shape, dtype); returns
        [(indices, [col arrays])] preserving original order within groups.
        Values are materialized as host (numpy) arrays: stacking happens as
        one memcpy + ONE device_put per column, instead of an n-arg XLA
        concatenate whose dispatch costs about as much as the n per-row
        calls the batching is meant to eliminate."""
        groups: Dict[Tuple, Tuple[List[int], List[List[Any]]]] = {}
        for i, r in enumerate(rows):
            arrs = [np.asarray(v) for v in r.values]
            key = tuple((a.shape, str(a.dtype)) for a in arrs)
            idxs, cols = groups.setdefault(
                key, ([], [[] for _ in arrs]))
            idxs.append(i)
            for c, a in zip(cols, arrs):
                c.append(a)
        return list(groups.values())

    def apply_batched(self, tables: List[Table], ctx=None) -> Table:
        if self._fallback:
            return ops.Fuse.apply(self, tables, ctx)
        if self._vmap_fallback:
            return JittedFuse.apply(self, tables, ctx)
        (t,) = tables
        schema = self.out_schema([t.schema])
        out_t = Table(schema, grouping=t.grouping)
        if not t.rows:
            return out_t
        try:
            groups = self._stack_groups(t.rows)
        except Exception:
            # non-array values slipped past the annotations: the batched
            # path cannot stack them — per-row jitted path still applies
            return JittedFuse.apply(self, tables, ctx)
        out_rows: List[Any] = [None] * len(t.rows)
        vmapped_any = False      # did a vmapped dispatch succeed THIS call?
        try:
            for idxs, cols in groups:
                n = len(idxs)
                if n == 1:
                    # singleton fast-path: the per-row executable avoids the
                    # stack/pad/device_get round-trip (measurably cheaper
                    # below the batching crossover at ~8 rows)
                    i = idxs[0]
                    out = self._jitted(*(jnp.asarray(v)
                                         for v in t.rows[i].values))
                    self.row_dispatches += 1
                    if len(out) != self._out_arity:
                        raise ops.TypecheckError(
                            f"{self.name}: returned {len(out)} values, "
                            f"schema expects {self._out_arity}")
                    self._jit_succeeded = True
                    out_rows[i] = t.rows[i].replace(tuple(out))
                    continue
                bucket = bucket_rows(n, self.bucket_sizes)
                # pad the row LIST (repeating row 0) before stacking, so
                # stacked shapes are always bucket-sized — padding on device
                # would compile a fresh XLA program per distinct n,
                # defeating the bucketing entirely
                stacked = [jnp.asarray(np.stack(c + c[:1] * (bucket - n)))
                           for c in cols]
                shapes = tuple(a.shape for a in stacked)
                dtypes = tuple(str(a.dtype) for a in stacked)
                fn = EXECUTABLE_CACHE.executable(
                    self._sig, [m.fn for m in self.ops], shapes, dtypes)
                outs = fn(*stacked)
                if len(outs) != self._out_arity:
                    raise ops.TypecheckError(
                        f"{self.name}: returned {len(outs)} values, schema "
                        f"expects {self._out_arity}")
                self.batch_dispatches += 1
                self.rows_batched += n
                vmapped_any = True
                # ONE host sync per batch: slicing a device array per row
                # would issue n gather dispatches — as many as the per-row
                # path — while numpy row views are free.  Downstream
                # consumers (jnp ops, lowered chains) take ndarray
                # transparently via jnp.asarray.
                outs_host = jax.device_get(outs)
                for j, i in enumerate(idxs):
                    out_rows[i] = t.rows[i].replace(
                        tuple(col[j] for col in outs_host))
        except ops.TypecheckError:
            raise
        except (jax.errors.JAXTypeError, TypeError, NotImplementedError,
                ValueError):
            # latching policy mirrors the per-row path, but the two
            # executables are judged separately: a chain can be jit-traceable
            # per row yet fail under vmap (callbacks, batching-hostile
            # primitives) — then the per-row executable keeps serving.
            # Proven executables never latch; their errors are data errors.
            if self._batch_succeeded and self._jit_succeeded:
                raise
            if self._jit_succeeded:
                # per-row proven; the vmapped path is the suspect
                self._vmap_fallback = True
                return JittedFuse.apply(self, tables, ctx)
            if self._batch_succeeded:
                # vmap proven but the per-row (singleton) call failed:
                # composed fn traced fine under vmap, so treat as data error
                raise
            self._fallback = True
            return ops.Fuse.apply(self, tables, ctx)
        if vmapped_any:
            # a singleton-only table proves the per-row executable, not the
            # vmapped one — conflating them would turn a later first vmap
            # trace failure into a permanent request-time error
            self._batch_succeeded = True
        out_t.rows = out_rows
        return out_t

    def apply(self, tables: List[Table], ctx=None) -> Table:
        return self.apply_batched(tables, ctx)


def lower_fuse(fuse: ops.Fuse, *, batched: bool = False,
               bucket_sizes: Tuple[int, ...] = DEFAULT_BUCKETS) -> JittedFuse:
    """Lower an interpreted ``Fuse`` into a ``JittedFuse`` (or, with
    ``batched=True``, a ``BatchedJittedFuse``).  Annotations are the
    caller's job — this only swaps the execution strategy."""
    if batched:
        lowered: JittedFuse = BatchedJittedFuse(list(fuse.ops),
                                                bucket_sizes=bucket_sizes)
    else:
        lowered = JittedFuse(list(fuse.ops))
    lowered.resource_class = fuse.resource_class
    lowered.batching = fuse.batching
    lowered.high_variance = fuse.high_variance
    lowered.competitive_replicas = fuse.competitive_replicas
    return lowered
