"""XLA-level lowering of fused JAX chains (tentpole of the compilation
pipeline).

Graph-level fusion (``FuseChainsPass``) collapses a linear chain into one
``Fuse`` node, but that node still *interprets* its sub-operators one Python
call at a time — per-row, per-op dispatch plus runtime typechecks.  When the
chain is entirely JAX-array ``Map``/``Filter`` operators placed on a
GPU-class executor, we can do better: compose the per-op functions into one
program and hand the whole thing to ``jax.jit``, so XLA fuses the
arithmetic across operator boundaries and the runtime pays a single
dispatch per row.

``JittedFuse`` keeps the exact ``Fuse`` interface (schema/grouping
propagation, ``ops`` list) so every graph-level invariant still holds; only
``apply`` changes.  ``jax.jit`` compiles lazily on first call and re-uses
the executable across rows and requests (shapes are stable in a serving
pipeline, which is what makes this profitable).

``BatchedJittedFuse`` goes further (paper §4 Batching, Fig 8): it executes
the whole chain as a single ``jax.vmap``-over-rows ``jax.jit`` dispatch per
batch.  Row counts are padded up to power-of-two buckets so XLA recompiles
are bounded (O(log max_batch) shapes per chain instead of one per batch
size), and compiled executables live in a process-wide cache keyed on
``(chain signature, bucket shapes, dtypes)`` so identical chains across
re-registrations and plans reuse XLA programs instead of re-tracing.
Ragged batches (rows whose arrays differ in shape) are split into
shape-uniform groups — one dispatch per group — and anything that cannot
be stacked or traced falls back to the per-row jitted / interpreted path.

Three engine capabilities live at this layer:

* **Device residency** — ``apply_batched`` accepts and (with
  ``emit_device=True``) emits a :class:`~repro.core.table.DeviceTable`, so
  a chain of adjacent device-lowered DAG nodes pays ONE host->device stack
  at entry and ONE device->host gather at the demux boundary instead of a
  full round-trip per node.  Buffers the pipeline exclusively owns are
  donated to XLA (``donate_argnums``) so output batches reuse input
  allocations.
* **Filter-in-jit** — ``Filter`` operators lower into the jitted body as
  boolean masking: the mask rides along as a device column and dropped rows
  are compacted only at the device->host boundary, so filter-containing
  chains still execute as one dispatch.
* **Cost-based exec-path routing** — the executable cache records measured
  per-row vs batched latencies per chain (``ChainProfile``); small batches
  below the measured crossover are routed to the per-row executable
  automatically, which removes the stacking overhead that made tiny batches
  slower than per-row execution.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import operators as ops
from repro.core.table import (HOST_COPIES, DeviceTable, Table,
                              note_host_copy)

try:  # the container bakes jax in, but keep the core importable without it
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jax = None
    jnp = None

#: annotation types treated as "JAX array" for lowering.  Deliberately NOT
#: np.ndarray: the jitted chain emits jax.Array values, so only fns that
#: already declare jax.Array keep their downstream value types unchanged.
_ARRAY_TYPES: Tuple[type, ...] = ()
if jax is not None:
    _ARRAY_TYPES = (jax.Array,)

#: value types jit commits directly (leaf, not pytree) — these skip the
#: per-column normalization on the per-row hot path
_FAST_ROW_TYPES: Tuple[type, ...] = (np.ndarray, np.generic, float, int,
                                     bool, complex)
if jax is not None:
    _FAST_ROW_TYPES = (jax.Array,) + _FAST_ROW_TYPES


def _array_annotation(t) -> bool:
    return any(t is a for a in _ARRAY_TYPES)


def array_annotation(t) -> bool:
    """Is ``t`` an array annotation for lowering purposes?  Public name
    for the eligibility test ``map_is_jax_lowerable``/
    ``filter_is_jax_lowerable`` apply per argument — the static verifier
    (``repro.analysis``) gates abstract interpretation on the same
    predicate so the two can never disagree about what lowers."""
    return _array_annotation(t)


def map_is_jax_lowerable(m: ops.Operator) -> bool:
    """A ``Map`` whose argument and return annotations are all arrays.
    ``m._schema`` already holds the expanded return types (tuple returns
    included) from ``operators._ret_schema``."""
    if not isinstance(m, ops.Map) or jax is None:
        return False
    arg_types = m._arg_types
    if not arg_types or any(a is None or not _array_annotation(a)
                            for a in arg_types):
        return False
    return all(_array_annotation(t) for _, t in m._schema)


def filter_is_jax_lowerable(f: ops.Operator) -> bool:
    """A ``Filter`` whose arguments are all arrays and whose predicate is
    declared ``-> bool``: it lowers into the jitted body as a boolean
    mask column (rows compacted only at the device->host boundary)."""
    if not isinstance(f, ops.Filter) or jax is None:
        return False
    arg_types, ret = ops.fn_signature(f.fn)
    if ret is not bool:
        return False
    return bool(arg_types) and all(a is not None and _array_annotation(a)
                                   for a in arg_types)


def op_is_jax_lowerable(op: ops.Operator) -> bool:
    return map_is_jax_lowerable(op) or filter_is_jax_lowerable(op)


def fuse_is_jax_lowerable(fuse: ops.Operator, placement: str,
                          min_ops: int = 2) -> bool:
    """Eligibility: a ``Fuse`` of >= ``min_ops`` JAX-array maps/filters
    placed on a GPU-class node (accelerator-attached executor)."""
    return (isinstance(fuse, ops.Fuse)
            and not isinstance(fuse, JittedFuse)
            and placement == "gpu"
            and len(fuse.ops) >= min_ops
            and all(op_is_jax_lowerable(m) for m in fuse.ops))


def _chain_steps(chain_ops: List[ops.Operator]) -> Tuple[Tuple[str, Any], ...]:
    return tuple(("filter" if isinstance(m, ops.Filter) else "map", m.fn)
                 for m in chain_ops)


def compose_steps(steps, *, masked_input: bool, with_keep: bool,
                  counter: Optional[List[int]] = None) -> Callable:
    """The ONE definition of chain composition, shared by the per-row and
    vmapped executables (the router swaps between them, so their keep-mask
    semantics must be identical): apply maps in sequence, AND every
    filter's predicate into the keep bit.

    ``masked_input`` — the callable takes the keep mask as its first
    argument (device-resident batches thread an upstream mask through);
    ``with_keep`` — prepend the final keep to the outputs (always true
    when ``masked_input``); ``counter`` — trace counter, bumped once per
    (re-)trace, never per compiled call.
    """
    steps = tuple(s if isinstance(s, tuple) else ("map", s) for s in steps)
    emit_keep = masked_input or with_keep

    def composed(*args):
        if counter is not None:
            counter[0] += 1
        if masked_input:
            keep, vals = args[0], args[1:]
        else:
            keep, vals = jnp.bool_(True), args
        for kind, fn in steps:
            if kind == "filter":
                keep = jnp.logical_and(keep, fn(*vals))
            else:
                out = fn(*vals)
                vals = out if isinstance(out, tuple) else (out,)
        return ((keep,) + tuple(vals)) if emit_keep else tuple(vals)

    return composed


@dataclasses.dataclass
class JittedFuse(ops.Fuse):
    """A fused chain of JAX map/filter operators compiled to ONE jitted
    callable.

    The composed function applies every constituent ``fn`` in sequence
    inside a single trace, so XLA fuses across operator boundaries and each
    row costs one dispatch instead of ``len(ops)`` interpreted calls.
    Filters contribute a boolean ``keep`` output rather than control flow;
    the caller drops rows whose keep is False.
    """

    def __post_init__(self):
        if jax is None:  # pragma: no cover
            raise RuntimeError("JittedFuse requires jax")
        steps = _chain_steps(self.ops)
        self._steps = steps
        self._has_filter = any(k == "filter" for k, _ in steps)
        self._sig = chain_signature(self.ops)
        self._jitted = jax.jit(compose_steps(
            steps, masked_input=False, with_keep=self._has_filter))
        last_map = next((m for m in reversed(self.ops)
                         if isinstance(m, ops.Map)), None)
        self._out_arity = (len(last_map._schema) if last_map is not None
                           else len(self.ops[0]._arg_types))
        self._fallback = False
        self._jit_succeeded = False
        self.row_dispatches = 0     # jitted per-row XLA dispatches issued
        self._prof: Optional[ChainProfile] = None
        self._prof_version = -1
        self._timing_tick = 0
        self._force_time = False    # set by a per-row routing probe

    def profile(self) -> "ChainProfile":
        """This chain's measured cost profile (cached handle into the
        process-wide executable cache; refreshed after a cache clear)."""
        v = EXECUTABLE_CACHE.version
        if self._prof is None or self._prof_version != v:
            self._prof = EXECUTABLE_CACHE.profile(self._sig)
            self._prof_version = v
        return self._prof

    @property
    def name(self):
        return "jit[" + ",".join(o.name for o in self.ops) + "]"

    @property
    def jitted_fn(self):
        """The single compiled callable (one per fused chain)."""
        return self._jitted

    def _row_call(self, r):
        """One per-row jitted dispatch; returns the output Row, or None for
        a row a fused filter dropped.  Array/scalar values go to the
        executable as-is (jit commits them itself — no per-column
        ``jnp.asarray`` on the hot path); anything else (a Python list
        smuggled past an array annotation) is normalized first, because
        jit would treat it as a pytree and silently compute nonsense."""
        out = self._jitted(*(v if isinstance(v, _FAST_ROW_TYPES)
                             else jnp.asarray(v) for v in r.values))
        self.row_dispatches += 1
        keep = None
        if self._has_filter:
            keep, out = out[0], tuple(out[1:])
        if len(out) != self._out_arity:
            raise ops.TypecheckError(
                f"{self.name}: returned {len(out)} values, schema "
                f"expects {self._out_arity}")
        self._jit_succeeded = True
        if keep is not None and not bool(keep):
            return None
        return r.replace(tuple(out))

    def apply(self, tables: List[Table], ctx=None) -> Table:
        if self._fallback:
            return ops.Fuse.apply(self, tables, ctx)
        (t,) = tables
        schema = self.out_schema([t.schema])
        rows = []
        # router timing is SAMPLED: warm multi-row calls of a chain whose
        # router actually consults the measurement (adaptive routing on a
        # batched lowering — plain per-row chains would pay the sync for
        # nothing), one in TIMING_SAMPLE_EVERY — the host sync drains the
        # async dispatch pipeline, so it must not tax every call
        timed = False
        if getattr(self, "adaptive_routing", False) and \
                self._jit_succeeded and len(t.rows) > 1:
            timed = self._force_time or \
                self._timing_tick % TIMING_SAMPLE_EVERY == 0
            self._timing_tick += 1
        self._force_time = False
        t0 = time.perf_counter()
        try:
            for r in t.rows:
                out = self._row_call(r)
                if out is not None:
                    rows.append(out)
        except ops.TypecheckError:
            raise
        except (jax.errors.JAXTypeError, TypeError, NotImplementedError):
            # annotations said "array" but the fn is not jax-traceable
            # (data-dependent control flow, numpy side effects, ...).
            # Tracing happens on the first call, so only latch the
            # permanent fallback before any jitted call has succeeded;
            # a per-request data error on a proven-traceable chain (and
            # transient runtime errors like XLA OOM) propagates instead
            # of silently disabling the jitted path for the deployment.
            if self._jit_succeeded:
                raise
            self._fallback = True
            return ops.Fuse.apply(self, tables, ctx)
        if timed and rows:
            # feed the exec-path router: measured warm per-row cost (cold
            # calls include the XLA trace and would poison the estimate;
            # singleton calls don't amortize the fixed per-call overhead
            # and would overstate the marginal per-row cost at larger n).
            # Block on the outputs first — on async backends the dispatches
            # return immediately, and an unsynced timing would make the
            # router believe per-row costs microseconds, pinning batches to
            # the slow path (note_batched times host-to-host; this must be
            # symmetric)
            jax.block_until_ready([r.values for r in rows])
            self.profile().note_per_row(
                (time.perf_counter() - t0) / len(t.rows))
        out_t = Table(schema, grouping=t.grouping)
        out_t.rows = rows
        return out_t


# ---------------------------------------------------------------------------
# batched (vmap-over-rows) execution: shape buckets + executable cache
# ---------------------------------------------------------------------------

#: default row-count buckets: powers of two.  A batch of n rows is padded up
#: to the smallest bucket >= n, bounding recompiles to O(log max_batch)
#: distinct shapes per chain.
DEFAULT_BUCKETS: Tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64)


# ---------------------------------------------------------------------------
# degraded serving: cheap execution variants under overload
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DegradePolicy:
    """How a low-priority request executes under overload pressure — only
    variants the executable cache already holds, so degrading never pays a
    fresh XLA trace on the hot path:

    * ``per_row`` — route to the per-row jitted executable (always compiled
      by the time any traffic flows; skips stack/pad/gather entirely);
    * ``bucket_cap`` — when the request does batch, cap its padding bucket
      (small buckets are the first ones traffic warms);
    * ``competitive`` — False disables competitive replication for the
      request (the runtime dispatches ONE replica of each wait-any group
      instead of racing all of them — tail suppression is a luxury a
      best-effort request does not get under overload).
    """
    per_row: bool = True
    bucket_cap: Optional[int] = 8
    competitive: bool = False


#: thread-local carrying the active DegradePolicy: the executor sets it
#: around a degraded request's node fn, and the exec-path router consults
#: it — the policy must travel WITH the work onto the executor thread, so
#: a context variable on the submitting thread would be invisible here
_DEGRADE_TLS = threading.local()


@contextlib.contextmanager
def degraded_execution(policy: Optional["DegradePolicy"]):
    """Execute the enclosed chain calls under ``policy`` (None = no-op).
    The exec-path router (``BatchedJittedFuse``) reads the active policy
    via :func:`active_degrade` and picks the cheap, already-compiled
    variant instead of the throughput-optimal one."""
    prev = getattr(_DEGRADE_TLS, "policy", None)
    _DEGRADE_TLS.policy = policy
    try:
        yield
    finally:
        _DEGRADE_TLS.policy = prev


def active_degrade() -> Optional["DegradePolicy"]:
    """The DegradePolicy in effect on this thread, or None."""
    return getattr(_DEGRADE_TLS, "policy", None)

#: per-row router timing is sampled 1-in-N (the measurement's host sync
#: drains the async dispatch pipeline — it must not tax every
#: steady-state per-row call); aligned with ChainProfile.PROBE_EVERY
TIMING_SAMPLE_EVERY = 16


def bucket_rows(n: int, buckets: Tuple[int, ...] = DEFAULT_BUCKETS) -> int:
    """Smallest bucket >= n; beyond the table, next power of two."""
    for b in buckets:
        if n <= b:
            return b
    b = buckets[-1] if buckets else 1
    while b < n:
        b *= 2
    return b


def chain_signature(chain_ops: List[ops.Operator]) -> Tuple[Any, ...]:
    """Identity of a fused chain: the tuple of its (op kind, function)
    pairs.  Two ``Fuse`` nodes built from the same function objects (the
    common case across re-registrations of the same flow) share compiled
    executables; redefining a function yields a new object and, correctly,
    a new entry."""
    return _chain_steps(chain_ops)


def crossover_from_costs(per_row_s: Optional[float],
                         batched_s: Dict[int, float],
                         max_n: int = 1024) -> Optional[int]:
    """THE crossover rule, shared by the live router (``ChainProfile``)
    and the offline profiler's ``OpLatencyCurve`` — the smallest batch
    size n at which one batched dispatch at n's covering measured bucket
    beats n per-row dispatches, or None while either path is unmeasured.
    One definition, so the optimizer's offline decision and the runtime
    router's live decision cannot silently diverge."""
    if per_row_s is None or not batched_s:
        return None
    measured = sorted(batched_s)
    for n in range(1, min(max_n, measured[-1]) + 1):
        b = next((batched_s[m] for m in measured if m >= n), None)
        if b is not None and n * per_row_s >= b:
            return n
    return None


class ChainProfile:
    """Measured execution costs of one chain, feeding the exec-path router.

    ``per_row_s`` is an EWMA of warm per-row jitted latency (seconds per
    row); ``batched_s[bucket]`` an EWMA of warm whole-batch latency
    (seconds per dispatch, host->host) at that padded bucket size.  The
    router batches a table of n rows only when the measured batched cost at
    its bucket beats n per-row dispatches — which is what removes the
    small-batch regression where stacking costs more than it saves."""

    __slots__ = ("alpha", "per_row_s", "per_row_samples",
                 "batched_s", "batched_samples", "_since_probe", "_lock")

    #: after this many consecutive same-path routings at a bucket, take
    #: the other path once — a single slow early sample must not pin the
    #: router forever (estimates go stale unless refreshed)
    PROBE_EVERY = 16

    #: never probe the per-row direction with more rows than this: a
    #: per-row probe pays n sequential dispatches, which on a large batch
    #: would turn every PROBE_EVERY-th request into a p99 outlier.  Large
    #: batches therefore stay vmapped unless small-batch traffic has
    #: already measured the per-row path.
    PROBE_ROW_CAP = 8

    def __init__(self, alpha: float = 0.3):
        self.alpha = alpha
        self.per_row_s: Optional[float] = None
        self.per_row_samples = 0
        self.batched_s: Dict[int, float] = {}
        self.batched_samples: Dict[int, int] = {}
        self._since_probe: Dict[int, int] = {}
        # mutated from every executor thread serving the chain; snapshot()
        # iterates the dicts, so unsynchronized inserts could blow up a
        # concurrent export with "dict changed size during iteration"
        self._lock = threading.Lock()

    def _ewma(self, old: Optional[float], new: float) -> float:
        if old is None:
            return new
        # clamp the sample: a scheduler stall can be 100x the true cost,
        # and an unclamped EWMA (mean-like) would need many clean samples
        # to recover — genuine 2-3x shifts still move the estimate fast
        return (1.0 - self.alpha) * old + self.alpha * min(new, 3.0 * old)

    def note_per_row(self, seconds_per_row: float) -> None:
        if seconds_per_row <= 0:
            return
        with self._lock:
            self.per_row_s = self._ewma(self.per_row_s, seconds_per_row)
            self.per_row_samples += 1

    def note_batched(self, bucket: int, seconds: float) -> None:
        if seconds <= 0:
            return
        with self._lock:
            n = self.batched_samples.get(bucket, 0) + 1
            self.batched_samples[bucket] = n
            if n == 1:
                # the first warm execution still pays one-time costs
                # (allocator growth, page faults); folding it into the
                # EWMA overstates the batched path and misroutes
                return
            self.batched_s[bucket] = self._ewma(
                self.batched_s.get(bucket), seconds)

    def prefer_per_row(self, n: int, bucket: int) -> bool:
        """True when n per-row dispatches are measured cheaper than one
        batched dispatch at ``bucket``.  Unmeasured paths prefer batching
        (the batched call doubles as the probe that measures it)."""
        with self._lock:
            b = self.batched_s.get(bucket)
            if b is None or self.per_row_s is None:
                return False
            return n * self.per_row_s < b

    def route_decision(self, n: int, bucket: int) -> Tuple[bool, bool]:
        """``(route_per_row, is_probe)``: ``prefer_per_row`` plus
        SYMMETRIC probing — every ``PROBE_EVERY``-th decision at a bucket
        takes the other path, so the unused path's estimate stays fresh
        and gets measured at all when it has never run.  Per-row probes
        are capped at ``PROBE_ROW_CAP`` rows (see above); a probe call
        must always be measured (its n dispatches are the measurement)."""
        prefer = self.prefer_per_row(n, bucket)
        with self._lock:
            seen = self._since_probe.get(bucket, 0) + 1
            if seen >= self.PROBE_EVERY:
                self._since_probe[bucket] = 0
                if prefer:
                    return False, True             # refresh batched cost
                return n <= self.PROBE_ROW_CAP, True   # refresh per-row
            self._since_probe[bucket] = seen
            return prefer, False

    def route_per_row(self, n: int, bucket: int) -> bool:
        return self.route_decision(n, bucket)[0]

    def crossover_rows(self, max_n: int = 1024) -> Optional[int]:
        """Smallest batch size at which the vmapped path is measured to
        win, or None while either path is unmeasured.  Candidate buckets
        are the MEASURED ones (the chain may have been lowered with custom
        ``bucket_sizes``; assuming the defaults would report a crossover
        for buckets that never exist)."""
        with self._lock:
            per_row_s = self.per_row_s
            batched_s = dict(self.batched_s)
        return crossover_from_costs(per_row_s, batched_s, max_n)

    # -- serialization (profiler persistence across processes) ---------------
    def to_dict(self) -> Dict[str, Any]:
        """Stable JSON-serializable state: the EWMAs and sample counts the
        router needs, with bucket keys as strings (JSON objects only have
        string keys — ``from_dict`` restores ints)."""
        with self._lock:
            return {
                "alpha": self.alpha,
                "per_row_s": self.per_row_s,
                "per_row_samples": self.per_row_samples,
                "batched_s": {str(b): s for b, s in self.batched_s.items()},
                "batched_samples": {str(b): n for b, n
                                    in self.batched_samples.items()},
            }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ChainProfile":
        p = cls(alpha=float(d.get("alpha", 0.3)))
        per_row = d.get("per_row_s")
        p.per_row_s = float(per_row) if per_row is not None else None
        p.per_row_samples = int(d.get("per_row_samples", 0))
        p.batched_s = {int(b): float(s)
                       for b, s in (d.get("batched_s") or {}).items()}
        p.batched_samples = {int(b): int(n)
                             for b, n in (d.get("batched_samples") or {})
                             .items()}
        return p

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            per_row_s = self.per_row_s
            per_row_samples = self.per_row_samples
            batched_s = dict(self.batched_s)
            batched_samples = dict(self.batched_samples)
        return {
            "per_row_ms": (per_row_s * 1e3
                           if per_row_s is not None else None),
            "per_row_samples": per_row_samples,
            "batched_ms": {b: s * 1e3 for b, s in sorted(batched_s.items())},
            "batched_samples": dict(sorted(batched_samples.items())),
            "crossover_rows": self.crossover_rows(),
        }


class ExecutableCache:
    """Process-wide cache of compiled batched chain executables.

    Entries are keyed on ``(chain signature, bucket shapes, dtypes, masked,
    donate)``.  All entries for one chain share its composed functions (XLA
    specializes per shape under ``jax.jit``); the explicit per-key
    bookkeeping is what lets callers *observe* reuse: ``misses`` count new
    combinations, ``traces`` count actual re-traces of the composed
    function — zero new traces for a repeated identical chain is the
    cache's contract.

    Two executable variants exist per chain: *masked* (a boolean liveness
    column threads through the body — used when the chain fuses a Filter or
    consumes an upstream-masked ``DeviceTable``) and *donating* (inputs are
    handed to XLA for buffer reuse — used when the caller exclusively owns
    the batch buffers).  The cache also carries each chain's measured
    :class:`ChainProfile` for exec-path routing.
    """

    def __init__(self, max_chains: int = 128):
        self._lock = threading.Lock()
        self.max_chains = max_chains
        #: bumped on clear() so ops can cache their ChainProfile handle
        self.version = 0
        # chain signature -> {"counter": [traces], "jitted": {(masked,
        # donate): callable}}; insertion/access order maintained for LRU
        # eviction — signatures hold the chain's fn objects, so an
        # unbounded cache would pin every deploy-time closure (and its
        # jitted executable) forever
        self._fns: "collections.OrderedDict[Tuple, Dict[str, Any]]" = \
            collections.OrderedDict()
        # (chain signature, shapes, dtypes, masked, donate) -> hit count
        self._entries: Dict[Tuple, int] = {}
        # independently LRU-bounded: profiles are also created for chains
        # that never compile a vmapped executable (per-row-only chains),
        # and their signatures pin fn closures just like _fns entries do
        self._profiles: "collections.OrderedDict[Tuple, ChainProfile]" = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def executable(self, sig: Tuple, steps, shapes: Tuple, dtypes: Tuple,
                   *, masked: bool = False, donate: bool = False) -> Callable:
        """The compiled callable for this (chain, bucket shapes, dtypes).

        ``shapes``/``dtypes`` describe the value columns only; the masked
        variant takes the boolean liveness column as its first argument.
        With ``donate=True`` every input buffer is donated to XLA
        (``donate_argnums``) — callers must own them exclusively."""
        with self._lock:
            rec = self._fns.get(sig)
            if rec is None:
                rec = {"counter": [0], "jitted": {}}
                self._fns[sig] = rec
                while len(self._fns) > self.max_chains:
                    old_sig, _ = self._fns.popitem(last=False)
                    self._entries = {k: v for k, v in self._entries.items()
                                     if k[0] != old_sig}
                    if self._profiles.pop(old_sig, None) is not None:
                        # invalidate cached profile handles: a still-live
                        # op of the evicted chain must not keep feeding an
                        # orphaned profile while fresh lookups get a new one
                        self.version += 1
                    self.evictions += 1
            else:
                self._fns.move_to_end(sig)
            variant = (bool(masked), bool(donate))
            fn = rec["jitted"].get(variant)
            if fn is None:
                composed = compose_steps(steps, masked_input=masked,
                                         with_keep=masked,
                                         counter=rec["counter"])
                n_args = len(shapes) + (1 if masked else 0)
                fn = jax.jit(jax.vmap(composed),
                             donate_argnums=(tuple(range(n_args))
                                             if donate else ()))
                rec["jitted"][variant] = fn
            key = (sig, shapes, dtypes) + variant
            if key in self._entries:
                self._entries[key] += 1
                self.hits += 1
            else:
                self._entries[key] = 0
                self.misses += 1
            return fn

    def profile(self, sig: Tuple) -> ChainProfile:
        """The chain's measured cost profile (created on first access)."""
        with self._lock:
            p = self._profiles.get(sig)
            if p is None:
                p = self._profiles[sig] = ChainProfile()
                while len(self._profiles) > self.max_chains:
                    self._profiles.popitem(last=False)
                    # invalidate cached handles (see eviction above)
                    self.version += 1
            else:
                self._profiles.move_to_end(sig)
            return p

    def traces(self, sig: Optional[Tuple] = None) -> int:
        """Total composed-fn traces (compilations), optionally per chain."""
        with self._lock:
            if sig is not None:
                rec = self._fns.get(sig)
                return rec["counter"][0] if rec else 0
            return sum(r["counter"][0] for r in self._fns.values())

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"chains": len(self._fns), "entries": len(self._entries),
                    "hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions,
                    "traces": sum(r["counter"][0]
                                  for r in self._fns.values())}

    def clear(self):
        with self._lock:
            self._fns.clear()
            self._entries.clear()
            self._profiles.clear()
            self.hits = self.misses = self.evictions = 0
            self.version += 1


#: the process-wide cache: identical fused chains across plans and
#: re-registrations reuse compiled XLA programs instead of re-tracing.
EXECUTABLE_CACHE = ExecutableCache()


@dataclasses.dataclass
class BatchedJittedFuse(JittedFuse):
    """A jitted fused chain executed as ONE vmapped dispatch per batch.

    ``apply_batched`` stacks the table's rows into a device-resident
    :class:`DeviceTable` (padding the row count up to a power-of-two
    bucket), looks up the compiled executable in the process-wide
    ``EXECUTABLE_CACHE``, and issues a single XLA dispatch for the whole
    batch.  Rows with heterogeneous array shapes are split into
    shape-uniform groups (one dispatch each) — ragged dims participate in
    the cache key, so recompiles stay bounded per distinct shape.

    Device residency: when handed a ``DeviceTable`` the chain runs without
    touching the host, and with ``emit_device=True`` it returns one — the
    runtime threads batches through adjacent device nodes this way, paying
    one stack at chain entry and one gather at the demux boundary.
    Exclusively-owned input buffers are donated to XLA so the output batch
    reuses their allocation.

    Exec-path routing: the chain's measured :class:`ChainProfile` decides
    per call whether n rows run as one vmapped dispatch or n per-row
    dispatches — singletons always take the per-row executable (no
    stacking at all), larger tables batch once the measured crossover says
    it pays.  The per-row jitted path and the interpreted ``Fuse`` path
    remain as fallbacks for non-stackable values and non-traceable
    functions.
    """
    bucket_sizes: Tuple[int, ...] = DEFAULT_BUCKETS
    adaptive_routing: bool = True

    def __post_init__(self):
        super().__post_init__()
        self._batch_succeeded = False
        self._vmap_fallback = False   # vmap untraceable; per-row jit works
        # dispatch + host-copy accounting (read by benchmarks and metrics)
        self.batch_dispatches = 0
        self.rows_batched = 0
        self.host_stacks = 0
        self.host_gathers = 0

    @property
    def name(self):
        return "vjit[" + ",".join(o.name for o in self.ops) + "]"

    # -- exec-path routing ---------------------------------------------------
    def _route_per_row(self, n: int) -> bool:
        """True when n rows should take the per-row executable: singletons
        always (stacking a batch of one only adds overhead), larger tables
        when the chain's measured crossover says per-row wins."""
        if n <= 1:
            return True
        pol = active_degrade()
        if pol is not None and pol.per_row:
            # degraded request: the per-row executable is always warm and
            # skips stack/pad/gather — take it regardless of the measured
            # crossover, and don't let the call probe/feed the EWMA
            return True
        if not self.adaptive_routing:
            return False
        route, probe = self.profile().route_decision(
            n, bucket_rows(n, self.bucket_sizes))
        if route and probe:
            # a per-row probe exists to measure: force the timing sample
            self._force_time = True
        return route

    # -- batched execution ---------------------------------------------------
    def _stack_groups(self, rows):
        """Group rows by per-column (shape, dtype); returns
        [(indices, [col lists])] preserving original order within groups.
        Values are materialized as host (numpy) arrays in ONE
        ``jax.device_get`` for the whole table (row values are frequently
        jax arrays already committed to the device — per-value conversion
        would pay one host sync per row); stacking then happens as one
        memcpy + ONE device_put per column, instead of an n-arg XLA
        concatenate whose dispatch costs about as much as the n per-row
        calls the batching is meant to eliminate."""
        host_vals = [list(r.values) for r in rows]
        if any(isinstance(v, jax.Array) for rv in host_vals for v in rv):
            host_vals = jax.device_get(host_vals)
            # honest accounting: this readback IS bulk row payload
            # crossing the boundary (rows arriving as host numpy — the
            # normal serving case — skip it entirely)
            note_host_copy("gathers")
            self.host_gathers += 1
        groups: Dict[Tuple, Tuple[List[int], List[List[Any]]]] = {}
        for i, rvals in enumerate(host_vals):
            arrs = [np.asarray(v) for v in rvals]
            key = tuple((a.shape, str(a.dtype)) for a in arrs)
            idxs, cols = groups.setdefault(
                key, ([], [[] for _ in arrs]))
            idxs.append(i)
            for c, a in zip(cols, arrs):
                c.append(a)
        return list(groups.values())

    def _run_device(self, dt: DeviceTable, donate: bool) -> DeviceTable:
        """ONE vmapped XLA dispatch over a device-resident batch; the
        result stays on the device.  The mask column (chain filters and/or
        upstream mask) threads through the executable."""
        masked = self._has_filter or dt.mask is not None
        shapes = tuple(tuple(c.shape) for c in dt.columns)
        dtypes = tuple(str(c.dtype) for c in dt.columns)
        do = bool(donate and dt.donatable)
        fn = EXECUTABLE_CACHE.executable(self._sig, self._steps, shapes,
                                         dtypes, masked=masked, donate=do)
        if masked:
            mask = dt.mask
            if mask is None:
                mask = jnp.asarray(np.ones(dt.cap, np.bool_))
            outs = fn(mask, *dt.columns)
            new_mask, out_cols = outs[0], outs[1:]
        else:
            out_cols = fn(*dt.columns)
            new_mask = None
        if len(out_cols) != self._out_arity:
            raise ops.TypecheckError(
                f"{self.name}: returned {len(out_cols)} values, schema "
                f"expects {self._out_arity}")
        self.batch_dispatches += 1
        self.rows_batched += dt.nrows
        if do:
            # donated buffers are gone; make accidental reuse loud
            dt.donatable = False
        return DeviceTable(self.out_schema([dt.schema]), list(out_cols),
                           dt.nrows, dt.row_ids, dt.groups,
                           grouping=dt.grouping, mask=new_mask,
                           donatable=True)

    def _apply_device(self, dt: DeviceTable, ctx, emit_device: bool,
                      donate_out: bool):
        """Device-resident fast path: DeviceTable in, DeviceTable (or host
        table, at the chain boundary) out — no host copy in between."""
        if self._fallback:
            self.host_gathers += 1
            return ops.Fuse.apply(self, [dt.to_table()], ctx)
        if self._vmap_fallback:
            self.host_gathers += 1
            return JittedFuse.apply(self, [dt.to_table()], ctx)
        try:
            out_dt = self._run_device(dt, donate=True)
        except ops.TypecheckError:
            raise
        except (jax.errors.JAXTypeError, TypeError, NotImplementedError,
                ValueError):
            if self._batch_succeeded and self._jit_succeeded:
                raise
            if self._jit_succeeded:
                self._vmap_fallback = True
                self.host_gathers += 1
                return JittedFuse.apply(self, [dt.to_table()], ctx)
            if self._batch_succeeded:
                raise
            self._fallback = True
            self.host_gathers += 1
            return ops.Fuse.apply(self, [dt.to_table()], ctx)
        self._batch_succeeded = True
        if emit_device:
            out_dt.donatable = donate_out
            return out_dt
        self.host_gathers += 1
        return out_dt.to_table()

    def apply_batched(self, tables: List[Table], ctx=None, *,
                      emit_device: bool = False,
                      donate_out: bool = False):
        (t,) = tables
        if isinstance(t, DeviceTable):
            return self._apply_device(t, ctx, emit_device, donate_out)
        if self._fallback:
            return ops.Fuse.apply(self, tables, ctx)
        if self._vmap_fallback:
            return JittedFuse.apply(self, tables, ctx)
        n = len(t.rows)
        if n == 1 and not emit_device:
            # singleton fast-path: straight to the per-row executable —
            # no stacking, no padding, no profile consult
            return JittedFuse.apply(self, tables, ctx)
        if not t.rows:
            return Table(self.out_schema([t.schema]), grouping=t.grouping)
        if not emit_device and self._route_per_row(n):
            # measured crossover says n per-row dispatches beat one
            # stack+vmap+gather round-trip
            return JittedFuse.apply(self, tables, ctx)
        t_start = time.perf_counter()      # honest: stacking cost included
        try:
            groups = self._stack_groups(t.rows)
        except Exception:
            # non-array values slipped past the annotations: the batched
            # path cannot stack them — per-row jitted path still applies
            return JittedFuse.apply(self, tables, ctx)
        out_rows: List[Any] = [None] * n
        vmapped_any = False      # did a vmapped dispatch succeed THIS call?
        try:
            for idxs, cols in groups:
                k = len(idxs)
                if k == 1 and (len(groups) > 1 or not emit_device):
                    # stray singleton in a ragged table: the per-row
                    # executable avoids the stack/pad/gather round-trip
                    i = idxs[0]
                    out_rows[i] = self._row_call(t.rows[i])
                    continue
                bucket = bucket_rows(k, self.bucket_sizes)
                pol = active_degrade()
                if pol is not None and pol.bucket_cap:
                    # degraded: pad into the smallest already-configured
                    # bucket <= cap that still fits — never a fresh shape,
                    # so no fresh XLA trace on the overloaded hot path
                    capped = tuple(b for b in self.bucket_sizes
                                   if b <= pol.bucket_cap)
                    if capped and k <= capped[-1]:
                        bucket = bucket_rows(k, capped)
                # pad the row LIST (repeating row 0) before stacking, so
                # stacked shapes are always bucket-sized — padding on
                # device would compile a fresh XLA program per distinct n,
                # defeating the bucketing entirely
                dt = DeviceTable.from_columns(
                    t.schema, cols, [t.rows[i].row_id for i in idxs],
                    [t.rows[i].group for i in idxs], pad_to=bucket,
                    grouping=t.grouping)
                self.host_stacks += 1
                was_fresh = EXECUTABLE_CACHE.misses
                out_dt = self._run_device(dt, donate=True)
                vmapped_any = True
                if emit_device and len(groups) == 1:
                    self._batch_succeeded = True
                    out_dt.donatable = donate_out
                    return out_dt
                # ONE host sync per group: slicing a device array per row
                # would issue n gather dispatches — as many as the per-row
                # path — while numpy row views are free.  Downstream
                # consumers (jnp ops, lowered chains) take ndarray
                # transparently via jnp.asarray.
                for pos, row in out_dt.host_rows():
                    out_rows[idxs[pos]] = row
                self.host_gathers += 1
                if len(groups) == 1 and EXECUTABLE_CACHE.misses == was_fresh:
                    # warm uniform batch: feed the router's batched-cost
                    # EWMA with the WHOLE path cost — stack + dispatch +
                    # gather — so the crossover reflects what a request
                    # actually pays (cold calls include the XLA trace and
                    # are skipped)
                    self.profile().note_batched(
                        bucket, time.perf_counter() - t_start)
        except ops.TypecheckError:
            raise
        except (jax.errors.JAXTypeError, TypeError, NotImplementedError,
                ValueError):
            # latching policy mirrors the per-row path, but the two
            # executables are judged separately: a chain can be jit-traceable
            # per row yet fail under vmap (callbacks, batching-hostile
            # primitives) — then the per-row executable keeps serving.
            # Proven executables never latch; their errors are data errors.
            if self._batch_succeeded and self._jit_succeeded:
                raise
            if self._jit_succeeded:
                # per-row proven; the vmapped path is the suspect
                self._vmap_fallback = True
                return JittedFuse.apply(self, tables, ctx)
            if self._batch_succeeded:
                # vmap proven but the per-row (singleton) call failed:
                # composed fn traced fine under vmap, so treat as data error
                raise
            self._fallback = True
            return ops.Fuse.apply(self, tables, ctx)
        if vmapped_any:
            # a singleton-only table proves the per-row executable, not the
            # vmapped one — conflating them would turn a later first vmap
            # trace failure into a permanent request-time error
            self._batch_succeeded = True
        out_t = Table(self.out_schema([t.schema]), grouping=t.grouping)
        out_t.rows = [r for r in out_rows if r is not None]
        return out_t

    def apply(self, tables: List[Table], ctx=None) -> Table:
        return self.apply_batched(tables, ctx)

    # -- cache warming (blue/green replanning) -------------------------------
    def warm(self, tables: List[Table], ctx=None, *,
             emit_device: bool = False, donate_out: bool = False):
        """Execute the chain once with the exec-path router BYPASSED
        (always the vmapped executable), so this call traces/loads the
        batch's bucket executable through ``EXECUTABLE_CACHE`` regardless
        of what the measured crossover would route.  The blue/green
        replanner walks a freshly compiled plan through this at every
        bucket size before any traffic is swapped onto it — the first
        post-swap request must find every executable already compiled
        (``EXECUTABLE_CACHE.traces()`` flat across the swap).

        Same contract as ``apply_batched`` (the warm-up result doubles as
        a correctness canary); a singleton input still warms the per-row
        executable, exactly the path a live singleton takes."""
        with forced_batched_routing([self]):
            return self.apply_batched(tables, ctx, emit_device=emit_device,
                                      donate_out=donate_out)


@contextlib.contextmanager
def forced_batched_routing(chain_ops):
    """Temporarily disable adaptive exec-path routing on the given lowered
    chains, so every multi-row call takes the vmapped executable — the
    cache-warming walk must trace the batched path at every bucket even
    where the live router would (correctly) route small batches per-row.
    Restores each chain's previous routing flag on exit."""
    prev = [(o, o.adaptive_routing) for o in chain_ops
            if isinstance(o, BatchedJittedFuse)]
    for o, _ in prev:
        o.adaptive_routing = False
    try:
        yield
    finally:
        for o, flag in prev:
            o.adaptive_routing = flag


def lower_fuse(fuse: ops.Fuse, *, batched: bool = False,
               bucket_sizes: Tuple[int, ...] = DEFAULT_BUCKETS) -> JittedFuse:
    """Lower an interpreted ``Fuse`` into a ``JittedFuse`` (or, with
    ``batched=True``, a ``BatchedJittedFuse``).  Annotations are the
    caller's job — this only swaps the execution strategy."""
    if batched:
        lowered: JittedFuse = BatchedJittedFuse(list(fuse.ops),
                                                bucket_sizes=bucket_sizes)
    else:
        lowered = JittedFuse(list(fuse.ops))
    lowered.resource_class = fuse.resource_class
    lowered.batching = fuse.batching
    lowered.high_variance = fuse.high_variance
    lowered.competitive_replicas = fuse.competitive_replicas
    return lowered
