"""Automated optimization selection (the paper's §7 "Future Work: Automated
Optimization Selection", implemented as a simple cost-based planner).

The paper requires the user to pick which optimizations to enable.  This
planner instead *profiles* the flow on a sample input (per-operator latency
mean/CV and output payload size) and decides:

* **fusion** — fuse a chain edge when the modeled inter-function cost
  (invocation overhead + payload transfer) is a significant fraction of the
  downstream operator's own compute time; keep slow, compute-heavy
  operators separate so the autoscaler retains per-operator granularity
  (the paper's stated fusion<->autoscaling tradeoff, §4).
* **competitive execution** — replicate operators whose latency
  coefficient-of-variation exceeds a threshold (tail-dominated stages).
* **locality / dynamic dispatch** — enabled whenever the flow contains
  ``lookup`` operators with non-trivial payloads.

``auto_deploy`` annotates the flow and deploys with the chosen flags.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

from repro.core import operators as ops
from repro.core.dataflow import Dataflow, Node
from repro.core.table import Table
from repro.runtime.netmodel import NetModel


@dataclasses.dataclass
class OpProfile:
    mean_s: float
    cv: float
    out_bytes: int
    runs: int


@dataclasses.dataclass
class Plan:
    fusion: bool
    competitive_exec: bool
    locality: bool
    replicas: Dict[int, int]            # node id -> competitive replicas
    profiles: Dict[int, OpProfile]
    notes: List[str]
    jit_fusion: bool = True             # lower fused JAX chains to XLA
    batched_lowering: bool = True       # vmap whole row batches per dispatch
    default_replicas: int = 3

    @property
    def flags(self) -> Dict[str, Any]:
        return {"fusion": self.fusion,
                "competitive_exec": self.competitive_exec,
                "locality": self.locality,
                "jit_fusion": self.jit_fusion,
                "batched_lowering": self.batched_lowering,
                "default_replicas": self.default_replicas}

    def build_pipeline(self, config=None):
        """The plan IS a pass configuration: materialize it as the
        ``PassPipeline`` the compiler will run over the physical-plan IR.
        ``config`` (a ``repro.profiling.optimizer.PlanConfig``) adds the
        SLO optimizer's per-node overrides — padding buckets, batched vs
        per-row lowering, placement — so bucket sizes stop being global
        constants."""
        from repro.core.passes import build_pipeline
        return build_pipeline(plan_config=config, **self.flags)


def profile_flow(flow: Dataflow, sample: Table, *, runs: int = 3,
                 kvs=None) -> Dict[int, OpProfile]:
    """Profile the flow at the sample's batch size, one ``OpProfile`` per
    node.  The measurement loop lives in ``repro.profiling.profiler``
    (the batch-sweep profiler) — this is the planner-facing view of a
    single-size sweep."""
    from repro.profiling.profiler import profile_flow_curves
    fp = profile_flow_curves(flow, sample, runs=runs, kvs=kvs)
    profiles: Dict[int, OpProfile] = {}
    for nid, curve in fp.curves.items():
        if not curve.buckets:
            continue
        b = max(curve.buckets)
        st = curve.buckets[b]
        profiles[nid] = OpProfile(mean_s=st.mean_s, cv=st.cv,
                                  out_bytes=st.out_bytes, runs=st.runs)
    return profiles


def make_plan(flow: Dataflow, sample: Table, *, net: Optional[NetModel] = None,
              runs: int = 3, kvs=None,
              fuse_ratio: float = 0.25,       # hop cost / compute threshold
              cv_threshold: float = 0.5,
              replicas: int = 3) -> Plan:
    net = net or NetModel()
    profiles = profile_flow(flow, sample, runs=runs, kvs=kvs)
    notes: List[str] = []

    # -- fusion: is the average chain edge dominated by hop costs? ----------
    edge_votes, edge_total = 0, 0
    for n in flow.sorted_nodes():
        if n.op is None or len(n.upstreams) != 1:
            continue
        up = n.upstreams[0]
        if up.op is None:
            continue
        hop = net.invoke_overhead_s + net.transfer_time(
            profiles[up.id].out_bytes)
        compute = profiles[n.id].mean_s
        edge_total += 1
        if hop > fuse_ratio * max(compute, 1e-9):
            edge_votes += 1
    fusion = edge_total > 0 and edge_votes >= max(1, edge_total // 2)
    notes.append(f"fusion: {edge_votes}/{edge_total} edges hop-dominated")

    # -- competitive: flag tail-dominated operators --------------------------
    rep: Dict[int, int] = {}
    for n in flow.sorted_nodes():
        if n.op is None:
            continue
        p = profiles[n.id]
        if p.cv > cv_threshold and p.mean_s > 1e-3:
            rep[n.id] = replicas
            n.op.high_variance = True
            n.op.competitive_replicas = replicas
            notes.append(f"competitive x{replicas}: node {n.id} "
                         f"({n.op.name}, cv={p.cv:.2f})")
    competitive_exec = bool(rep)

    # -- locality: lookups with real payloads --------------------------------
    locality = False
    for n in flow.sorted_nodes():
        if n.op is None:
            continue
        is_lookup = isinstance(n.op, ops.Lookup)
        if is_lookup and profiles[n.id].out_bytes > 64 * 1024:
            locality = True
            notes.append(f"locality: lookup node {n.id} moves "
                         f"{profiles[n.id].out_bytes/1e6:.2f} MB")

    # -- XLA lowering: fused GPU JAX chains compile to one jitted callable ---
    # count *fusable adjacent* lowerable-map edges (same structural
    # conditions fusion uses), so the note only fires when LowerJaxChains
    # will actually get a >=2-op chain to compile
    from repro.core.lowering import map_is_jax_lowerable
    counts: Dict[int, int] = {}
    for n in flow.sorted_nodes():
        for u in n.upstreams:
            counts[u.id] = counts.get(u.id, 0) + 1

    def _lowerable_gpu(n) -> bool:
        return (n.op is not None and n.op.resource_class == "gpu"
                and map_is_jax_lowerable(n.op))

    jit_edges = sum(
        1 for n in flow.sorted_nodes()
        if _lowerable_gpu(n) and len(n.upstreams) == 1
        and _lowerable_gpu(n.upstreams[0])
        and counts.get(n.upstreams[0].id, 0) == 1)
    jit_fusion = bool(fusion and jit_edges >= 1)
    if jit_fusion:
        notes.append(f"jit: {jit_edges} fusable gpu jax map edges are "
                     "XLA-lowerable after fusion")

    # -- batched lowering: batch-hinted ops or multi-row requests benefit
    # from ONE vmapped dispatch per batch; per-row lowering is kept for
    # strictly single-row pipelines (no stacking overhead to pay)
    has_batch_hint = any(n.op is not None and n.op.batching
                         for n in flow.sorted_nodes())
    batched_lowering = bool(jit_fusion
                            and (has_batch_hint or len(sample.rows) > 1))
    if jit_fusion:
        notes.append("batched lowering: "
                     + ("vmap over row batches (batch-hinted ops or "
                        "multi-row sample)" if batched_lowering
                        else "per-row (single-row pipeline, no batch hints)"))
    return Plan(fusion=fusion, competitive_exec=competitive_exec,
                locality=locality, replicas=rep, profiles=profiles,
                notes=notes, jit_fusion=jit_fusion,
                batched_lowering=batched_lowering,
                default_replicas=replicas)


def auto_deploy(flow: Dataflow, runtime, sample: Table, *, runs: int = 3,
                **plan_kwargs):
    """Profile, plan, and deploy in one call (paper §7 made concrete)."""
    plan = make_plan(flow, sample, net=runtime.net, runs=runs,
                     kvs=runtime.kvs, **plan_kwargs)
    deployed = flow.deploy(runtime, pipeline=plan.build_pipeline())
    return deployed, plan
