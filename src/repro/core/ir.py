"""Physical-plan IR: the layer between logical ``Dataflow`` graphs and the
runtime (PRETZEL-style white-box plan compilation).

A ``PhysicalPlan`` is an immutable, topologically ordered sequence of
``PhysicalOp`` records.  Each record carries a logical operator payload plus
the *scheduling annotations* the paper's optimizations (§4) need — placement
(resource class), batching, wait-for-any, competitive-replication, and
locality (resolved-ref dynamic dispatch).  Optimizations are expressed as
passes over this IR (``repro.core.passes``); the runtime lowering
(``RuntimeDag.from_plan``) consumes the annotated plan verbatim.

Conventions:

* op ids are positive ints; ``SOURCE_ID`` (0) denotes the plan input and has
  no ``PhysicalOp`` record;
* ``plan.ops`` is topologically sorted — every op's inputs appear earlier
  (or are the source);
* passes never mutate: they build a new ``PhysicalPlan`` via ``with_ops``,
  which re-validates the invariants above.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.core import operators as ops
from repro.core.table import Schema, Table

SOURCE_ID = 0


class PlanError(ValueError):
    pass


@dataclasses.dataclass(frozen=True)
class PhysicalOp:
    """One physical operator: logical payload + scheduling annotations."""
    op_id: int
    op: ops.Operator
    inputs: Tuple[int, ...]
    # -- scheduling annotations (paper §4) ---------------------------------
    placement: str = "cpu"              # executor resource class
    batching: bool = False
    wait_any: bool = False              # wait-for-any (anyof) semantics
    high_variance: bool = False
    replicas: int = 0                   # competitive replication factor
    # locality / dynamic dispatch: resolved-ref column or constant key
    locality_ref_column: Optional[str] = None
    locality_const: Optional[str] = None
    # batched execution (set by LowerJaxChainsPass): the op executes whole
    # row batches in one vmapped XLA dispatch, padded to these row-count
    # buckets (the runtime feeds merged request tables straight in)
    batchable: bool = False
    batch_buckets: Tuple[int, ...] = ()
    # device residency (set by LowerJaxChainsPass): the op can consume and
    # produce device-resident columnar batches (DeviceTable) — the runtime
    # lowering wires adjacent device-resident ops so batches skip the host
    # round-trip between them
    device_resident: bool = False
    # Pallas kernels placed into this op's map steps (set by
    # PlaceKernelsPass): repr strings of the KernelCalls, for explain
    # output and tests — the executable identity lives in the step fns
    kernels: Tuple[str, ...] = ()
    # buffer-donation intent for this op's device-resident output edge:
    # None derives the runtime's safe default (donate only single-
    # consumer device edges); True forces donation (audited by the
    # static verifier — donating a fan-out edge deletes buffers a
    # sibling consumer still needs); False forbids it
    donate: Optional[bool] = None

    def replace(self, **kw) -> "PhysicalOp":
        return dataclasses.replace(self, **kw)

    @property
    def locality_key(self) -> Optional[str]:
        return self.locality_ref_column or self.locality_const

    def __repr__(self):
        flags = []
        if self.placement != "cpu":
            flags.append(self.placement)
        if self.batching:
            flags.append("batch")
        if self.batchable:
            flags.append("vmap")
        if self.device_resident:
            flags.append("dev")
        if self.kernels:
            flags.append(f"pallas:{','.join(k.split('(')[0] for k in self.kernels)}")
        if self.donate is not None:
            flags.append("donate" if self.donate else "nodonate")
        if self.wait_any:
            flags.append("any")
        if self.replicas:
            flags.append(f"x{self.replicas}")
        if self.locality_key:
            flags.append(f"near:{self.locality_key}")
        tag = f" [{','.join(flags)}]" if flags else ""
        return (f"%{self.op_id} = {self.op.name}"
                f"({', '.join(f'%{i}' for i in self.inputs)}){tag}")


def annotations_from_op(op: ops.Operator) -> Dict[str, Any]:
    """Lift a logical operator's hint fields into IR annotations."""
    return dict(placement=op.resource_class, batching=op.batching,
                wait_any=isinstance(op, ops.AnyOf),
                high_variance=op.high_variance,
                replicas=op.competitive_replicas)


@dataclasses.dataclass(frozen=True)
class PhysicalPlan:
    """Immutable physical plan: topo-sorted ops + the output op id."""
    input_schema: Tuple[Tuple[str, type], ...]
    ops: Tuple[PhysicalOp, ...]
    output_id: int

    # -- construction -------------------------------------------------------
    @staticmethod
    def from_dataflow(flow) -> "PhysicalPlan":
        """Lower a logical ``Dataflow`` into the physical IR.  Annotations
        are seeded from the operators' optimization hints."""
        mapping: Dict[int, int] = {}
        records: List[PhysicalOp] = []
        next_id = SOURCE_ID + 1
        for n in flow.sorted_nodes():
            if n.op is None:
                mapping[n.id] = SOURCE_ID
                continue
            inputs = tuple(mapping[u.id] for u in n.upstreams)
            records.append(PhysicalOp(op_id=next_id, op=n.op, inputs=inputs,
                                      **annotations_from_op(n.op)))
            mapping[n.id] = next_id
            next_id += 1
        if flow.output is None or flow.output.id not in mapping:
            raise PlanError("flow has no output")
        out = mapping[flow.output.id]
        if out == SOURCE_ID:
            raise PlanError("plan output cannot be the source")
        schema = tuple((n, t) for n, t in flow.input_schema)
        plan = PhysicalPlan(schema, tuple(records), out)
        plan.validate()
        return plan

    def with_ops(self, new_ops: List[PhysicalOp],
                 output_id: Optional[int] = None) -> "PhysicalPlan":
        plan = PhysicalPlan(self.input_schema, tuple(new_ops),
                            self.output_id if output_id is None else output_id)
        plan.validate()
        return plan

    def __post_init__(self):
        object.__setattr__(self, "_by_id", {o.op_id: o for o in self.ops})

    # -- accessors ----------------------------------------------------------
    def op(self, op_id: int) -> PhysicalOp:
        try:
            return self._by_id[op_id]
        except KeyError:
            raise PlanError(f"no op %{op_id} in plan") from None

    @property
    def output(self) -> PhysicalOp:
        return self.op(self.output_id)

    def consumer_counts(self) -> Dict[int, int]:
        counts: Dict[int, int] = {}
        for o in self.ops:
            for i in o.inputs:
                counts[i] = counts.get(i, 0) + 1
        return counts

    def next_id(self) -> int:
        return max((o.op_id for o in self.ops), default=SOURCE_ID) + 1

    # -- invariants ---------------------------------------------------------
    def validate(self):
        seen = {SOURCE_ID}
        for o in self.ops:
            if o.op_id in seen:
                raise PlanError(f"duplicate op id %{o.op_id}")
            if o.op is None:
                raise PlanError(f"%{o.op_id} has no operator payload")
            for i in o.inputs:
                if i not in seen:
                    raise PlanError(
                        f"%{o.op_id} consumes %{i} which is not defined "
                        "earlier (plan must be topologically sorted)")
            seen.add(o.op_id)
        if self.output_id not in seen or self.output_id == SOURCE_ID:
            raise PlanError(f"output %{self.output_id} not in plan")

    def typecheck(self) -> Dict[int, Tuple[Schema, Optional[str]]]:
        """Propagate (schema, grouping) through the plan; raises on
        mismatch.  The IR analogue of ``Dataflow.typecheck``."""
        info: Dict[int, Tuple[Schema, Optional[str]]] = {
            SOURCE_ID: (list(self.input_schema), None)}
        for o in self.ops:
            schemas = [info[i][0] for i in o.inputs]
            groupings = [info[i][1] for i in o.inputs]
            info[o.op_id] = (o.op.typecheck(schemas),
                             o.op.out_grouping(groupings))
        return info

    # -- reference semantics ------------------------------------------------
    def execute_local(self, table: Table, ctx=None) -> Table:
        """Single-process interpreter over the plan (oracle for pass
        equivalence tests)."""
        results: Dict[int, Table] = {SOURCE_ID: table}
        for o in self.ops:
            ins = [results[i] for i in o.inputs]
            results[o.op_id] = o.op.apply(ins, ctx)
        return results[self.output_id]

    # -- logical round-trip (compatibility shim support) ---------------------
    def to_dataflow(self):
        """Reconstruct a logical ``Dataflow`` carrying this plan's operators
        and annotations (used by the ``apply_rewrites`` compatibility shim).
        Operator hint fields are re-synced from the IR annotations."""
        import copy

        from repro.core.dataflow import Dataflow, Node

        flow = Dataflow(list(self.input_schema))
        nodes: Dict[int, Node] = {SOURCE_ID: flow.source}
        for o in self.ops:
            op = copy.copy(o.op)
            op.resource_class = o.placement
            op.batching = o.batching
            op.high_variance = o.high_variance
            op.competitive_replicas = o.replicas
            nodes[o.op_id] = Node(flow, op, [nodes[i] for i in o.inputs])
        flow.output = nodes[self.output_id]
        return flow

    def __repr__(self):
        lines = [f"plan(input={list(self.input_schema)})"]
        lines += [f"  {o!r}" for o in self.ops]
        lines.append(f"  return %{self.output_id}")
        return "\n".join(lines)
